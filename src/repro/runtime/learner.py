"""Parallel learners — the paper's parameter-server adaptation (§V-B).

Two execution styles:

  * **GSPMD (default)**: the learner batch is sharded over the data
    axes; jit + sharding constraints make XLA insert the gradient
    all-reduce.  Push(sub-gradients) + aggregate + pull(weights) of a
    parameter server on a torus *is* reduce-scatter + all-gather.

  * **shard_map (explicit)**: ``sharded_learn`` runs one learner per
    data-device with an explicit gradient ``pmean`` — used by the
    sharded-replay path where each learner samples from its local buffer
    shard.  (The cross-pod int8 error-feedback reduce in
    optim/compress.py is a future extension of this path; ROADMAP.)

The async-PS variant applies gradients with bounded staleness: actors
never block on the learner (the lazy-write invariant) and a learner
shard that misses ``max_staleness`` rounds is dropped from the reduce
(straggler mitigation — the reduce weight renormalizes).  That path is
``make_sharded_learn(..., max_staleness=...)``: each shard's gradient is
scaled by ``staleness_weights(age, max_staleness)`` and the psum is
renormalized by the total weight, so the realized reduce weights sum to
one whenever at least one shard is within the bound
(``staleness_reduce_weights``) and the update degrades to zero — params
held, never corrupted — when every shard is stale.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.agents.base import Agent
from repro.core.distributed import ShardedPrioritizedReplay

Pytree = Any


def pmean_gradients(grads: Pytree, axes: Tuple[str, ...]) -> Pytree:
    """Shard-average the gradient pytree (psum / axis size).  The mean —
    not the raw sum — keeps the effective learning rate independent of
    the shard count."""
    out = grads
    for ax in axes:
        out = jax.tree.map(lambda g: jax.lax.pmean(g, ax), out)
    return out


def _pmean_inexact(tree: Pytree, axes: Tuple[str, ...]) -> Pytree:
    """pmean only float leaves (opt-state step counters stay int)."""
    def avg(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x
        out = x
        for ax in axes:
            out = jax.lax.pmean(out, ax)
        return out
    return jax.tree.map(avg, tree)


def _weighted_psum(tree: Pytree, scale: jax.Array, axes: Tuple[str, ...]) -> Pytree:
    """psum of ``leaf * scale`` over ``axes`` (scale is a per-shard scalar)."""
    def red(x):
        out = x * scale
        for ax in axes:
            out = jax.lax.psum(out, ax)
        return out
    return jax.tree.map(red, tree)


def _renormalize(w: jax.Array, total: jax.Array) -> jax.Array:
    """``w / Σw`` with the all-stale clamp — the single renormalization
    used by both the production reduce (``total`` = psum over the mesh)
    and the property-testable vector form (``total`` = jnp.sum)."""
    return w / jnp.maximum(total, 1e-12)


def make_sharded_learn(
    agent: Agent,
    replay: ShardedPrioritizedReplay,
    batch_per_shard: int,
    beta: float = 0.4,
    max_staleness: Optional[int] = None,
):
    """Per-shard learner call: local PER sample → local grads → reduce →
    update (paper §V-B parameter-server adaptation).

    Returns ``sharded_learn(agent_state, replay_state, rng, age=None) →
    (agent_state', replay_state', loss)`` — the same signature as the
    fused ``make_learner_step`` — to be invoked *inside* ``shard_map``
    over ``replay.config.axis_names``:

      * the PER sample is local to the shard's tree/storage, with
        importance weights against the psum'd global distribution
        (``ShardedPrioritizedReplay.sample``);
      * agents exposing the ``grads``/``apply_grads`` split get the exact
        data-parallel reduction: grads are pmean'd across shards before
        the optimizer step, so replicated params stay bit-identical;
      * with ``max_staleness`` set (the async executor's sharded path),
        the pmean becomes the bounded-staleness weighted reduce: each
        shard's gradient is scaled by ``staleness_weights(age,
        max_staleness)`` and the psum renormalized by the total weight —
        a shard whose acting copy aged past the bound is dropped from
        the reduce and the surviving weights sum to one (``age`` is the
        shard's ``LoopState.params_age``);
      * agents without the split fall back to a local ``learn`` followed
        by a parameter/target/opt pmean (gossip-average; identical result
        at 1 shard, approximate beyond);
      * priority write-back stays local (write-after-read, §IV-D3).
    """
    axes = replay.config.axis_names

    def reduce_grads(grads, age):
        if max_staleness is None or age is None:
            return pmean_gradients(grads, axes)
        w = staleness_weights(age, max_staleness)
        total = w
        for ax in axes:
            total = jax.lax.psum(total, ax)
        # renormalized weighted reduce: realized weight of shard d is
        # w_d / Σw — sums to 1 while any shard is within the bound, and
        # degrades to an all-zero gradient (params held) when none is
        return _weighted_psum(grads, _renormalize(w, total), axes)

    def sharded_learn(agent_state, replay_state, rng, age=None):
        idx, items, is_w = replay.sample(replay_state, rng, batch_per_shard, beta)
        if agent.grads is not None and agent.apply_grads is not None:
            grads, aux = agent.grads(agent_state, items, is_w)
            grads = reduce_grads(grads, age)
            agent_state, metrics, td = agent.apply_grads(agent_state, grads, aux)
        else:
            agent_state, metrics, td = agent.learn(agent_state, items, is_w)
            agent_state = agent_state._replace(
                params=_pmean_inexact(agent_state.params, axes),
                target=_pmean_inexact(agent_state.target, axes),
                opt=_pmean_inexact(agent_state.opt, axes),
            )
        replay_state = replay.update_priorities(replay_state, idx, td)
        return agent_state, replay_state, metrics["loss"]

    return sharded_learn


def staleness_weights(ages: jax.Array, max_staleness: int) -> jax.Array:
    """Bounded-staleness discount: weight 1/(1+age), 0 beyond the bound
    (dropped straggler)."""
    w = 1.0 / (1.0 + ages.astype(jnp.float32))
    return jnp.where(ages > max_staleness, 0.0, w)


def staleness_reduce_weights(ages: jax.Array, max_staleness: int) -> jax.Array:
    """Realized per-shard reduce weights of the bounded-staleness reduce:
    ``staleness_weights`` renormalized by their sum over the shard vector.

    Invariant (property-tested): the weights sum to exactly the gradient
    scale of a synchronous pmean — 1 — whenever at least one shard is
    within the bound, and to 0 (update skipped, params held) when every
    shard is stale."""
    w = staleness_weights(ages, max_staleness)
    return _renormalize(w, jnp.sum(w))
