"""Parallel learners — the paper's parameter-server adaptation (§V-B).

Two execution styles:

  * **GSPMD (default)**: the learner batch is sharded over the data
    axes; jit + sharding constraints make XLA insert the gradient
    all-reduce.  Push(sub-gradients) + aggregate + pull(weights) of a
    parameter server on a torus *is* reduce-scatter + all-gather.

  * **shard_map (explicit)**: ``sharded_learn`` runs one learner per
    data-device with an explicit gradient ``pmean`` — used by the
    sharded-replay path where each learner samples from its local buffer
    shard.  (The cross-pod int8 error-feedback reduce in
    optim/compress.py is a future extension of this path; ROADMAP.)

An async-PS variant applies gradients with bounded staleness: actors
never block on the learner (the lazy-write invariant) and a learner
shard that misses ``max_staleness`` rounds is dropped from the reduce
(straggler mitigation — the reduce weight renormalizes).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.agents.base import Agent
from repro.core.distributed import ShardedPrioritizedReplay

Pytree = Any


def pmean_gradients(grads: Pytree, axes: Tuple[str, ...]) -> Pytree:
    """Shard-average the gradient pytree (psum / axis size).  The mean —
    not the raw sum — keeps the effective learning rate independent of
    the shard count."""
    out = grads
    for ax in axes:
        out = jax.tree.map(lambda g: jax.lax.pmean(g, ax), out)
    return out


def _pmean_inexact(tree: Pytree, axes: Tuple[str, ...]) -> Pytree:
    """pmean only float leaves (opt-state step counters stay int)."""
    def avg(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x
        out = x
        for ax in axes:
            out = jax.lax.pmean(out, ax)
        return out
    return jax.tree.map(avg, tree)


def make_sharded_learn(
    agent: Agent,
    replay: ShardedPrioritizedReplay,
    batch_per_shard: int,
    beta: float = 0.4,
):
    """Per-shard learner call: local PER sample → local grads → pmean →
    update (paper §V-B parameter-server adaptation).

    Returns ``sharded_learn(agent_state, replay_state, rng) →
    (agent_state', replay_state', loss)`` — the same signature as the
    fused ``make_learner_step`` — to be invoked *inside* ``shard_map``
    over ``replay.config.axis_names``:

      * the PER sample is local to the shard's tree/storage, with
        importance weights against the psum'd global distribution
        (``ShardedPrioritizedReplay.sample``);
      * agents exposing the ``grads``/``apply_grads`` split get the exact
        data-parallel reduction: grads are pmean'd across shards before
        the optimizer step, so replicated params stay bit-identical;
      * agents without the split fall back to a local ``learn`` followed
        by a parameter/target/opt pmean (gossip-average; identical result
        at 1 shard, approximate beyond);
      * priority write-back stays local (write-after-read, §IV-D3).
    """
    axes = replay.config.axis_names

    def sharded_learn(agent_state, replay_state, rng):
        idx, items, is_w = replay.sample(replay_state, rng, batch_per_shard, beta)
        if agent.grads is not None and agent.apply_grads is not None:
            grads, aux = agent.grads(agent_state, items, is_w)
            grads = pmean_gradients(grads, axes)
            agent_state, metrics, td = agent.apply_grads(agent_state, grads, aux)
        else:
            agent_state, metrics, td = agent.learn(agent_state, items, is_w)
            agent_state = agent_state._replace(
                params=_pmean_inexact(agent_state.params, axes),
                target=_pmean_inexact(agent_state.target, axes),
                opt=_pmean_inexact(agent_state.opt, axes),
            )
        replay_state = replay.update_priorities(replay_state, idx, td)
        return agent_state, replay_state, metrics["loss"]

    return sharded_learn


def staleness_weights(ages: jax.Array, max_staleness: int) -> jax.Array:
    """Bounded-staleness discount: weight 1/(1+age), 0 beyond the bound
    (dropped straggler)."""
    w = 1.0 / (1.0 + ages.astype(jnp.float32))
    return jnp.where(ages > max_staleness, 0.0, w)
