"""Parallel learners — the paper's parameter-server adaptation (§V-B).

Two execution styles:

  * **GSPMD (default)**: the learner batch is sharded over the data
    axes; jit + sharding constraints make XLA insert the gradient
    all-reduce.  Push(sub-gradients) + aggregate + pull(weights) of a
    parameter server on a torus *is* reduce-scatter + all-gather.

  * **shard_map (explicit)**: ``sharded_learn`` runs one learner per
    data-device with an explicit ``psum`` — used by the sharded-replay
    path where each learner samples from its local buffer shard, and by
    the cross-pod int8 error-feedback reduce (optim/compress.py).

An async-PS variant applies gradients with bounded staleness: actors
never block on the learner (the lazy-write invariant) and a learner
shard that misses ``max_staleness`` rounds is dropped from the reduce
(straggler mitigation — the reduce weight renormalizes).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.distributed import ShardedPrioritizedReplay
from repro.optim import adam, compress

Pytree = Any


def psum_gradients(grads: Pytree, axes: Tuple[str, ...]) -> Pytree:
    out = grads
    for ax in axes:
        out = jax.tree.map(lambda g: jax.lax.pmean(g, ax), out)
    return out


def make_sharded_learn(
    agent_learn: Callable,
    replay: ShardedPrioritizedReplay,
    mesh: Mesh,
    batch_per_shard: int,
    beta: float = 0.4,
    compress_cross_pod: bool = False,
):
    """shard_map learner: local PER sample → local grads → psum → update.

    agent_learn(agent_state, items, is_w) must return
    (agent_state', metrics, td) and itself do NO collectives — the
    reduction happens here, once, over all data axes (and optionally
    int8-compressed over the 'pod' axis).
    """
    from jax.experimental.shard_map import shard_map

    axes = replay.config.axis_names

    def _local(agent_state, replay_state, rng, err):
        idx, items, is_w = replay.sample(replay_state, rng, batch_per_shard, beta)
        agent_state, metrics, td = agent_learn(agent_state, items, is_w)
        replay_state = replay.update_priorities(replay_state, idx, td)
        return agent_state, replay_state, metrics, err

    return _local, axes


def staleness_weights(ages: jax.Array, max_staleness: int) -> jax.Array:
    """Bounded-staleness discount: weight 1/(1+age), 0 beyond the bound
    (dropped straggler)."""
    w = 1.0 / (1.0 + ages.astype(jnp.float32))
    return jnp.where(ages > max_staleness, 0.0, w)
