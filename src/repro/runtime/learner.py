"""Parallel learners — the paper's parameter-server adaptation (§V-B).

Two execution styles:

  * **GSPMD (default)**: the learner batch is sharded over the data
    axes; jit + sharding constraints make XLA insert the gradient
    all-reduce.  Push(sub-gradients) + aggregate + pull(weights) of a
    parameter server on a torus *is* reduce-scatter + all-gather.

  * **shard_map (explicit)**: ``sharded_learn`` runs one learner per
    data-device with an explicit gradient ``pmean`` — used by the
    sharded-replay path where each learner samples from its local buffer
    shard.

On a 2-D ``("pod", "data")`` mesh the reduce is **hierarchical**
(DESIGN.md §7): gradients first reduce in f32 over the fast intra-pod
``data`` axis, then cross the slow inter-pod ``pod`` links through the
int8 error-feedback compressed reduce of ``optim/compress.py``
(``compressed_pmean``).  The EF buffer is explicit state threaded
through ``LoopState.ef_error`` — identical across the data shards of a
pod (they compress the same intra-pod partial), differing across pods.

The async-PS variant applies gradients with bounded staleness: actors
never block on the learner (the lazy-write invariant) and a learner
shard that misses ``max_staleness`` rounds is dropped from the reduce
(straggler mitigation — the reduce weight renormalizes).  That path is
``make_sharded_learn(..., max_staleness=...)``: each shard's gradient is
scaled by ``staleness_weights(age, max_staleness)`` and the psum is
renormalized by the total weight, so the realized reduce weights sum to
one whenever at least one shard is within the bound
(``staleness_reduce_weights``) and the update degrades to zero — params
held, never corrupted — when every shard is stale.  Composed with
compression, the weighted partial sums cross the pod axis as
``compressed_pmean × n_pods`` (mean × static pod count = the weighted
sum), so the realized weights still total one.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.agents.base import Agent
from repro.core.distributed import ShardedPrioritizedReplay
from repro.optim import compress
from repro.optim.collectives import fused_tree_reduce

Pytree = Any


def pmean_gradients(grads: Pytree, axes: Tuple[str, ...],
                    dtype=None) -> Pytree:
    """Shard-average the gradient pytree (psum / axis size).  The mean —
    not the raw sum — keeps the effective learning rate independent of
    the shard count.  ``dtype`` (e.g. ``jnp.bfloat16``) casts each leaf
    onto the wire before the reduce and back to its original dtype
    after — the bf16 intra-pod option, halving the reduce payload at the
    cost of mantissa bits (the injected error is surfaced per step as
    the ``compress_error_norm`` metric).  The whole pytree crosses the
    wire as ONE fused collective per axis (``optim/collectives.py``) —
    bit-exact against the per-leaf form, but a single launch on a real
    multi-process transport."""
    cast = dtype is not None and bool(axes)   # no axes → nothing on a wire
    wire = jax.tree.map(lambda g: g.astype(dtype), grads) if cast else grads
    red = fused_tree_reduce(wire, axes, jax.lax.pmean)
    if cast:
        red = jax.tree.map(lambda o, g: o.astype(g.dtype), red, grads)
    return red


def _pmean_inexact(tree: Pytree, axes: Tuple[str, ...]) -> Pytree:
    """pmean only float leaves (opt-state step counters stay int)."""
    return fused_tree_reduce(
        tree, axes, jax.lax.pmean,
        select=lambda x: jnp.issubdtype(x.dtype, jnp.inexact))


def _weighted_psum(tree: Pytree, scale: jax.Array, axes: Tuple[str, ...],
                   dtype=None) -> Pytree:
    """psum of ``leaf * scale`` over ``axes`` (scale is a per-shard
    scalar); ``dtype`` casts onto the wire like ``pmean_gradients``, and
    the reduce is fused the same way (one launch per axis)."""
    cast = dtype is not None and bool(axes)
    scaled = jax.tree.map(lambda x: x * scale, tree)
    if cast:
        scaled = jax.tree.map(lambda x: x.astype(dtype), scaled)
    red = fused_tree_reduce(scaled, axes, jax.lax.psum)
    if cast:
        red = jax.tree.map(lambda o, x: o.astype(x.dtype), red, tree)
    return red


def _renormalize(w: jax.Array, total: jax.Array) -> jax.Array:
    """``w / Σw`` with the all-stale clamp — the single renormalization
    used by both the production reduce (``total`` = psum over the mesh)
    and the property-testable vector form (``total`` = jnp.sum)."""
    return w / jnp.maximum(total, 1e-12)


def resolve_reduce_dtype(intra_pod_dtype: Optional[str]):
    """Map the executor-facing intra-pod reduce dtype option onto a jnp
    dtype (None = f32, no cast)."""
    if intra_pod_dtype in (None, "f32", "float32"):
        return None
    if intra_pod_dtype in ("bf16", "bfloat16"):
        return jnp.bfloat16
    raise ValueError(
        f"intra_pod_dtype={intra_pod_dtype!r}: expected 'f32' or 'bf16'")


def make_grad_reducer(
    axes: Tuple[str, ...],
    max_staleness: Optional[int] = None,
    compress_axis: Optional[str] = None,
    intra_pod_dtype: Optional[str] = None,
    overlap: bool = False,
):
    """Build the cross-shard gradient reduce used by ``sharded_learn``:
    ``reduce_grads(grads, age, ef) → (reduced, ef')`` over mesh ``axes``
    (call inside shard_map, or vmap with axis names in tests).

    Plain pmean by default; bounded-staleness renormalized weighted psum
    with ``max_staleness``; hierarchical f32-intra-pod / int8-EF-cross-
    pod with ``compress_axis`` (DESIGN.md §7) — composable with both.
    ``intra_pod_dtype='bf16'`` halves the wire payload of the fast-axis
    leg (all axes when there is no compressed pod leg) by casting each
    leaf to bf16 around the reduce.

    ``overlap=True`` double-buffers the compressed pod leg (DESIGN.md
    §10): learn event *i* applies this event's intra-pod partial plus
    the cross-pod *correction* computed at event *i−1*,

        applied_i = p_i + (pm_{i−1} − p_{i−1})

    so the slow ``compressed_pmean`` issued at event *i* is consumed
    only at event *i+1* — its result leaves the critical path and the
    collective runs concurrently with the next actor/learn chunk (XLA /
    the gloo transport overlap it with compute because nothing in this
    step's program depends on it).  The carried state becomes
    ``{"ef": …, "prev_mean": …, "prev_partial": …}``: the quantizer's EF
    buffer plus the previous event's pod mean and intra-pod partial.
    The update is computed as ``pm_{i−1} + (p_i − p_{i−1})`` — the same
    value, associated so that a constant gradient stream yields the
    barrier reduce's previous-event output *bit-exactly* from the second
    event on (the delta is exactly zero); for varying streams the
    cumulative difference telescopes to ``p_T − pm_T`` — one gradient's
    pod disagreement, never compounding (tests/test_distributed.py).
    Incompatible with ``max_staleness``: the staleness-weighted partial
    sums renormalize by a *global* total, which would need this event's
    cross-pod traffic on the critical path again.
    """
    if compress_axis is not None and compress_axis not in axes:
        raise ValueError(
            f"compress_axis={compress_axis!r} is not one of the mesh "
            f"axes {axes}")
    if overlap and compress_axis is None:
        raise ValueError(
            "overlap=True needs compress_axis: the double buffer defers "
            "the compressed cross-pod leg — with no pod leg there is "
            "nothing to overlap (the intra-pod pmean stays synchronous)")
    if overlap and max_staleness is not None:
        raise ValueError(
            "overlap=True is incompatible with max_staleness: the "
            "bounded-staleness reduce renormalizes by a global weight "
            "total, which puts this event's cross-pod traffic back on "
            "the critical path — pick one of the two staleness forms")
    fast_axes = tuple(ax for ax in axes if ax != compress_axis)
    wire_dtype = resolve_reduce_dtype(intra_pod_dtype)

    def reduce_grads(grads, age, ef):
        if compress_axis is not None and not jax.tree.leaves(ef):
            raise ValueError(
                "compress_axis is set but no error-feedback buffer was "
                "passed: thread LoopState.ef_error through the learn fn "
                "(init_loop_state(..., ef_buffer=True) materializes it)")
        if overlap:
            # double-buffered pod leg: apply the one-event-stale cross-
            # pod mean corrected by the fresh local delta, issue this
            # event's compressed mean for the next event.  pm + (p − p')
            # rather than p + (pm − p'): for an unchanged partial the
            # delta is exactly 0.0 and the applied update is bitwise the
            # previous barrier output.
            partial = pmean_gradients(grads, fast_axes, dtype=wire_dtype)
            pod_mean, new_ef = compress.compressed_pmean(
                partial, ef["ef"], compress_axis)
            applied = jax.tree.map(
                lambda pm, p, pp: pm + (p - pp),
                ef["prev_mean"], partial, ef["prev_partial"])
            return applied, {"ef": new_ef, "prev_mean": pod_mean,
                             "prev_partial": partial}
        if max_staleness is None or age is None:
            if compress_axis is None:
                return pmean_gradients(grads, axes, dtype=wire_dtype), ef
            # hierarchical: f32/bf16 mean inside the pod, int8-EF mean
            # across pods — equals the global pmean up to the wire error
            partial = pmean_gradients(grads, fast_axes, dtype=wire_dtype)
            return compress.compressed_pmean(partial, ef, compress_axis)
        w = staleness_weights(age, max_staleness)
        total = w
        for ax in axes:
            total = jax.lax.psum(total, ax)
        # renormalized weighted reduce: realized weight of shard d is
        # w_d / Σw — sums to 1 while any shard is within the bound, and
        # degrades to an all-zero gradient (params held) when none is
        wn = _renormalize(w, total)
        if compress_axis is None:
            return _weighted_psum(grads, wn, axes, dtype=wire_dtype), ef
        # weighted hierarchical reduce: f32 weighted partial sums inside
        # the pod, then the compressed mean across pods scaled by the
        # static pod count — mean × P = the cross-pod sum, so the
        # realized weights still total exactly 1.  An all-stale round
        # must degrade to an exactly-zero update with the EF buffer held:
        # the quantizer folds the carried error into zero partials, so
        # without the gate it would emit ≈ Σ_pods ef_p as a gradient.
        partial = _weighted_psum(grads, wn, fast_axes, dtype=wire_dtype)
        pod_mean, new_ef = compress.compressed_pmean(partial, ef,
                                                     compress_axis)
        n_pods = jax.lax.psum(1, compress_axis)
        alive = total > 0
        reduced = jax.tree.map(
            lambda g: jnp.where(alive, g * n_pods, 0.0), pod_mean)
        ef = jax.tree.map(lambda n, o: jnp.where(alive, n, o), new_ef, ef)
        return reduced, ef

    return reduce_grads


def make_sharded_learn(
    agent: Agent,
    replay: ShardedPrioritizedReplay,
    batch_per_shard: int,
    beta: float = 0.4,
    max_staleness: Optional[int] = None,
    compress_axis: Optional[str] = None,
    intra_pod_dtype: Optional[str] = None,
    lazy_writes: bool = False,
    overlap: bool = False,
):
    """Per-shard learner call: local PER sample → local grads → reduce →
    update (paper §V-B parameter-server adaptation).

    Returns ``sharded_learn(agent_state, replay_state, rng, age=None,
    ef=None) → (agent_state', replay_state', learn_metrics, ef')`` — the
    same signature as the fused ``make_learner_step`` (``learn_metrics``
    carries ``loss`` and ``compress_error_norm``) — to be invoked *inside*
    ``shard_map`` over ``replay.config.axis_names``:

      * the PER sample is local to the shard's tree/storage, with
        importance weights against the psum'd global distribution
        (``ShardedPrioritizedReplay.sample``);
      * agents exposing the ``grads``/``apply_grads`` split get the exact
        data-parallel reduction: grads are pmean'd across shards before
        the optimizer step, so replicated params stay bit-identical;
      * with ``compress_axis`` set (the 2-D pod×data mesh), the reduce is
        hierarchical: an f32 pmean over the remaining (fast intra-pod)
        axes, then the int8 error-feedback ``compressed_pmean`` across
        ``compress_axis`` — ``ef`` carries the per-shard EF buffer in
        and the contracted buffer out (``LoopState.ef_error``);
      * with ``max_staleness`` set (the async executor's sharded path),
        the pmean becomes the bounded-staleness weighted reduce: each
        shard's gradient is scaled by ``staleness_weights(age,
        max_staleness)`` and the psum renormalized by the total weight —
        a shard whose acting copy aged past the bound is dropped from
        the reduce and the surviving weights sum to one (``age`` is the
        shard's ``LoopState.params_age``).  Composed with
        ``compress_axis``, the weighted partials psum in f32 inside the
        pod and cross the pod axis as ``compressed_pmean × n_pods`` (the
        weighted sum, since the weights were renormalized globally);
      * agents without the split fall back to a local ``learn`` followed
        by a parameter/target/opt pmean (gossip-average; identical result
        at 1 shard, approximate beyond) — incompatible with
        ``compress_axis`` (there is no gradient pytree to compress) and
        with ``intra_pod_dtype`` (no gradient pytree to cast);
      * ``intra_pod_dtype='bf16'`` casts the fast-axis reduce leg to
        bf16 on the wire; the injected error is reported per learn as
        ``compress_error_norm`` (local cast error ‖g − bf16(g)‖₂,
        summed with the EF-buffer norm of the int8 pod leg when both
        compressions are active);
      * priority write-back stays local (write-after-read, §IV-D3);
        ``lazy_writes=True`` defers its propagation to the runtime
        loop's per-iteration flush (DESIGN.md §9);
      * ``overlap=True`` (requires ``compress_axis``) double-buffers the
        compressed pod leg — this learn applies the previous learn's
        cross-pod correction while issuing its own off the critical path
        (``make_grad_reducer``, DESIGN.md §10).  ``ef`` then carries the
        ``{"ef", "prev_mean", "prev_partial"}`` triple
        (``init_loop_state(..., overlap=True)``); only the ``"ef"``
        entry feeds the ``compress_error_norm`` metric, matching the
        barrier reduce.
    """
    axes = replay.config.axis_names
    if compress_axis is not None and (agent.grads is None
                                      or agent.apply_grads is None):
        raise ValueError(
            f"agent {agent.name!r} has no grads/apply_grads split: the "
            "compressed cross-pod reduce needs the explicit gradient "
            "pytree (the parameter-average fallback has nothing to "
            "quantize)")
    wire_dtype = resolve_reduce_dtype(intra_pod_dtype)
    if wire_dtype is not None and (agent.grads is None
                                   or agent.apply_grads is None):
        raise ValueError(
            f"agent {agent.name!r} has no grads/apply_grads split: the "
            "bf16 intra-pod reduce needs the explicit gradient pytree "
            "(the parameter-average fallback has nothing to cast)")
    # the cast only happens when a fast-axis reduce actually exists —
    # with every mesh axis consumed by the compressed pod leg there is
    # no intra-pod wire, so no cast and no cast-error metric
    fast_axes = tuple(ax for ax in axes if ax != compress_axis)
    cast_active = wire_dtype is not None and bool(fast_axes)
    reduce_grads = make_grad_reducer(axes, max_staleness=max_staleness,
                                     compress_axis=compress_axis,
                                     intra_pod_dtype=intra_pod_dtype,
                                     overlap=overlap)

    def sharded_learn(agent_state, replay_state, rng, age=None, ef=None):
        idx, items, is_w = replay.sample(replay_state, rng, batch_per_shard, beta)
        err_norm = jnp.zeros(())
        if agent.grads is not None and agent.apply_grads is not None:
            grads, aux = agent.grads(agent_state, items, is_w)
            if cast_active:
                # compression error this shard injects into the fast leg
                err_norm = err_norm + compress.l2_norm(jax.tree.map(
                    lambda g: g - g.astype(wire_dtype).astype(g.dtype),
                    grads))
            grads, ef = reduce_grads(grads, age, ef)
            if jax.tree.leaves(ef):
                # residual the int8 pod leg carries into the next step
                # (overlap mode also carries the stale correction — only
                # the quantizer's EF half is compression error)
                err_norm = err_norm + compress.l2_norm(
                    ef["ef"] if overlap else ef)
            agent_state, metrics, td = agent.apply_grads(agent_state, grads, aux)
        else:
            agent_state, metrics, td = agent.learn(agent_state, items, is_w)
            agent_state = agent_state._replace(
                params=_pmean_inexact(agent_state.params, axes),
                target=_pmean_inexact(agent_state.target, axes),
                opt=_pmean_inexact(agent_state.opt, axes),
            )
        replay_state = replay.update_priorities(replay_state, idx, td,
                                                lazy=lazy_writes)
        lmetrics = {"loss": metrics["loss"], "compress_error_norm": err_norm}
        return agent_state, replay_state, lmetrics, ef

    return sharded_learn


def staleness_weights(ages: jax.Array, max_staleness: int) -> jax.Array:
    """Bounded-staleness discount: weight 1/(1+age), 0 beyond the bound
    (dropped straggler)."""
    w = 1.0 / (1.0 + ages.astype(jnp.float32))
    return jnp.where(ages > max_staleness, 0.0, w)


def staleness_reduce_weights(ages: jax.Array, max_staleness: int) -> jax.Array:
    """Realized per-shard reduce weights of the bounded-staleness reduce:
    ``staleness_weights`` renormalized by their sum over the shard vector.

    Invariant (property-tested): the weights sum to exactly the gradient
    scale of a synchronous pmean — 1 — whenever at least one shard is
    within the bound, and to 0 (update skipped, params held) when every
    shard is stale."""
    w = staleness_weights(ages, max_staleness)
    return _renormalize(w, jnp.sum(w))
