"""DSE-driven runtime configuration planner (paper §V-D, Eq. 5 — Fig. 12
generalized from 1-D lane splits to full runtime configs).

``dse.solve`` answers one question: how to split a scalar lane budget
between actors and learners so collection matches consumption (Eq. 5).
The runtime grew past that axis — it now has three executor backends
(fused | sharded | async), a two-axis pod×data mesh and a
``publish_interval`` staleness knob — so the planner searches the full
configuration space

    (backend, n_pods, n_data, publish_interval, lane split)

from *measured* throughput, in the spirit of GA3C's dynamic adjustment
of actor/learner process counts (PAPERS.md):

  * profiled points come from ``BENCH_fig9.json`` (env-steps/s per
    executor backend and publish interval) and ``BENCH_fig10.json``
    (env-steps/s per shard/pod count), the json that
    ``benchmarks/run.py --emit-json`` writes — or live via
    :func:`profile`, which reuses the same sweep entry points;
  * the Eq. 5 lane split within the chosen config uses
    ``dse.solve`` on the host actor/learner curves, hull-clamped
    (``dse.interp_hull``) so no allocation claims unmeasured throughput;
  * a config measured both emulated (forced host devices in one
    process) and wall-clock (the real multi-process gang points of the
    fig10 ``--wall-clock`` arm, ``backend="wallclock"``) keeps only the
    wall-clock measurement — emulated devices time-slice one process,
    so the gang number is ground truth for the same config;
  * candidates are scored by realized env-steps/s — a single unit across
    both json files, enforced by ``benchmarks/schema.py`` — subject to
    feasibility: a config is only eligible if it was actually measured
    (the config-level "profiled hull"), its device/batch divisibility
    holds, and for async configs the publish/learn-period aliasing rule
    of ``AsyncExecutor`` admits it (a ``publish_interval`` sharing a
    factor with the learn period greater than ``max_staleness + 1``
    would permanently drop shards from the gradient reduce — the
    executor would refuse to construct, so the planner never selects
    it);
  * the winner is emitted as an executable :class:`PlannedConfig` that
    ``runtime.executors.executor_from_plan`` / ``launch.mesh.
    mesh_from_plan`` instantiate directly, and that
    ``examples/quickstart.py --plan BENCH_plan.json`` and
    ``launch/train.py --plan`` consume from disk.

This module imports neither jax nor the executors at module level — a
plan can be loaded and inspected before the forced-device-count XLA flag
is set (the same reason quickstart defers its jax import).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.runtime import dse

BACKENDS = ("fused", "sharded", "async")

FIG9_JSON = "BENCH_fig9.json"
FIG10_JSON = "BENCH_fig10.json"
SERVE_JSON = "BENCH_serve.json"
PLAN_JSON = "BENCH_plan.json"


# -- the executable plan -----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlannedConfig:
    """A full runtime configuration the planner chose — everything an
    executor constructor needs, in one serializable record.

    ``backend`` selects the executor class; ``n_pods``/``n_data`` the
    mesh (``n_data=0`` means no mesh: the fused program, also for the
    fused-async path); ``publish_interval``/``max_staleness`` the async
    knobs (0/0 on the synchronous backends); ``x_actor``/``x_learner``
    the Eq. 5 lane split (0 when no curves were provided), with
    ``n_envs`` the actor lanes rounded up to a multiple of the shard
    count so the executor's divisibility checks hold.

    ``n_replay_shards``/``samples_per_insert`` are the replay-service
    degrees of freedom (DESIGN.md §11): 0/0.0 keeps the replay in-loop
    (the fused/sharded/async programs above); ``n_replay_shards ≥ 1``
    routes experience through a ``ReplayService`` with that many shards
    behind a ``RateLimiter`` pinned to ``samples_per_insert`` — the
    explicit flow-control form of ``update_interval``'s implicit ratio
    (spi = batch_size / update_interval).
    """

    backend: str
    n_pods: int = 1
    n_data: int = 0                    # 0 = no mesh (fused program)
    publish_interval: int = 0          # 0 = synchronous
    max_staleness: int = 0
    compress_pod_reduce: bool = False
    overlap_pod_reduce: bool = False   # double-buffered compressed pod leg
    n_envs: int = 8
    update_interval: int = 1
    x_actor: int = 0                   # Eq. 5 lanes; 0 = not lane-solved
    x_learner: int = 0
    n_replay_shards: int = 0           # 0 = in-loop replay (no service)
    samples_per_insert: float = 0.0    # 0 = implicit (update_interval)
    predicted_env_steps_per_s: float = 0.0
    source: str = "unspecified"

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend={self.backend!r}: "
                             f"expected one of {BACKENDS}")
        if self.backend == "async" and self.publish_interval < 1:
            raise ValueError("async plan needs publish_interval ≥ 1")
        if self.backend != "async" and self.publish_interval:
            raise ValueError(f"backend={self.backend!r} is synchronous — "
                             "publish_interval must be 0")
        if self.backend == "sharded" and self.n_data < 1:
            raise ValueError("sharded plan needs n_data ≥ 1 (a mesh)")
        if self.backend == "fused" and self.n_data:
            raise ValueError("fused plan must have n_data=0 (no mesh)")
        if self.compress_pod_reduce and self.n_pods < 2:
            raise ValueError("compress_pod_reduce needs n_pods ≥ 2 (the "
                             "compressed leg crosses the pod axis)")
        if self.overlap_pod_reduce and not self.compress_pod_reduce:
            raise ValueError("overlap_pod_reduce needs compress_pod_reduce "
                             "(the double buffer defers the compressed "
                             "cross-pod leg — runtime/learner.py)")
        if self.n_shards > 1 and self.n_envs % self.n_shards:
            raise ValueError(f"n_envs={self.n_envs} not divisible by "
                             f"{self.n_shards} shards")
        if self.n_replay_shards < 0:
            raise ValueError("n_replay_shards must be ≥ 0 (0 = in-loop "
                             "replay, no service)")
        if self.samples_per_insert < 0:
            raise ValueError("samples_per_insert must be ≥ 0 (0 = no "
                             "rate limit)")
        if self.samples_per_insert and not self.n_replay_shards:
            raise ValueError("samples_per_insert needs a replay service "
                             "(n_replay_shards ≥ 1) to enforce it")

    @property
    def n_shards(self) -> int:
        """Mesh cells (1 when the plan runs the fused program)."""
        return max(1, self.n_pods) * max(1, self.n_data)

    @property
    def n_devices(self) -> int:
        """Devices the plan needs (the forced-host-device count)."""
        return self.n_shards if self.n_data else 1

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "PlannedConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown PlannedConfig fields: {sorted(unknown)}")
        return cls(**d)

    def describe(self) -> str:
        mesh = ("no mesh" if not self.n_data
                else f"{self.n_pods}×{self.n_data} pod×data mesh"
                if self.n_pods > 1 else f"{self.n_data}-shard data mesh")
        knobs = (f", publish every {self.publish_interval}, "
                 f"max staleness {self.max_staleness}"
                 if self.backend == "async" else "")
        comp = ", int8-EF cross-pod reduce" if self.compress_pod_reduce else ""
        if self.overlap_pod_reduce:
            comp += " (overlapped)"
        if self.n_replay_shards:
            comp += (f", replay service ({self.n_replay_shards} shard"
                     f"{'s' if self.n_replay_shards > 1 else ''}, "
                     f"spi {self.samples_per_insert:g})")
        return (f"{self.backend} executor ({mesh}{knobs}{comp}), "
                f"{self.n_envs} envs, update_interval "
                f"{self.update_interval}, predicted "
                f"{self.predicted_env_steps_per_s:,.0f} env-steps/s "
                f"[{self.source}]")


# -- profiled candidates -----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One measured runtime configuration (a point of the config-level
    profiled hull — the planner only ever selects measured configs).

    ``wallclock`` marks a point measured on a real multi-process gang
    (launch/multiprocess.py) rather than emulated host devices in one
    process; ``update_interval`` is the ratio the point was measured at
    (``None`` = the sweep default, matching any requested ratio —
    legacy emulated points don't carry the field)."""

    backend: str
    n_pods: int
    n_data: int
    publish_interval: int
    compress: bool
    n_envs: int
    env_steps_per_s: float
    source: str
    overlap: bool = False
    wallclock: bool = False
    update_interval: Optional[int] = None

    @property
    def config_key(self) -> Tuple:
        """The runtime configuration a point measured — everything but
        the measurement itself and how it was measured.  Two points with
        one config_key are the same config measured two ways (emulated
        vs wall-clock), and the planner keeps the wall-clock one."""
        return (self.backend, self.n_pods, self.n_data,
                self.publish_interval, self.compress, self.overlap,
                self.n_envs, self.update_interval)


def candidates_from_points(fig9_points: Iterable[dict] = (),
                           fig10_points: Iterable[dict] = (),
                           default_n_envs: int = 16) -> List[Candidate]:
    """Adapt BENCH json points to planner candidates.

    fig9 points carry the backend axis (fused + async publish-interval
    sweep, unsharded); fig10 points carry the shard/pod axis (sharded
    1-D counts and pod×data cells, with and without the compressed
    reduce).  Unknown backends are skipped, not errors — the json may
    come from a newer benchmark sweep.
    """
    out: List[Candidate] = []
    for p in fig9_points:
        backend = p.get("backend")
        shards = int(p.get("shards", 0))
        if backend == "fused":
            out.append(Candidate("fused", 1, 0, 0, False,
                                 int(p.get("n_envs", default_n_envs)),
                                 float(p["env_steps_per_s"]), "fig9"))
        elif backend == "async":
            out.append(Candidate("async", max(1, int(p.get("pods", 1))),
                                 shards, int(p["publish_interval"]), False,
                                 int(p.get("n_envs", default_n_envs)),
                                 float(p["env_steps_per_s"]), "fig9"))
    for p in fig10_points:
        backend = p.get("backend")
        if backend == "sharded":
            out.append(Candidate("sharded", 1, int(p["shards"]), 0, False,
                                 int(p.get("n_envs", default_n_envs)),
                                 float(p["env_steps_per_s"]), "fig10"))
        elif backend == "sharded_pod_data":
            out.append(Candidate("sharded", int(p["pods"]), int(p["shards"]),
                                 0, bool(p.get("compressed", False)),
                                 int(p.get("n_envs", default_n_envs)),
                                 float(p["env_steps_per_s"]), "fig10"))
        elif backend == "wallclock":
            # real multi-process gang measurement (fig10 --wall-clock
            # arm): the executable config drops the process count — a
            # launch-time detail — but keeps the reduce shape, and the
            # measured update_interval rides along so the ratio filter
            # in `feasible` never scores it against a different workload
            pods = max(1, int(p.get("pods", 1)))
            shards = int(p.get("shards", 1))
            fused = pods == 1 and shards <= 1
            publish = int(p.get("publish_interval", 0))
            backend_name = ("async" if publish
                            else "fused" if fused else "sharded")
            out.append(Candidate(
                backend_name, pods,
                0 if fused else shards,
                publish,
                bool(p.get("compressed", False)),
                int(p.get("n_envs", default_n_envs)),
                float(p["env_steps_per_s"]), "fig10-wallclock",
                overlap=bool(p.get("overlapped", False)),
                wallclock=True,
                update_interval=(int(p["update_interval"])
                                 if "update_interval" in p else None)))
    return out


# -- feasibility -------------------------------------------------------------


def learn_period(update_interval: int, env_steps_per_iter: int) -> int:
    """Iterations between learn events — the same arithmetic as
    ``RatioSchedule.from_config`` (kept dependency-free here so a plan
    can be checked before jax is importable; parity is asserted in
    tests/test_planner.py)."""
    u = max(1, update_interval)
    e = max(1, env_steps_per_iter)
    return max(1, round(u / e)) if u >= e else 1


def aliasing_ok(publish_interval: int, period: int, n_shards: int,
                max_staleness: int) -> bool:
    """The ``AsyncExecutor``/``ShardedExecutor`` construction rule: shard
    d's staggered publish clock has fixed phase d mod P, so when P shares
    a factor g with the learn period, some shard's age exceeds the bound
    at *every* learn tick once min(g, n_shards) > max_staleness + 1 —
    that shard would be permanently dropped from the gradient reduce.
    The planner must never select a config the executor would refuse."""
    if publish_interval < 1 or n_shards <= 1:
        return True                      # no cross-shard reduce to drop from
    g = math.gcd(publish_interval, period)
    return min(g, n_shards) <= max_staleness + 1


def _round_up(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


def _resolve_n_envs(cand: Candidate) -> int:
    """Actor lanes the plan will run: the env count the point was
    *measured* at (so the executable config stays on the measured hull
    and realized-vs-predicted is a like-for-like comparison), rounded up
    to the shard count so the executor's divisibility check holds."""
    shards = max(1, cand.n_pods) * max(1, cand.n_data)
    return _round_up(max(cand.n_envs, shards), shards)


def feasible(cand: Candidate, *, update_interval: int, max_staleness: int,
             max_devices: Optional[int] = None, batch_size: int = 64) -> bool:
    """Whether a measured candidate can actually be instantiated with
    the requested knobs (device budget, batch divisibility, async
    publish/learn-period aliasing)."""
    shards = max(1, cand.n_pods) * max(1, cand.n_data)
    devices = shards if cand.n_data else 1
    if max_devices is not None and devices > max_devices:
        return False
    if batch_size % shards:
        return False
    if (cand.update_interval is not None
            and cand.update_interval != update_interval):
        # a point measured at a different collection/consumption ratio
        # is a different workload — its env-steps/s is not comparable
        # (legacy points without the field match any requested ratio)
        return False
    if cand.backend == "async":
        if cand.publish_interval < 1:
            return False
        period = learn_period(update_interval, _resolve_n_envs(cand))
        if not aliasing_ok(cand.publish_interval, period, shards,
                           max_staleness):
            return False
    return True


def select_replay_service(serve_points: Sequence[dict], *,
                          insert_rate: float, update_interval: int,
                          batch_size: int) -> Tuple[int, float]:
    """Choose the replay-service shape from measured ``figure="serve"``
    points (benchmarks/fig_serve.py): the service must sustain the
    chosen executor's insert rate AND the target sample rate it implies

        target_spi  = batch_size / update_interval
        sample_rate = target_spi · insert_rate

    Among configs whose *measured* inserts_per_s and samples_per_s both
    clear those requirements (with batch divisibility for stratified
    sampling), the fewest shards win — less cross-shard composition for
    the same sustained flow — tie-broken by headroom (the smaller of the
    two measured/required ratios).  Returns ``(n_replay_shards,
    samples_per_insert)``; ``(0, 0.0)`` when no measured config can
    sustain the flow — the plan keeps the replay in-loop rather than
    promising a service that would rate-limit the executor below its
    measured throughput.
    """
    target_spi = batch_size / max(1, update_interval)
    need_samples = target_spi * insert_rate
    eligible = []
    for p in serve_points:
        shards = int(p.get("n_shards", 1))
        if shards < 1 or batch_size % shards:
            continue
        ins = float(p.get("inserts_per_s", 0.0))
        smp = float(p.get("samples_per_s", 0.0))
        if ins >= insert_rate and smp >= need_samples:
            headroom = min(ins / max(insert_rate, 1e-9),
                           smp / max(need_samples, 1e-9))
            eligible.append((shards, -headroom, p))
    if not eligible:
        return 0, 0.0
    shards, _, _ = min(eligible)
    return shards, target_spi


# -- the planner -------------------------------------------------------------


def solve_lanes(actor_curve: Dict[int, float],
                learner_curve: Dict[int, float],
                total: int, update_interval: float = 1.0) -> dse.DSEResult:
    """Eq. 5 lane split — delegates to ``dse.solve`` so the planner is
    backward-compatible with the 1-D DSE on identical curves (asserted
    in tests/test_planner.py)."""
    return dse.solve(actor_curve, learner_curve, total, update_interval)


def solve_backend_curves(
    backend_curves: Dict[str, Tuple[Dict[int, float], Dict[int, float]]],
    total: int,
    update_interval: float = 1.0,
) -> Tuple[str, dse.DSEResult]:
    """Curve-level backend selection: run Eq. 5 per backend's
    (actor_curve, learner_curve) pair and pick the backend whose solution
    best matches the ratio, tie-broken by measured collection throughput.

    This is the *curve-space* companion to :func:`plan`, for when only
    profiled curves exist (offline what-if analysis, fig12-style
    studies) — not the production selection path, and deliberately
    ordered differently: ``plan`` ranks whole measured configs by
    realized env-steps/s because each point already *is* the full
    workload, while here ratio feasibility must come first — each
    backend's Eq. 5 fit differs, and ranking curves by raw magnitude
    would just reward whichever curve carries the larger unit.

    Unit contract: actor curves must share one unit across backends
    (env-steps/s — what the BENCH schema enforces), and each backend's
    *pair* must be internally consistent (``update_interval × f_l`` in
    ``f_a``'s unit — Eq. 5 is meaningless otherwise).  What IS
    guaranteed unit-free: jointly rescaling one backend's pair leaves
    the ranking unchanged (the residual is divided by ``f_a``), and
    exact-fit ties break on the *relative* score
    (``dse.relative_score``) rather than raw magnitude — the raw
    ``-(fa + fl)`` sum this replaces let whichever backend's learner
    curve carried the larger unit win every tie.
    """
    if not backend_curves:
        raise ValueError("backend_curves is empty — nothing to select from")
    best = None
    for name, (ac, lc) in sorted(backend_curves.items()):
        res = dse.solve(ac, lc, total, update_interval)
        rel = dse.relative_score(res, ac, lc)
        # ratio feasibility first; among comparable fits the measured-
        # faster backend (absolute env-steps/s) wins; the relative score
        # breaks exact throughput ties unit-free
        key = (round(res.ratio_error, 6), -res.actor_throughput, rel)
        if best is None or key < best[0]:
            best = (key, name, res)
    return best[1], best[2]


def plan(
    fig9_points: Sequence[dict] = (),
    fig10_points: Sequence[dict] = (),
    *,
    serve_points: Sequence[dict] = (),
    actor_curve: Optional[Dict[int, float]] = None,
    learner_curve: Optional[Dict[int, float]] = None,
    total_lanes: int = 8,
    update_interval: int = 1,
    max_staleness: int = 1,
    max_devices: Optional[int] = None,
    batch_size: int = 64,
    source: str = "bench-json",
) -> PlannedConfig:
    """Choose the full runtime config from measured throughput.

    Scoring is realized env-steps/s over the *feasible measured*
    candidates (the config-level profiled hull) — :func:`profile` and
    ``benchmarks/run.py --emit-json`` measure every point at one global
    env count per sweep mode, so the comparison is the same workload
    under different runtime configs.  The winner keeps the env count it
    was measured at (only rounded up for shard divisibility), so the
    emitted config's throughput really was observed and the
    predicted-vs-realized gap in BENCH_plan.json measures planner error,
    not an env-count change.  The Eq. 5 lane split is solved alongside
    when actor/learner curves are provided (``x_actor``/``x_learner``
    report the host-level split; 0 when no curves) and decides ``n_envs``
    only on the curve-only fallback, where nothing was measured.  Ties
    prefer fewer devices, then a smaller publish_interval (less
    staleness for the same speed).

    When ``serve_points`` (measured replay-service throughput,
    benchmarks/fig_serve.py) are provided, a second selection stage
    picks ``n_replay_shards``/``samples_per_insert`` via
    :func:`select_replay_service` — the service shape that sustains the
    winning executor's measured insert rate at the implied target ratio,
    or 0/0.0 (in-loop replay) when none can.
    """
    lanes = None
    if actor_curve and learner_curve:
        lanes = solve_lanes(actor_curve, learner_curve, total_lanes,
                            update_interval)
    x_actor = lanes.x_actor if lanes else 0
    x_learner = lanes.x_learner if lanes else 0

    cands = candidates_from_points(fig9_points, fig10_points)
    ok = [c for c in cands
          if feasible(c, update_interval=update_interval,
                      max_staleness=max_staleness, max_devices=max_devices,
                      batch_size=batch_size)]
    # a config measured both emulated and on a real gang keeps only the
    # wall-clock measurement: emulated host devices time-slice one
    # process, so the gang number is the ground truth for the same
    # configuration (fig10 --wall-clock arm, DESIGN.md §10).  Dedup runs
    # *after* the ratio filter and keys on the config minus
    # update_interval: every survivor is either the requested ratio or a
    # legacy point with no recorded ratio, so a wall-clock survivor
    # shadows exactly the emulated measurement of its own config.
    by_config: Dict[Tuple, Candidate] = {}
    for c in ok:
        key = c.config_key[:-1]
        held = by_config.get(key)
        if held is None or (c.wallclock and not held.wallclock):
            by_config[key] = c
    ok = list(by_config.values())
    if not ok:
        if lanes:
            # curve-only fallback: the fused single-program config at the
            # Eq. 5 lane split, predicted from the actor curve
            return PlannedConfig(
                backend="fused", n_envs=max(1, x_actor),
                update_interval=update_interval, x_actor=x_actor,
                x_learner=x_learner,
                predicted_env_steps_per_s=lanes.actor_throughput,
                source=f"{source}:curves-only")
        raise ValueError(
            "no feasible measured candidate: every BENCH point was filtered "
            f"out (device budget {max_devices}, batch_size {batch_size}, "
            f"max_staleness {max_staleness}) and no lane curves were given "
            "to fall back on — re-run `python -m benchmarks.run "
            "--emit-json` or relax the constraints")

    best = min(ok, key=lambda c: (-c.env_steps_per_s,
                                  max(1, c.n_pods) * max(1, c.n_data),
                                  c.publish_interval))
    n_replay_shards, spi = (
        select_replay_service(serve_points, insert_rate=best.env_steps_per_s,
                              update_interval=update_interval,
                              batch_size=batch_size)
        if serve_points else (0, 0.0))
    return PlannedConfig(
        backend=best.backend,
        n_pods=best.n_pods,
        n_data=best.n_data,
        publish_interval=best.publish_interval,
        # the overlapped reduce is incompatible with bounded staleness
        # (runtime/learner.py) — an overlapped winner pins it to 0
        max_staleness=(max_staleness if best.backend == "async"
                       and best.n_data and not best.overlap else 0),
        compress_pod_reduce=best.compress,
        overlap_pod_reduce=best.overlap,
        n_envs=_resolve_n_envs(best),
        update_interval=update_interval,
        x_actor=x_actor,
        x_learner=x_learner,
        n_replay_shards=n_replay_shards,
        samples_per_insert=spi,
        predicted_env_steps_per_s=best.env_steps_per_s,
        source=f"{source}:{best.source}",
    )


# -- json I/O ----------------------------------------------------------------


def _load_points(path: str) -> List[dict]:
    with open(path) as f:
        payload = json.load(f)
    return list(payload.get("points", ()))


# the measurement-side fields of every figure (mirrors the union of
# benchmarks/schema.py metrics + dispersion records; kept inline because
# ``benchmarks`` is not importable from ``src``) — everything else on a
# point is identity
_MEASUREMENT_FIELDS = frozenset({
    "env_steps_per_s", "inserts_per_s", "samples_per_s",
    "replay_ops_per_s", "speedup_vs_sync", "repeats", "rel_spread",
    "realized_spi",
    # actor-serve figure (benchmarks/fig_actor.py) measurements
    "requests_per_s", "p50_ms", "p99_ms",
    "p99_before_swap_ms", "p99_after_swap_ms", "param_swaps",
})


def _point_identity(point: dict) -> Tuple:
    return tuple(sorted(
        (k, repr(v)) for k, v in point.items()
        if k not in _MEASUREMENT_FIELDS))


def merge_bench_points(bench_dir: str) -> Dict[str, List[dict]]:
    """Walk a directory tree of BENCH artifacts — several CI runs, a
    cron sweep, wall-clock arms dropped in subdirectories — and merge
    the points per figure.  Two points with the same identity fields are
    the same config measured twice: the one from the newest file (mtime)
    wins, so a stale artifact can never shadow a fresh measurement of
    the same config.  Plan envelopes (no ``points`` list) are skipped;
    unreadable json is tolerated (a partially written artifact must not
    kill planning over the rest of the directory)."""
    by_figure: Dict[str, Dict[Tuple, Tuple[float, dict]]] = {}
    for root, _dirs, files in sorted(os.walk(bench_dir)):
        for name in sorted(files):
            if not (name.startswith("BENCH_") and name.endswith(".json")):
                continue
            path = os.path.join(root, name)
            try:
                with open(path) as f:
                    payload = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            figure = payload.get("figure") if isinstance(payload, dict) \
                else None
            points = payload.get("points") if isinstance(payload, dict) \
                else None
            if not figure or not isinstance(points, list):
                continue
            mtime = os.path.getmtime(path)
            held = by_figure.setdefault(figure, {})
            for p in points:
                if not isinstance(p, dict):
                    continue
                key = _point_identity(p)
                if key not in held or mtime > held[key][0]:
                    held[key] = (mtime, p)
    return {figure: [p for _, p in held.values()]
            for figure, held in by_figure.items()}


def plan_from_json(bench_dir: str, **kwargs) -> PlannedConfig:
    """Plan from a *directory* of BENCH artifacts: every
    ``BENCH_*.json`` under ``bench_dir`` (recursively) is merged per
    figure with :func:`merge_bench_points` — identical configs keep the
    freshest measurement — so the planner sees the union of however many
    ``benchmarks/run.py --emit-json`` runs, wall-clock arms and service
    sweeps accumulated, not just one run's files.  Missing figures are
    tolerated; serve points (figure="serve") feed the replay-service
    selection stage automatically."""
    merged = merge_bench_points(bench_dir)
    fig9 = merged.get("fig9", [])
    fig10 = merged.get("fig10", [])
    if not fig9 and not fig10:
        raise FileNotFoundError(
            f"no fig9/fig10 BENCH points found under {bench_dir!r} — "
            "run `python -m benchmarks.run --emit-json DIR` first")
    kwargs.setdefault("source", f"json:{bench_dir}")
    kwargs.setdefault("serve_points", merged.get("serve", []))
    return plan(fig9, fig10, **kwargs)


def save_plan(pc: PlannedConfig, path: str, *,
              realized_env_steps_per_s: Optional[float] = None,
              curves: Optional[dict] = None) -> dict:
    """Write BENCH_plan.json: the chosen config plus predicted vs
    realized throughput (the autotuner's output becomes the next CI
    run's machine-readable trajectory)."""
    payload = {
        "figure": "plan",
        "metric": "env_steps_per_s",
        "config": pc.to_dict(),
        "predicted_env_steps_per_s": pc.predicted_env_steps_per_s,
        "realized_env_steps_per_s": realized_env_steps_per_s,
    }
    if curves:
        payload["curves"] = curves
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return payload


def load_plan(path: str) -> PlannedConfig:
    """Read a plan back — accepts the BENCH_plan.json envelope or a bare
    PlannedConfig dict, so hand-written plans work too."""
    with open(path) as f:
        payload = json.load(f)
    cfg = payload.get("config", payload)
    return PlannedConfig.from_dict(cfg)


# -- live profiling ----------------------------------------------------------


def profile(smoke: bool = False) -> dict:
    """Measure the planner's inputs live on this host, reusing the
    benchmark sweep entry points (``benchmarks`` must be importable —
    run from the repo root): the fig9 executor-backend points, the fig10
    shard/pod points (forced-device subprocesses), and the fig12-style
    actor/learner lane curves for the Eq. 5 split.  ``smoke`` shrinks
    every sweep to the CI-budget sizes used by ``benchmarks/run.py
    --smoke``."""
    try:
        from benchmarks import fig9_fanout, fig10_scalability, fig12_dse
    except ImportError as e:
        raise ImportError(
            "planner.profile() reuses the benchmark sweeps — run with the "
            "repo root on sys.path (e.g. `PYTHONPATH=src python -m "
            "benchmarks.run --emit-json DIR` profiles and plans in one "
            "go)") from e

    # one global env count per mode, across BOTH sweeps: the planner
    # ranks fig9 and fig10 points against each other, which is only a
    # like-for-like comparison when every point runs the same workload
    if smoke:
        fig9_pts = fig9_fanout.executor_backend_points(
            publish_intervals=(1, 2), n_envs=8, iters=40)
        fig10_pts = fig10_scalability.shard_pod_points(
            shard_counts=(1, 2), pod_specs=((2, 1, False),),
            n_envs=8, iters=40)
        lanes = (1, 2, 4)
    else:
        fig9_pts = fig9_fanout.executor_backend_points(n_envs=16)
        fig10_pts = fig10_scalability.shard_pod_points(n_envs=16)
        lanes = (1, 2, 4, 8)
    actor_curve = dse.profile_curve(fig12_dse.actor_throughput, list(lanes))
    learner_curve = dse.profile_curve(fig12_dse.learner_throughput,
                                      list(lanes))
    return {
        "fig9_points": fig9_pts,
        "fig10_points": fig10_pts,
        "actor_curve": actor_curve,
        "learner_curve": learner_curve,
    }
