"""Design-space exploration (paper §V-D, Eq. 5 + Fig. 12).

Given profiled throughput curves f_a(x) (data collection vs parallelism)
and f_l(x) (data consumption vs parallelism) and a total resource budget
M, pick (x_a, x_l) with x_a + x_l ≤ M such that

    f_a(x_a) ≈ update_interval × f_l(x_l)

by the paper's exhaustive O(M²) search.  On this host the resource axis
is "parallel env/learner lanes" (vmap width); on a pod it is the
actor/learner device-group split — same equation, profiled the same way.

This module owns the 1-D lane split only.  The full-configuration
planner (executor backend × pod/data mesh × publish_interval) lives in
``runtime/planner.py`` and builds on the primitives exported here:
``hull``/``interp_hull`` (never claim throughput outside the profiled
range) and ``relative_score`` (unit-free comparison of Eq. 5 solutions
across curves that were measured in different units — e.g. env-steps/s
vs batch-items/s loaded from different BENCH json files).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Tuple


@dataclasses.dataclass
class DSEResult:
    x_actor: int
    x_learner: int
    actor_throughput: float
    learner_throughput: float
    ratio: float                 # realized collection/consumption ratio
    target_ratio: float
    ratio_error: float = 0.0     # |f_a - U·f_l| / f_a of the chosen point


def profile_curve(run_at: Callable[[int], float], xs: List[int]) -> Dict[int, float]:
    """run_at(x) → measured throughput (items/s) at parallelism x."""
    return {x: run_at(x) for x in xs}


def hull(curve: Dict[int, float]) -> Tuple[int, int]:
    """The profiled hull ``[min x, max x]`` of a throughput curve."""
    if not curve:
        raise ValueError("empty curve has no profiled hull")
    return min(curve), max(curve)


def interp_hull(curve: Dict[int, float], x: float) -> float:
    """Hull-clamped linear interpolation: ``x`` outside the profiled
    range reads the nearest hull edge instead of extrapolating — the
    "never claim throughput that was never measured" rule.  ``solve``
    reads every candidate allocation through this (its search ranges are
    clamped to the hull as well, because an allocation at an unprofiled
    parallelism level would tie the hull edge on ratio error and win the
    tie-break order dependent — the old flat-extrapolation behavior this
    replaces)."""
    lo, hi = hull(curve)
    x = min(max(x, lo), hi)
    if x in curve:
        return curve[x]
    xs = sorted(curve)
    below = max(v for v in xs if v <= x)
    above = min(v for v in xs if v >= x)
    if below == above:
        return curve[below]
    w = (x - below) / (above - below)
    return curve[below] * (1 - w) + curve[above] * w


def ratio_error(fa: float, fl: float, update_interval: float) -> float:
    """Eq. 5 residual |f_a − U·f_l| / f_a of an allocation."""
    return abs(fa - update_interval * fl) / max(fa, 1e-9)


def relative_score(res: DSEResult,
                   actor_curve: Dict[int, float],
                   learner_curve: Dict[int, float]) -> Tuple[float, float]:
    """Unit-free comparison key for an Eq. 5 solution: ``(ratio_error,
    -(f_a/max f_a + f_l/max f_l))`` — smaller is better.

    Normalizing each throughput by its own curve's maximum makes the
    tie-break meaningful when the two curves carry different units
    (env-steps/s vs batch-items/s — always the case for curves loaded
    from BENCH json), and makes scores comparable *across* solves on
    different curve pairs: the planner ranks candidate backends by this
    key, where the raw ``-(fa + fl)`` sum would be dominated by
    whichever backend's json happened to use the larger unit.
    """
    ma = max(actor_curve.values())
    ml = max(learner_curve.values())
    return (res.ratio_error,
            -(res.actor_throughput / max(ma, 1e-9)
              + res.learner_throughput / max(ml, 1e-9)))


def solve(
    actor_curve: Dict[int, float],
    learner_curve: Dict[int, float],
    total: int,
    update_interval: float = 1.0,
) -> DSEResult:
    """Exhaustive O(M²) search of Eq. 5 (paper §VI-G), clamped to the
    profiled hull: candidate allocations are restricted to parallelism
    levels inside ``[min profiled x, max profiled x]`` of each curve, so
    the solver never returns a lane count whose throughput was never
    measured (flat extrapolation used to let such points tie the ratio
    error of the hull edge and be selected by iteration order).

    Ties on ratio error are broken by *relative* combined throughput
    (``relative_score``): each curve's throughput is normalized by its
    own maximum before summing, so the tie-break is invariant to the
    units either curve was measured in.  (The raw ``-(fa + fl)`` sum it
    replaces compared env-steps/s against batch-items/s head-on: with
    curves loaded from json the larger-unit curve decided every tie.)

    Raises ``ValueError`` for an infeasible budget or empty curves — with
    ``total < 2`` the (x_a ≥ 1, x_l ≥ 1) search space is empty and there
    is no allocation to return, and a budget too small to reach both
    curves' minimum profiled parallelism has no measured allocation
    either.
    """
    if total < 2:
        raise ValueError(
            f"total={total}: the DSE needs a resource budget of at least 2 "
            "(one actor lane + one learner lane, Eq. 5 requires x_a ≥ 1 "
            "and x_l ≥ 1)")
    if not actor_curve or not learner_curve:
        raise ValueError("actor_curve and learner_curve must be non-empty "
                         "profiled throughput curves")
    a_lo, a_hi = hull(actor_curve)
    l_lo, l_hi = hull(learner_curve)
    ma = max(actor_curve.values())
    ml = max(learner_curve.values())
    best = None
    for xa in range(max(1, a_lo), min(total - 1, a_hi) + 1):
        for xl in range(max(1, l_lo), min(total - xa, l_hi) + 1):
            fa = interp_hull(actor_curve, xa)
            fl = interp_hull(learner_curve, xl)
            err = ratio_error(fa, fl, update_interval)
            # match ratio, then maximize *relative* work (unit-free)
            score = (err, -(fa / max(ma, 1e-9) + fl / max(ml, 1e-9)))
            if best is None or score < best[0]:
                best = (score, DSEResult(xa, xl, fa, fl,
                                         fa / max(fl, 1e-9), update_interval,
                                         ratio_error=err))
    if best is None:
        raise ValueError(
            f"total={total} cannot reach the profiled hull: the smallest "
            f"measured allocation is x_a={a_lo} + x_l={l_lo} = "
            f"{a_lo + l_lo} lanes — profile smaller parallelism levels or "
            "raise the budget (allocating below the profiled range would "
            "claim throughput that was never measured)")
    return best[1]


def measure_throughput(fn: Callable[[], None], items_per_call: int,
                       warmup: int = 2, iters: int = 5) -> float:
    """Wall-clock items/s of a jitted callable (block_until_ready inside)."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    dt = time.perf_counter() - t0
    return items_per_call * iters / dt
