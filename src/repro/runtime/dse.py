"""Design-space exploration (paper §V-D, Eq. 5 + Fig. 12).

Given profiled throughput curves f_a(x) (data collection vs parallelism)
and f_l(x) (data consumption vs parallelism) and a total resource budget
M, pick (x_a, x_l) with x_a + x_l ≤ M such that

    f_a(x_a) ≈ update_interval × f_l(x_l)

by the paper's exhaustive O(M²) search.  On this host the resource axis
is "parallel env/learner lanes" (vmap width); on a pod it is the
actor/learner device-group split — same equation, profiled the same way.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Tuple

import numpy as np


@dataclasses.dataclass
class DSEResult:
    x_actor: int
    x_learner: int
    actor_throughput: float
    learner_throughput: float
    ratio: float                 # realized collection/consumption ratio
    target_ratio: float


def profile_curve(run_at: Callable[[int], float], xs: List[int]) -> Dict[int, float]:
    """run_at(x) → measured throughput (items/s) at parallelism x."""
    return {x: run_at(x) for x in xs}


def _interp(curve: Dict[int, float], x: int) -> float:
    """Linear interpolation *within* the profiled hull.

    Callers must keep ``x`` inside ``[min(curve), max(curve)]`` —
    ``solve`` clamps its search to the hull, because extrapolating flat
    beyond the profiled range claims throughput that was never measured
    (a lane allocation at an unprofiled parallelism level would tie with
    the hull edge on ratio error and win the ``-(fa + fl)`` tie-break
    order dependent — the old behavior this replaces)."""
    xs = sorted(curve)
    if x in curve:
        return curve[x]
    lo = max([v for v in xs if v <= x], default=xs[0])
    hi = min([v for v in xs if v >= x], default=xs[-1])
    if lo == hi:
        return curve[lo]
    w = (x - lo) / (hi - lo)
    return curve[lo] * (1 - w) + curve[hi] * w


def solve(
    actor_curve: Dict[int, float],
    learner_curve: Dict[int, float],
    total: int,
    update_interval: float = 1.0,
) -> DSEResult:
    """Exhaustive O(M²) search of Eq. 5 (paper §VI-G), clamped to the
    profiled hull: candidate allocations are restricted to parallelism
    levels inside ``[min profiled x, max profiled x]`` of each curve, so
    the solver never returns a lane count whose throughput was never
    measured (flat extrapolation used to let such points tie the ratio
    error of the hull edge and be selected by iteration order).

    Raises ``ValueError`` for an infeasible budget or empty curves — with
    ``total < 2`` the (x_a ≥ 1, x_l ≥ 1) search space is empty and there
    is no allocation to return, and a budget too small to reach both
    curves' minimum profiled parallelism has no measured allocation
    either.
    """
    if total < 2:
        raise ValueError(
            f"total={total}: the DSE needs a resource budget of at least 2 "
            "(one actor lane + one learner lane, Eq. 5 requires x_a ≥ 1 "
            "and x_l ≥ 1)")
    if not actor_curve or not learner_curve:
        raise ValueError("actor_curve and learner_curve must be non-empty "
                         "profiled throughput curves")
    a_lo, a_hi = min(actor_curve), max(actor_curve)
    l_lo, l_hi = min(learner_curve), max(learner_curve)
    best = None
    for xa in range(max(1, a_lo), min(total - 1, a_hi) + 1):
        for xl in range(max(1, l_lo), min(total - xa, l_hi) + 1):
            fa = _interp(actor_curve, xa)
            fl = _interp(learner_curve, xl)
            err = abs(fa - update_interval * fl) / max(fa, 1e-9)
            score = (err, -(fa + fl))      # match ratio, then maximize work
            if best is None or score < best[0]:
                best = (score, DSEResult(xa, xl, fa, fl,
                                         fa / max(fl, 1e-9), update_interval))
    if best is None:
        raise ValueError(
            f"total={total} cannot reach the profiled hull: the smallest "
            f"measured allocation is x_a={a_lo} + x_l={l_lo} = "
            f"{a_lo + l_lo} lanes — profile smaller parallelism levels or "
            "raise the budget (allocating below the profiled range would "
            "claim throughput that was never measured)")
    return best[1]


def measure_throughput(fn: Callable[[], None], items_per_call: int,
                       warmup: int = 2, iters: int = 5) -> float:
    """Wall-clock items/s of a jitted callable (block_until_ready inside)."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    dt = time.perf_counter() - t0
    return items_per_call * iters / dt
