"""Design-space exploration (paper §V-D, Eq. 5 + Fig. 12).

Given profiled throughput curves f_a(x) (data collection vs parallelism)
and f_l(x) (data consumption vs parallelism) and a total resource budget
M, pick (x_a, x_l) with x_a + x_l ≤ M such that

    f_a(x_a) ≈ update_interval × f_l(x_l)

by the paper's exhaustive O(M²) search.  On this host the resource axis
is "parallel env/learner lanes" (vmap width); on a pod it is the
actor/learner device-group split — same equation, profiled the same way.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Tuple

import numpy as np


@dataclasses.dataclass
class DSEResult:
    x_actor: int
    x_learner: int
    actor_throughput: float
    learner_throughput: float
    ratio: float                 # realized collection/consumption ratio
    target_ratio: float


def profile_curve(run_at: Callable[[int], float], xs: List[int]) -> Dict[int, float]:
    """run_at(x) → measured throughput (items/s) at parallelism x."""
    return {x: run_at(x) for x in xs}


def _interp(curve: Dict[int, float], x: int) -> float:
    xs = sorted(curve)
    if x in curve:
        return curve[x]
    lo = max([v for v in xs if v <= x], default=xs[0])
    hi = min([v for v in xs if v >= x], default=xs[-1])
    if lo == hi:
        return curve[lo]
    w = (x - lo) / (hi - lo)
    return curve[lo] * (1 - w) + curve[hi] * w


def solve(
    actor_curve: Dict[int, float],
    learner_curve: Dict[int, float],
    total: int,
    update_interval: float = 1.0,
) -> DSEResult:
    """Exhaustive O(M²) search of Eq. 5 (paper §VI-G).

    Raises ``ValueError`` for an infeasible budget or empty curves — with
    ``total < 2`` the (x_a ≥ 1, x_l ≥ 1) search space is empty and there
    is no allocation to return.
    """
    if total < 2:
        raise ValueError(
            f"total={total}: the DSE needs a resource budget of at least 2 "
            "(one actor lane + one learner lane, Eq. 5 requires x_a ≥ 1 "
            "and x_l ≥ 1)")
    if not actor_curve or not learner_curve:
        raise ValueError("actor_curve and learner_curve must be non-empty "
                         "profiled throughput curves")
    best = None
    for xa in range(1, total):
        for xl in range(1, total - xa + 1):
            fa = _interp(actor_curve, xa)
            fl = _interp(learner_curve, xl)
            err = abs(fa - update_interval * fl) / max(fa, 1e-9)
            score = (err, -(fa + fl))      # match ratio, then maximize work
            if best is None or score < best[0]:
                best = (score, DSEResult(xa, xl, fa, fl,
                                         fa / max(fl, 1e-9), update_interval))
    return best[1]


def measure_throughput(fn: Callable[[], None], items_per_call: int,
                       warmup: int = 2, iters: int = 5) -> float:
    """Wall-clock items/s of a jitted callable (block_until_ready inside)."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    dt = time.perf_counter() - t0
    return items_per_call * iters / dt
