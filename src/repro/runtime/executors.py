"""Executor layer: one training-loop API, three runtime backends.

An executor owns the composed actor/learner step program (runtime/loop.py)
and drives it through chunked ``lax.scan``:

  * ``FusedExecutor``   — the single-jit path: all actors, the buffer and
    the learners live in one XLA program on the default device.  This is
    the paper's single-node regime (and the previous ``loop.train``).

  * ``ShardedExecutor`` — the whole step runs inside ``shard_map`` over
    the replay config's mesh axes: each shard owns E/D envs and one
    replay shard (``ShardedPrioritizedReplay``: local K-ary tree +
    storage), actors insert locally, learners sample locally with
    globally-corrected PER weights (one scalar psum), and gradients are
    pmean'd before the optimizer step
    (runtime/learner.make_sharded_learn) so the replicated agent state
    stays in lockstep.  This is the paper's parallel actors + parallel
    learners architecture mapped onto a device mesh (DESIGN.md §3).
    The mesh may be 1-D (``("data",)``) or 2-D pod-scale
    (``("pod", "data")`` via ``launch.mesh.pod_data_mesh``); on the 2-D
    mesh ``compress_pod_reduce=True`` switches the gradient reduce to
    the hierarchical form (DESIGN.md §7): f32 pmean over the fast
    intra-pod ``data`` axis, then the int8 error-feedback compressed
    mean (``optim/compress.compressed_pmean``) across the slow ``pod``
    links, with the EF buffer threaded through ``LoopState.ef_error``.

  * ``AsyncExecutor``   — the bounded-staleness path (DESIGN.md §5):
    actors act on a *delayed* parameter copy, double-buffered in
    ``LoopState.actor_params`` and republished from the fresh learner
    params every ``publish_interval`` iterations, while learners keep
    updating the fresh params — the paper's "actors never block on
    learners" decoupling (§IV-D) realized inside a deterministic program.
    Without a mesh it wraps the fused program; with a mesh the shard
    publish ticks are staggered and each shard's gradient contribution is
    scaled by ``staleness_weights(age, max_staleness)`` with the reduce
    weight renormalized — a shard past the bound is dropped from the
    reduce (runtime/learner.py).  At ``publish_interval=1,
    max_staleness=0`` it reproduces the synchronous executors
    trajectory-exactly (tests/test_async_executor.py).

All executors realize the same ``RatioSchedule``, so a 1-shard
``ShardedExecutor`` reproduces ``FusedExecutor`` metrics exactly from the
same seed (asserted in tests/test_executors.py), and ``Executor.run``
performs exactly the requested number of iterations (full chunks plus an
exact-length tail chunk, one cached jit per tail length).

Every chunk program **donates the replay state** (tree + storage) at the
jit boundary (``donate_argnums``): the multi-MB sum tree and transition
storage buffers are aliased input↔output instead of copied per chunk
call, completing the lazy-write story — one propagation pass per
iteration (runtime/loop.py) and zero surviving tree copies across the
scan/jit seam.  Callers must treat ``state.replay`` as consumed by
``run_chunk`` (use the returned state; the other LoopState fields —
agent params, the async double buffer, env state — are *not* donated, so
holding references to those across chunks stays legal).

Typical use::

    env_fn = functools.partial(make_vec, "cartpole")
    ex = FusedExecutor(agent, replay, env_fn, cfg, n_envs=8)
    state, history = ex.train(iterations=2000, key=jax.random.PRNGKey(0))

    mesh = data_mesh(4)
    srb = ShardedPrioritizedReplay(ShardedReplayConfig(...), example)
    ex = ShardedExecutor(agent, srb, env_fn, cfg, n_envs=8, mesh=mesh)
    state, history = ex.train(iterations=2000, key=jax.random.PRNGKey(0))

    ex = AsyncExecutor(agent, srb, env_fn, cfg, n_envs=8, mesh=mesh,
                       publish_interval=4, max_staleness=1)
    state, history = ex.train(iterations=2000, key=jax.random.PRNGKey(0))
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.agents.base import Agent
from repro.core.distributed import ShardedPrioritizedReplay
from repro.core.replay import PrioritizedReplay
from repro.runtime.learner import make_sharded_learn
from repro.runtime.loop import (METRIC_KEYS, LoopConfig, LoopState,
                                RatioSchedule, init_loop_state, make_step)

Pytree = Any


class Executor:
    """Common chunked-scan driver; subclasses provide init() and
    _build_chunk(length)."""

    schedule: RatioSchedule
    scan_chunk: int

    def init(self, key: jax.Array) -> LoopState:
        raise NotImplementedError

    def _build_chunk(self, length: int) -> Callable:
        """Compile (state) → (state, per-iteration metrics of shape
        (length,)) scanning the step ``length`` times."""
        raise NotImplementedError

    def run_chunk(self, state: LoopState, length: Optional[int] = None):
        """(state) → (state, per-iteration metrics of shape (length,)).

        Compiled programs are cached per distinct ``length`` — the run
        loop only ever uses ``scan_chunk`` plus one tail length."""
        length = self.scan_chunk if length is None else length
        cache = getattr(self, "_chunks", None)
        if cache is None:
            cache = self._chunks = {}
        fn = cache.get(length)
        if fn is None:
            fn = cache[length] = self._build_chunk(length)
        return fn(state)

    def run(self, state: LoopState, iterations: int, log_every: int = 0
            ) -> Tuple[LoopState, Dict[str, jax.Array]]:
        """Run *exactly* ``iterations`` iterations: full ``scan_chunk``
        chunks plus one exact-length tail chunk (no off-by-chunk
        overshoot).  ``history`` holds the last iteration's metrics of
        each chunk."""
        if iterations < 1:
            raise ValueError(f"iterations={iterations}: need ≥ 1")
        history = []
        done_iters = 0
        while done_iters < iterations:
            length = min(self.scan_chunk, iterations - done_iters)
            state, metrics = self.run_chunk(state, length)
            prev_iters, done_iters = done_iters, done_iters + length
            last = jax.tree.map(lambda x: x[-1], metrics)
            history.append(last)
            if log_every and done_iters // log_every > prev_iters // log_every:
                print(f"iter={done_iters} "
                      f"return={float(last['mean_episode_return']):.1f} "
                      f"loss={float(last['loss']):.4f} "
                      f"buffer={int(last['buffer_size'])} "
                      f"learns={int(last['learn_steps'])}")
        return state, jax.tree.map(lambda *xs: jnp.stack(xs), *history)

    def train(self, iterations: int, key: jax.Array, log_every: int = 0
              ) -> Tuple[LoopState, Dict[str, jax.Array]]:
        return self.run(self.init(key), iterations, log_every)


class FusedExecutor(Executor):
    """Single-jit fused path (the paper's single-node regime).

    ``publish_interval`` is plumbing for ``AsyncExecutor``: > 0 switches
    the step into double-buffered acting (actors read the delayed
    ``actor_params`` copy, republished every ``publish_interval``
    iterations); 0 (the default) is the synchronous loop.
    ``external_publish=True`` removes the in-program republish — the
    host runtime rewrites ``actor_params`` between chunks via a real
    device→host→device transfer (``launch/multiprocess.py``)."""

    def __init__(
        self,
        agent: Agent,
        replay: PrioritizedReplay,
        env_fn: Callable[[int], tuple],
        cfg: LoopConfig,
        n_envs: int,
        scan_chunk: int = 64,
        publish_interval: int = 0,
        external_publish: bool = False,
    ):
        self.agent = agent
        self.replay = replay
        self.cfg = cfg
        self.n_envs = n_envs
        self.scan_chunk = scan_chunk
        self.publish_interval = publish_interval
        self.external_publish = external_publish
        self._chunks: Dict[int, Callable] = {}
        self.spec, self._v_reset, self._v_step = env_fn(n_envs)
        self.schedule = RatioSchedule.from_config(cfg, n_envs)
        self.step = make_step(agent, replay, self._v_step, cfg, n_envs,
                              schedule=self.schedule,
                              publish_interval=publish_interval,
                              external_publish=external_publish)

    def _build_chunk(self, length: int) -> Callable:
        def chunk(replay_state, rest):
            state = rest._replace(replay=replay_state)

            def body(s, _):
                return self.step(s)
            return jax.lax.scan(body, state, None, length=length)

        # tree + storage are donated: XLA aliases the replay buffers
        # input↔output instead of round-tripping a copy per chunk
        fn = jax.jit(chunk, donate_argnums=(0,))

        def run(state: LoopState):
            return fn(state.replay, state._replace(replay=()))
        return run

    def init(self, key: jax.Array) -> LoopState:
        return init_loop_state(self.agent, self.replay, self._v_reset, key,
                               self.n_envs,
                               double_buffer=self.publish_interval > 0)


class ShardedExecutor(Executor):
    """shard_map path: per-shard actors + replay shard, pmean'd learners.

    ``n_envs`` is the *global* env count; each of the mesh's D shards
    (D = the product of the replay config's axis extents — e.g. a 2×2
    pod×data mesh has D=4) runs ``n_envs / D`` envs and holds one replay
    shard.  The learner batch is ``cfg.batch_size / D`` per shard
    (global batch preserved under the gradient pmean).  Shard identity
    is the *flattened* (pod, data) index — row-major over
    ``replay.config.axis_names`` — so a 2×1 pod×data mesh reproduces a
    1-D 2-shard data mesh exactly (same rng folds, same stagger phases).

    ``compress_pod_reduce=True`` (2-D meshes only — the first axis is
    the slow inter-pod one) swaps the cross-pod leg of the gradient
    reduce for the int8 error-feedback compressed mean; the per-shard EF
    buffer rides in ``LoopState.ef_error`` with the same leading-shard-
    axis layout as the replay shards.  ``overlap_pod_reduce=True`` (on
    top of ``compress_pod_reduce``) double-buffers that compressed pod
    leg: each learn applies the previous learn's cross-pod correction
    while its own ``compressed_pmean`` runs off the critical path
    (``make_grad_reducer(overlap=True)``, DESIGN.md §10); ``ef_error``
    then carries the per-shard ``{"ef", "prev_mean", "prev_partial"}``
    triple.

    ``publish_interval``/``max_staleness`` are plumbing for
    ``AsyncExecutor``: with ``publish_interval > 0`` each shard acts on
    its own delayed parameter copy (publish ticks staggered by shard id,
    so shard ages differ) and the gradient pmean becomes the bounded-
    staleness renormalized reduce of ``runtime/learner.py``.
    """

    def __init__(
        self,
        agent: Agent,
        replay: ShardedPrioritizedReplay,
        env_fn: Callable[[int], tuple],
        cfg: LoopConfig,
        n_envs: int,
        mesh: Mesh,
        scan_chunk: int = 64,
        publish_interval: int = 0,
        max_staleness: Optional[int] = None,
        compress_pod_reduce: bool = False,
        intra_pod_dtype: Optional[str] = None,
        overlap_pod_reduce: bool = False,
        external_publish: bool = False,
    ):
        axes = tuple(replay.config.axis_names)
        missing = [ax for ax in axes if ax not in mesh.shape]
        if missing:
            raise ValueError(f"replay axes {missing} not in mesh axes "
                             f"{tuple(mesh.shape)}")
        extra = [ax for ax in mesh.shape if ax not in axes]
        if extra:
            raise ValueError(
                f"mesh axes {extra} are not in the replay config's "
                f"axis_names {axes}: the executor would replicate every "
                "shard across them (duplicate programs on "
                f"{math.prod(mesh.shape[ax] for ax in extra)}× the "
                "devices, no extra capacity or gradient averaging) — "
                "name every mesh axis in ShardedReplayConfig.axis_names, "
                "e.g. axis_names=(\"pod\", \"data\") for pod_data_mesh")
        if compress_pod_reduce and len(axes) < 2:
            raise ValueError(
                "compress_pod_reduce needs a multi-axis (pod, data) mesh: "
                f"with the single axis {axes} there is no slow cross-pod "
                "link to compress — the intra-pod reduce stays f32")
        if overlap_pod_reduce and not compress_pod_reduce:
            raise ValueError(
                "overlap_pod_reduce needs compress_pod_reduce=True: the "
                "double buffer defers the *compressed* cross-pod leg — "
                "there is no overlapped form of the plain global pmean")
        if overlap_pod_reduce and publish_interval and max_staleness is not None:
            raise ValueError(
                "overlap_pod_reduce is incompatible with max_staleness: "
                "the bounded-staleness reduce renormalizes by a global "
                "weight total, which puts this event's cross-pod traffic "
                "back on the critical path (runtime/learner.py)")
        self._axes = axes
        axis_sizes = tuple(mesh.shape[ax] for ax in axes)
        n_shards = math.prod(axis_sizes)
        if n_envs % n_shards:
            raise ValueError(f"n_envs={n_envs} not divisible by "
                             f"{n_shards} shards")
        if cfg.batch_size % n_shards:
            raise ValueError(f"batch_size={cfg.batch_size} not divisible by "
                             f"{n_shards} shards")
        self.agent = agent
        self.replay = replay
        self.cfg = cfg
        self.mesh = mesh
        self.n_shards = n_shards
        self.n_envs = n_envs
        self.n_envs_local = n_envs // n_shards
        self.scan_chunk = scan_chunk
        self.publish_interval = publish_interval
        self.max_staleness = max_staleness
        self.compress_pod_reduce = compress_pod_reduce
        self.intra_pod_dtype = intra_pod_dtype
        self.overlap_pod_reduce = overlap_pod_reduce
        self.external_publish = external_publish
        self._chunks: Dict[int, Callable] = {}
        self.spec, self._v_reset, self._v_step = env_fn(self.n_envs_local)
        self.schedule = RatioSchedule.from_config(cfg, n_envs)

        if publish_interval and max_staleness is not None:
            # the staggered publish clock of shard d has fixed phase d mod
            # P, so at learn ticks (every `period` iterations) its age
            # cycles over {(d + k·gcd(P, period)) mod P} with minimum
            # d mod gcd — a shard whose minimum exceeds the bound would be
            # dropped from EVERY reduce and its replay data never trains
            g = math.gcd(publish_interval, self.schedule.period)
            if min(g, n_shards) > max_staleness + 1:
                raise ValueError(
                    f"publish_interval={publish_interval} and the learn "
                    f"period {self.schedule.period} share the factor {g} > "
                    f"max_staleness+1={max_staleness + 1}: shards whose "
                    "staggered publish phase exceeds the staleness bound at "
                    "every learn tick would be permanently dropped from the "
                    "gradient reduce (their replay data would never train). "
                    "Pick a publish_interval coprime with the learn period "
                    "or raise max_staleness.")

        learn_fn = make_sharded_learn(
            agent, replay, batch_per_shard=cfg.batch_size // n_shards,
            beta=cfg.beta,
            max_staleness=max_staleness if publish_interval else None,
            compress_axis=axes[0] if compress_pod_reduce else None,
            intra_pod_dtype=intra_pod_dtype,
            lazy_writes=cfg.lazy_replay,
            overlap=overlap_pod_reduce)

        def flat_shard_id():
            # row-major flattened (pod, data) index over the mesh axes —
            # the single integer identity used for rng folds and the
            # staggered publish clocks
            sid = jnp.zeros((), jnp.int32)
            for ax, size in zip(axes, axis_sizes):
                sid = sid * size + jax.lax.axis_index(ax)
            return sid

        # metric reduction deliberately does NOT ride the per-iteration
        # step (identity mean_across/sum_across): the scanned step emits
        # shard-local metrics and _reduce_metrics contracts the whole
        # chunk's stack with one fused collective per chunk — on the
        # real multi-process transport the 7-per-iteration metric
        # collectives were most of the wall-clock (DESIGN.md §10)
        self.step = make_step(
            agent, replay, self._v_step, cfg, self.n_envs_local,
            schedule=self.schedule,
            learn_fn=learn_fn,
            shard_id=flat_shard_id,
            publish_interval=publish_interval,
            external_publish=external_publish,
        )

        self._specs = self._state_specs()
        self._metric_specs = {k: PartitionSpec() for k in METRIC_KEYS}

        def init_local(key):
            st = init_loop_state(agent, replay, self._v_reset, key,
                                 self.n_envs_local, shard_id=flat_shard_id(),
                                 double_buffer=publish_interval > 0,
                                 ef_buffer=compress_pod_reduce,
                                 overlap=overlap_pod_reduce)
            return self._global_state(st)

        self._init = jax.jit(shard_map(
            init_local, mesh=mesh, in_specs=(PartitionSpec(),),
            out_specs=self._specs, check_rep=False))

    def _reduce_metrics(self, metrics: Dict[str, jax.Array]
                        ) -> Dict[str, jax.Array]:
        """Contract the chunk's stacked shard-local metrics across the
        mesh in ONE fused collective (call inside shard_map, after the
        scan).  The per-iteration form reduced 7 scalars per step — at
        real multi-process launch latencies that was most of the
        wall-clock budget; here the cross-shard keys of the whole
        (length,)-stacked chunk share a single pmean.  ``buffer_size``
        rides the same f32 pmean as mean × shard count: counts are ≤
        capacity (exact in f32) and the round() clears the /D·D
        rounding when the shard count is not a power of two.  Values
        are bit-identical to the per-iteration reduction — psum
        commutes with stacking."""
        stack = jnp.stack([
            metrics["loss"],
            metrics["mean_episode_return"],
            metrics["compress_error_norm"],
            metrics["buffer_size"].astype(jnp.float32),
        ])
        for ax in self._axes:
            stack = jax.lax.pmean(stack, ax)
        out = dict(metrics)
        out["loss"] = stack[0]
        out["mean_episode_return"] = stack[1]
        out["compress_error_norm"] = stack[2]
        out["buffer_size"] = jnp.round(stack[3] * self.n_shards).astype(
            metrics["buffer_size"].dtype)
        return out

    def _build_chunk(self, length: int) -> Callable:
        def chunk_local(replay_g, rest_g):
            state = self._local_state(rest_g._replace(replay=replay_g))

            def body(s, _):
                return self.step(s)

            state, metrics = jax.lax.scan(body, state, None, length=length)
            return self._global_state(state), self._reduce_metrics(metrics)

        # replay (tree + storage) donated at the jit boundary, same as
        # the fused path — per-shard buffers alias through shard_map
        fn = jax.jit(shard_map(
            chunk_local, mesh=self.mesh,
            in_specs=(self._specs.replay, self._specs._replace(replay=())),
            out_specs=(self._specs, self._metric_specs), check_rep=False),
            donate_argnums=(0,))

        def run(state: LoopState):
            return fn(state.replay, state._replace(replay=()))
        return run

    # -- per-shard ↔ global state layout ----------------------------------
    #
    # Replay-shard leaves (tree, storage, head, count, max_priority) gain a
    # leading shard axis in the global representation: local (…) ↔ global
    # (D, …), so rank-0 per-shard scalars stay addressable under a
    # PartitionSpec(axes) without replication lies (on a 2-D mesh the
    # leading dim is sharded over BOTH axes — P(("pod", "data")) — in the
    # same row-major order as the flattened shard id).  The async double
    # buffer (actor_params, params_age) and the EF error buffer are laid
    # out the same way — each shard holds its *own* delayed copy / error
    # state (within a pod the EF copies are numerically identical, across
    # pods they differ).  Env-side leaves already carry the env axis,
    # which concatenates across shards to the global env count.  Agent
    # params / rng / counters are replicated.

    def _map_sharded_fields(self, state: LoopState, fn) -> LoopState:
        updates = {"replay": jax.tree.map(fn, state.replay)}
        if self.publish_interval:
            updates["actor_params"] = jax.tree.map(fn, state.actor_params)
            updates["params_age"] = fn(state.params_age)
        if self.compress_pod_reduce:
            updates["ef_error"] = jax.tree.map(fn, state.ef_error)
        return state._replace(**updates)

    def _local_state(self, gstate: LoopState) -> LoopState:
        return self._map_sharded_fields(gstate, lambda x: x[0])

    def _global_state(self, state: LoopState) -> LoopState:
        return self._map_sharded_fields(state, lambda x: x[None])

    def _state_specs(self) -> LoopState:
        key_shape = jax.ShapeDtypeStruct((2,), jnp.uint32)
        shapes = jax.eval_shape(
            lambda k: init_loop_state(self.agent, self.replay, self._v_reset,
                                      k, self.n_envs_local,
                                      double_buffer=self.publish_interval > 0,
                                      ef_buffer=self.compress_pod_reduce,
                                      overlap=self.overlap_pod_reduce),
            key_shape)
        # leading dim sharded over ALL mesh axes at once (row-major):
        # P(("pod", "data")) on the 2-D mesh, P(("data",)) ≡ P("data") 1-D
        dim0 = PartitionSpec(self._axes)
        rep = lambda tree: jax.tree.map(lambda _: PartitionSpec(), tree)
        shard = lambda tree: jax.tree.map(lambda _: dim0, tree)
        return LoopState(
            agent=rep(shapes.agent),
            replay=shard(shapes.replay),
            env_state=shard(shapes.env_state),
            obs=dim0,
            rng=PartitionSpec(),
            env_steps=PartitionSpec(),
            episode_return=dim0,
            last_return=dim0,
            learn_steps=PartitionSpec(),
            actor_params=shard(shapes.actor_params),
            params_age=shard(shapes.params_age),
            ef_error=shard(shapes.ef_error),
        )

    def init(self, key: jax.Array) -> LoopState:
        return self._init(key)


class AsyncExecutor(Executor):
    """Bounded-staleness backend (DESIGN.md §5): decoupled actor/learner
    parameter clocks.

    Actors act on a delayed copy of the agent params
    (``LoopState.actor_params``), republished from the fresh learner
    params every ``publish_interval`` iterations; learners update the
    fresh params every scheduled learn event.  Without ``mesh`` this
    wraps the fused program (``max_staleness`` is inert — there is no
    cross-shard reduce to weight).  With ``mesh`` the publish ticks are
    staggered per shard, so shards act at different parameter ages, and
    each shard's gradient enters the reduce scaled by
    ``staleness_weights(age, max_staleness)`` with the total weight
    renormalized — a shard past the bound is dropped, the survivors'
    realized weights sum to 1 (``runtime/learner.py``).

    At the identity settings ``publish_interval=1, max_staleness=0`` the
    delayed copy is republished every iteration and this executor
    reproduces the synchronous ones trajectory-exactly from the same
    seed (asserted in tests/test_async_executor.py).
    """

    def __init__(
        self,
        agent: Agent,
        replay,
        env_fn: Callable[[int], tuple],
        cfg: LoopConfig,
        n_envs: int,
        publish_interval: int = 1,
        max_staleness: int = 0,
        mesh: Optional[Mesh] = None,
        scan_chunk: int = 64,
        compress_pod_reduce: bool = False,
        intra_pod_dtype: Optional[str] = None,
        overlap_pod_reduce: bool = False,
        external_publish: bool = False,
    ):
        if publish_interval < 1:
            raise ValueError(
                f"publish_interval={publish_interval}: need ≥ 1 (1 = "
                "republish every iteration = the synchronous loop)")
        if max_staleness < 0:
            raise ValueError(f"max_staleness={max_staleness}: need ≥ 0")
        if overlap_pod_reduce and max_staleness:
            raise ValueError(
                "overlap_pod_reduce is incompatible with max_staleness > "
                "0: the bounded-staleness reduce renormalizes by a global "
                "weight total, putting this event's cross-pod traffic "
                "back on the critical path (runtime/learner.py)")
        if mesh is None:
            if compress_pod_reduce:
                raise ValueError(
                    "compress_pod_reduce needs a (pod, data) mesh — the "
                    "fused path has no cross-pod reduce to compress")
            if overlap_pod_reduce:
                raise ValueError(
                    "overlap_pod_reduce needs a (pod, data) mesh — the "
                    "fused path has no cross-pod reduce to overlap")
            if intra_pod_dtype not in (None, "f32", "float32"):
                raise ValueError(
                    "intra_pod_dtype needs a mesh — the fused path has "
                    "no cross-shard reduce to cast")
            self._impl: Executor = FusedExecutor(
                agent, replay, env_fn, cfg, n_envs, scan_chunk=scan_chunk,
                publish_interval=publish_interval,
                external_publish=external_publish)
        else:
            self._impl = ShardedExecutor(
                agent, replay, env_fn, cfg, n_envs, mesh,
                scan_chunk=scan_chunk, publish_interval=publish_interval,
                max_staleness=None if overlap_pod_reduce else max_staleness,
                compress_pod_reduce=compress_pod_reduce,
                intra_pod_dtype=intra_pod_dtype,
                overlap_pod_reduce=overlap_pod_reduce,
                external_publish=external_publish)
            self.n_shards = self._impl.n_shards
            self.n_envs_local = self._impl.n_envs_local
        self.agent = agent
        self.replay = replay
        self.cfg = cfg
        self.mesh = mesh
        self.n_envs = n_envs
        self.scan_chunk = scan_chunk
        self.publish_interval = publish_interval
        self.max_staleness = max_staleness
        self.compress_pod_reduce = compress_pod_reduce
        self.intra_pod_dtype = intra_pod_dtype
        self.overlap_pod_reduce = overlap_pod_reduce
        self.external_publish = external_publish
        self.spec = self._impl.spec
        self.step = self._impl.step
        self.schedule = self._impl.schedule

    def _build_chunk(self, length: int) -> Callable:
        return self._impl._build_chunk(length)

    def init(self, key: jax.Array) -> LoopState:
        return self._impl.init(key)


def executor_from_plan(
    plan,
    agent: Agent,
    env_fn: Callable[[int], tuple],
    cfg,
    example: Pytree,
    *,
    capacity: int = 50_000,
    fanout: int = 128,
    tree_backend: str = "xla",
    scan_chunk: int = 64,
    intra_pod_dtype: Optional[str] = None,
) -> Executor:
    """Instantiate the executor a ``runtime.planner.PlannedConfig``
    selected: the right backend class, mesh (``launch.mesh.
    mesh_from_plan``), replay flavor and async knobs, with the plan's
    ``n_envs`` and ``update_interval`` applied (the latter overrides
    ``cfg.update_interval`` — the plan *is* the Eq. 5 answer for the
    ratio it was solved at).

    The caller must have forced ``plan.n_devices`` host devices before
    the first jax call (``--xla_force_host_platform_device_count``);
    ``examples/quickstart.py --plan`` shows the full dance.

    A plan with ``n_replay_shards ≥ 1`` (the replay-service degrees of
    freedom, runtime/planner.py) routes experience through an in-process
    ``ReplayService`` behind a ``RateLimiter`` pinned to the plan's
    ``samples_per_insert`` — the ``ServiceExecutor`` form of the same
    workload (DESIGN.md §11).  Service plans run the fused (no-mesh)
    program per process; the multi-process service gang is launched by
    ``launch.multiprocess.launch_service`` instead.
    """
    import dataclasses as _dc

    from repro.core.distributed import ShardedReplayConfig
    from repro.launch.mesh import mesh_from_plan

    cfg = _dc.replace(cfg, update_interval=plan.update_interval)
    n_replay_shards = getattr(plan, "n_replay_shards", 0)
    if n_replay_shards:
        from repro.service.executor import ServiceExecutor
        from repro.service.rate_limiter import RateLimiter
        from repro.service.server import ReplayService, ReplayServiceConfig

        if mesh_from_plan(plan) is not None:
            raise ValueError(
                f"plan ({plan.describe()}) combines a device mesh with a "
                "replay service — the service executor runs the fused "
                "per-process program; use launch_service for a gang")
        service = ReplayService(
            ReplayServiceConfig(
                capacity_per_shard=max(1, capacity // n_replay_shards),
                n_shards=n_replay_shards, fanout=fanout,
                backend=tree_backend, router="round_robin"),
            example)
        limiter = None
        if plan.samples_per_insert:
            limiter = RateLimiter.for_loop(
                cfg.batch_size,
                max(1, round(cfg.batch_size / plan.samples_per_insert)),
                cfg.warmup, insert_burst=plan.n_envs)
        return ServiceExecutor(agent, service, env_fn, cfg, plan.n_envs,
                               scan_chunk=scan_chunk,
                               rate_limiter=limiter)
    mesh = mesh_from_plan(plan)
    if mesh is None:
        if intra_pod_dtype not in (None, "f32", "float32"):
            raise ValueError(
                f"intra_pod_dtype={intra_pod_dtype!r} but the plan "
                f"({plan.describe()}) runs the fused program — there is "
                "no cross-shard reduce to cast")
        from repro.core.replay import ReplayConfig
        replay = PrioritizedReplay(
            ReplayConfig(capacity=capacity, fanout=fanout,
                         backend=tree_backend), example)
        if plan.backend == "async":
            return AsyncExecutor(agent, replay, env_fn, cfg, plan.n_envs,
                                 publish_interval=plan.publish_interval,
                                 max_staleness=plan.max_staleness,
                                 scan_chunk=scan_chunk)
        return FusedExecutor(agent, replay, env_fn, cfg, plan.n_envs,
                             scan_chunk=scan_chunk)
    axis_names = ("pod", "data") if plan.n_pods > 1 else ("data",)
    replay = ShardedPrioritizedReplay(
        ShardedReplayConfig(capacity_per_shard=capacity // plan.n_shards,
                            fanout=fanout, backend=tree_backend,
                            axis_names=axis_names), example)
    overlap = getattr(plan, "overlap_pod_reduce", False)
    if plan.backend == "async":
        return AsyncExecutor(agent, replay, env_fn, cfg, plan.n_envs,
                             publish_interval=plan.publish_interval,
                             max_staleness=plan.max_staleness, mesh=mesh,
                             scan_chunk=scan_chunk,
                             compress_pod_reduce=plan.compress_pod_reduce,
                             intra_pod_dtype=intra_pod_dtype,
                             overlap_pod_reduce=overlap)
    return ShardedExecutor(agent, replay, env_fn, cfg, plan.n_envs, mesh,
                           scan_chunk=scan_chunk,
                           compress_pod_reduce=plan.compress_pod_reduce,
                           intra_pod_dtype=intra_pod_dtype,
                           overlap_pod_reduce=overlap)
