"""Executor layer: one training-loop API, two runtime backends.

An executor owns the composed actor/learner step program (runtime/loop.py)
and drives it through chunked ``lax.scan``:

  * ``FusedExecutor``   — the single-jit path: all actors, the buffer and
    the learners live in one XLA program on the default device.  This is
    the paper's single-node regime (and the previous ``loop.train``).

  * ``ShardedExecutor`` — the whole step runs inside ``shard_map`` over a
    mesh data axis: each shard owns E/D envs and one replay shard
    (``ShardedPrioritizedReplay``: local K-ary tree + storage), actors
    insert locally, learners sample locally with globally-corrected PER
    weights (one scalar psum), and gradients are pmean'd before the
    optimizer step (runtime/learner.make_sharded_learn) so the replicated
    agent state stays in lockstep.  This is the paper's parallel
    actors + parallel learners architecture mapped onto a device mesh
    (DESIGN.md §3).

Both executors realize the same ``RatioSchedule``, so a 1-shard
``ShardedExecutor`` reproduces ``FusedExecutor`` metrics exactly from the
same seed (asserted in tests/test_executors.py).

Typical use::

    env_fn = functools.partial(make_vec, "cartpole")
    ex = FusedExecutor(agent, replay, env_fn, cfg, n_envs=8)
    state, history = ex.train(iterations=2000, key=jax.random.PRNGKey(0))

    mesh = data_mesh(4)
    srb = ShardedPrioritizedReplay(ShardedReplayConfig(...), example)
    ex = ShardedExecutor(agent, srb, env_fn, cfg, n_envs=8, mesh=mesh)
    state, history = ex.train(iterations=2000, key=jax.random.PRNGKey(0))
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.agents.base import Agent
from repro.core.distributed import ShardedPrioritizedReplay
from repro.core.replay import PrioritizedReplay
from repro.runtime.learner import make_sharded_learn
from repro.runtime.loop import (METRIC_KEYS, LoopConfig, LoopState,
                                RatioSchedule, init_loop_state, make_step)

Pytree = Any


class Executor:
    """Common chunked-scan driver; subclasses provide init() and _chunk."""

    schedule: RatioSchedule
    scan_chunk: int

    def init(self, key: jax.Array) -> LoopState:
        raise NotImplementedError

    def run_chunk(self, state: LoopState):
        """(state) → (state, per-iteration metrics of shape (scan_chunk,))."""
        raise NotImplementedError

    def run(self, state: LoopState, iterations: int, log_every: int = 0
            ) -> Tuple[LoopState, Dict[str, jax.Array]]:
        history = []
        done_iters = 0
        while done_iters < iterations:
            state, metrics = self.run_chunk(state)
            done_iters += self.scan_chunk
            last = jax.tree.map(lambda x: x[-1], metrics)
            history.append(last)
            if log_every and done_iters % log_every < self.scan_chunk:
                print(f"iter={done_iters} "
                      f"return={float(last['mean_episode_return']):.1f} "
                      f"loss={float(last['loss']):.4f} "
                      f"buffer={int(last['buffer_size'])} "
                      f"learns={int(last['learn_steps'])}")
        return state, jax.tree.map(lambda *xs: jnp.stack(xs), *history)

    def train(self, iterations: int, key: jax.Array, log_every: int = 0
              ) -> Tuple[LoopState, Dict[str, jax.Array]]:
        return self.run(self.init(key), iterations, log_every)


class FusedExecutor(Executor):
    """Single-jit fused path (the paper's single-node regime)."""

    def __init__(
        self,
        agent: Agent,
        replay: PrioritizedReplay,
        env_fn: Callable[[int], tuple],
        cfg: LoopConfig,
        n_envs: int,
        scan_chunk: int = 64,
    ):
        self.agent = agent
        self.replay = replay
        self.cfg = cfg
        self.n_envs = n_envs
        self.scan_chunk = scan_chunk
        self.spec, self._v_reset, self._v_step = env_fn(n_envs)
        self.schedule = RatioSchedule.from_config(cfg, n_envs)
        self.step = make_step(agent, replay, self._v_step, cfg, n_envs,
                              schedule=self.schedule)

        @jax.jit
        def chunk(state):
            def body(s, _):
                return self.step(s)
            return jax.lax.scan(body, state, None, length=scan_chunk)

        self._chunk = chunk

    def init(self, key: jax.Array) -> LoopState:
        return init_loop_state(self.agent, self.replay, self._v_reset, key,
                               self.n_envs)

    def run_chunk(self, state: LoopState):
        return self._chunk(state)


class ShardedExecutor(Executor):
    """shard_map path: per-shard actors + replay shard, pmean'd learners.

    ``n_envs`` is the *global* env count; each of the mesh's D data-axis
    shards runs ``n_envs / D`` envs and holds one replay shard.  The
    learner batch is ``cfg.batch_size / D`` per shard (global batch
    preserved under the gradient pmean).
    """

    def __init__(
        self,
        agent: Agent,
        replay: ShardedPrioritizedReplay,
        env_fn: Callable[[int], tuple],
        cfg: LoopConfig,
        n_envs: int,
        mesh: Mesh,
        scan_chunk: int = 64,
    ):
        (self._axis,) = replay.config.axis_names  # single data axis for now
        n_shards = mesh.shape[self._axis]
        if n_envs % n_shards:
            raise ValueError(f"n_envs={n_envs} not divisible by "
                             f"{n_shards} shards")
        if cfg.batch_size % n_shards:
            raise ValueError(f"batch_size={cfg.batch_size} not divisible by "
                             f"{n_shards} shards")
        self.agent = agent
        self.replay = replay
        self.cfg = cfg
        self.mesh = mesh
        self.n_shards = n_shards
        self.n_envs = n_envs
        self.n_envs_local = n_envs // n_shards
        self.scan_chunk = scan_chunk
        self.spec, self._v_reset, self._v_step = env_fn(self.n_envs_local)
        self.schedule = RatioSchedule.from_config(cfg, n_envs)

        axis = self._axis
        learn_fn = make_sharded_learn(
            agent, replay, batch_per_shard=cfg.batch_size // n_shards,
            beta=cfg.beta)
        self.step = make_step(
            agent, replay, self._v_step, cfg, self.n_envs_local,
            schedule=self.schedule,
            learn_fn=learn_fn,
            shard_id=lambda: jax.lax.axis_index(axis),
            mean_across=lambda x: jax.lax.pmean(x, axis),
            sum_across=lambda x: jax.lax.psum(x, axis),
        )

        specs = self._state_specs()
        metric_specs = {k: PartitionSpec() for k in METRIC_KEYS}

        def chunk_local(gstate):
            state = self._local_state(gstate)

            def body(s, _):
                return self.step(s)

            state, metrics = jax.lax.scan(body, state, None, length=scan_chunk)
            return self._global_state(state), metrics

        self._chunk = jax.jit(shard_map(
            chunk_local, mesh=mesh, in_specs=(specs,),
            out_specs=(specs, metric_specs), check_rep=False))

        def init_local(key):
            sid = jax.lax.axis_index(axis)
            st = init_loop_state(agent, replay, self._v_reset, key,
                                 self.n_envs_local, shard_id=sid)
            return self._global_state(st)

        self._init = jax.jit(shard_map(
            init_local, mesh=mesh, in_specs=(PartitionSpec(),),
            out_specs=specs, check_rep=False))

    # -- per-shard ↔ global state layout ----------------------------------
    #
    # Replay-shard leaves (tree, storage, head, count, max_priority) gain a
    # leading shard axis in the global representation: local (…) ↔ global
    # (D, …), so rank-0 per-shard scalars stay addressable under a
    # PartitionSpec("data") without replication lies.  Env-side leaves
    # already carry the env axis, which concatenates across shards to the
    # global env count.  Agent params / rng / counters are replicated.

    def _local_state(self, gstate: LoopState) -> LoopState:
        return gstate._replace(
            replay=jax.tree.map(lambda x: x[0], gstate.replay))

    def _global_state(self, state: LoopState) -> LoopState:
        return state._replace(
            replay=jax.tree.map(lambda x: x[None], state.replay))

    def _state_specs(self) -> LoopState:
        key_shape = jax.ShapeDtypeStruct((2,), jnp.uint32)
        shapes = jax.eval_shape(
            lambda k: init_loop_state(self.agent, self.replay, self._v_reset,
                                      k, self.n_envs_local),
            key_shape)
        rep = lambda tree: jax.tree.map(lambda _: PartitionSpec(), tree)
        shard = lambda tree: jax.tree.map(
            lambda _: PartitionSpec(self._axis), tree)
        return LoopState(
            agent=rep(shapes.agent),
            replay=shard(shapes.replay),
            env_state=shard(shapes.env_state),
            obs=PartitionSpec(self._axis),
            rng=PartitionSpec(),
            env_steps=PartitionSpec(),
            episode_return=PartitionSpec(self._axis),
            last_return=PartitionSpec(self._axis),
            learn_steps=PartitionSpec(),
        )

    def init(self, key: jax.Array) -> LoopState:
        return self._init(key)

    def run_chunk(self, state: LoopState):
        return self._chunk(state)
