"""The paper's training loop (Alg. 1) as composable actor/learner programs.

The fused iteration realizes lazy writing (§IV-D) as a replay
*transaction* (DESIGN.md §9): every tree mutation inside one iteration
writes only the sum tree's leaf level, and a single merged propagation
pass (``replay.flush``) runs at the sample boundary:

    1. ACTORS   — ε-greedy act on E vectorized envs, env step           (§V-A)
    2. INSERT-BEGIN — zero in-flight slot priorities (leaf-only write)
    3. FLUSH    — ONE upward propagation pass coalescing the previous
                  iteration's priority updates + insert-commit with this
                  iteration's insert-begin (lazy ≡ eager bit-exact here)
    4. LEARNERS — sample B from the flushed tree, TD update             (§V-B)
    5. PRIORITY UPDATE — leaf-only write, write-after-read tolerated  (§IV-D3)
    6. INSERT-COMMIT — storage write + P_max restore (leaf-only write)

Steps 5/6 defer their propagation to the *next* iteration's flush, so
the eager path's three full propagation passes per iteration collapse
to one (asserted by an op-count trace test).  Step 4 never depends on
step 6's storage write (in-flight slots are invisible by construction),
so XLA schedules the transition DMA concurrently with learner compute —
the same overlap the paper's lock split buys on a multicore CPU.
``LoopConfig.lazy_replay=False`` restores the eager per-op propagation
(the replay microbenchmark's baseline arm).

The loop is built from three pieces (DESIGN.md §3):

  * ``make_actor_step``   — one vectorized env interaction producing a
    batch of transitions (the paper's parallel actors);
  * ``make_learner_step`` — one PER sample → TD update → priority
    write-back (the paper's parallel learners);
  * ``RatioSchedule``     — the collection/consumption ratio.  The
    paper's ``update_interval`` (env steps per learn) is *honored*: with
    E envs per iteration and ratio U, the schedule runs round(E/U)
    learner calls per iteration (U < E) or one learner call every
    round(U/E) iterations (U ≥ E).  ``learns_per_step`` multiplies the
    learner calls per event, so both "N actor steps per learn" and
    "M learns per actor step" are expressible.

``make_step`` composes them into one jit-able program; the executors in
``runtime/executors.py`` run that program fused on one device, inside
``shard_map`` over a mesh data axis, or asynchronously: with
``publish_interval > 0`` the actors act on a *delayed* parameter copy
(``LoopState.actor_params``, double-buffered and republished from the
fresh learner params every ``publish_interval`` iterations, staggered by
shard id) while learners keep updating the fresh ``LoopState.agent`` —
the paper's "actors never block on learners" decoupling (§IV-D), with
``LoopState.params_age`` counting iterations since the last publish so
the sharded reduce can weight shards by staleness
(runtime/learner.staleness_weights).  ``publish_interval=1`` republishes
after every iteration, which is exactly the synchronous loop.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.agents.base import Agent, AgentState
from repro.core.replay import PrioritizedReplay, ReplayState
from repro.optim import compress

Pytree = Any

# keys of the metrics dict every composed step returns (make_step below);
# the sharded executor derives its shard_map out_specs from this tuple
METRIC_KEYS = ("loss", "mean_episode_return", "env_steps", "learn_steps",
               "buffer_size", "epsilon", "compress_error_norm")

# keys of the per-learn metrics dict every learn fn returns (the shared
# contract of make_learner_step and runtime/learner.make_sharded_learn)
LEARN_METRIC_KEYS = ("loss", "compress_error_norm")


class LoopState(NamedTuple):
    agent: AgentState
    replay: ReplayState
    env_state: Pytree
    obs: jax.Array
    rng: jax.Array
    env_steps: jax.Array
    episode_return: jax.Array     # running per-env return accumulator
    last_return: jax.Array        # most recently finished episode returns
    learn_steps: jax.Array        # cumulative learner update count
    # async double buffer (empty pytrees on the synchronous executors):
    actor_params: Pytree = ()     # delayed acting copy of the agent params
    params_age: Pytree = ()       # int32 iterations since the last publish
    # error-feedback buffer of the int8 cross-pod compressed reduce
    # (runtime/learner.py); an empty pytree whenever the executor's
    # reduce is uncompressed — 1-D meshes, fused, and plain sharded runs
    ef_error: Pytree = ()


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    batch_size: int = 128
    update_interval: int = 1      # env steps per learn step (paper ratio)
    learns_per_step: int = 1      # extra learner calls per learn event
    warmup: int = 1000            # env steps before learning starts
    epsilon: float = 0.1          # exploration at step 0
    epsilon_final: float = 0.02   # exploration floor after decay
    epsilon_decay_steps: int = 10_000   # env steps of linear ε decay
    beta: float = 0.4             # PER importance exponent
    lazy_replay: bool = True      # lazy-writing replay transactions: one
                                  # merged tree-propagation pass per
                                  # iteration (False = eager per-op passes)


@dataclasses.dataclass(frozen=True)
class RatioSchedule:
    """Static actor/learner interleave realizing ``update_interval``.

    ``period`` iterations separate learn events; each event runs
    ``learns`` learner calls.  Realized ratio (env steps per learn) is
    ``period * env_steps_per_iter / learns``.
    """

    period: int               # iterations between learn events (≥ 1)
    learns: int               # learner calls per event (≥ 1)
    env_steps_per_iter: int   # global env steps added per iteration

    @property
    def realized_ratio(self) -> float:
        return self.period * self.env_steps_per_iter / self.learns

    @classmethod
    def from_config(cls, cfg: LoopConfig, env_steps_per_iter: int) -> "RatioSchedule":
        u = max(1, cfg.update_interval)
        e = env_steps_per_iter
        if u >= e:
            return cls(period=max(1, round(u / e)),
                       learns=max(1, cfg.learns_per_step),
                       env_steps_per_iter=e)
        return cls(period=1,
                   learns=max(1, round(e / u)) * max(1, cfg.learns_per_step),
                   env_steps_per_iter=e)


def epsilon_schedule(cfg: LoopConfig, env_steps: jax.Array) -> jax.Array:
    """Linear ε decay: cfg.epsilon → cfg.epsilon_final over decay_steps."""
    frac = jnp.clip(
        env_steps.astype(jnp.float32) / max(1, cfg.epsilon_decay_steps), 0.0, 1.0
    )
    return cfg.epsilon + (cfg.epsilon_final - cfg.epsilon) * frac


# -- actor program -----------------------------------------------------------


def make_actor_step(agent: Agent, v_step: Callable, n_envs: int):
    """One parallel-actor interaction: act on E envs, step, package the
    transition batch (no weight mutation → no sync; paper §V-A)."""

    def actor_step(agent_state, env_state, obs, ep_ret, last_ret,
                   k_act, k_env, epsilon):
        actions = agent.act(agent_state, obs, k_act, epsilon)
        env_state, obs_next, rew, done, true_next = v_step(env_state, actions, k_env)
        ep_ret = ep_ret + rew
        last_ret = jnp.where(done, ep_ret, last_ret)
        ep_ret = jnp.where(done, 0.0, ep_ret)
        transitions = {
            "obs": obs,
            "action": actions,
            "reward": rew,
            "next_obs": true_next,
            "done": done.astype(jnp.float32),
        }
        return env_state, obs_next, ep_ret, last_ret, transitions

    return actor_step


# -- actor-side program (service boundary, DESIGN.md §11) --------------------


class ActorSlice(NamedTuple):
    """The actor-side state of the decoupled runtime: everything an actor
    fleet process owns when the replay buffer lives behind a service —
    env state plus the episode-return bookkeeping.  The agent params
    arrive via the service's param channel; the replay state never
    crosses into actor land at all."""

    env_state: Pytree
    obs: jax.Array
    episode_return: jax.Array
    last_return: jax.Array


def init_actor_slice(v_reset: Callable, key: jax.Array, n_envs: int,
                     shard_id: int = 0) -> ActorSlice:
    env_state, obs = v_reset(jax.random.fold_in(key, shard_id))
    return ActorSlice(env_state=env_state, obs=obs,
                      episode_return=jnp.zeros((n_envs,)),
                      last_return=jnp.zeros((n_envs,)))


def make_actor_program(agent: Agent, v_step: Callable, cfg: LoopConfig,
                       n_envs: int):
    """The actor side of the split runtime: one jit-able program that
    turns (acting params, env slice, rng, global env-step clock) into a
    transition batch — no replay state, no learner coupling.  The
    ε-schedule is computed *inside* the program from the integer
    ``env_steps`` clock (the service reports global inserts), so a
    host-driven actor reproduces the fused loop's exploration bit-exactly.

    Returns ``program(agent_state, slice, k_act, k_env, env_steps) →
    (slice', transitions)``; the caller jits it (once) and owns the rng
    chain and the append to the replay service.
    """
    actor_step = make_actor_step(agent, v_step, n_envs)

    def program(agent_state, sl: ActorSlice, k_act, k_env, env_steps):
        eps = epsilon_schedule(cfg, env_steps)
        env_state, obs, ep_ret, last_ret, transitions = actor_step(
            agent_state, sl.env_state, sl.obs,
            sl.episode_return, sl.last_return, k_act, k_env, eps)
        return ActorSlice(env_state, obs, ep_ret, last_ret), transitions

    return program


# -- learner program ---------------------------------------------------------


def make_learner_program(agent: Agent):
    """The learner side of the split runtime (DESIGN.md §11): consume a
    sampled batch handed over the service boundary, return the TD errors
    the service needs for the priority write-back.  No replay state —
    sample and priority update live behind the service; this program is
    everything the learner process owns.  ``make_learner_step`` below is
    its fused composition with an in-program replay shard.

    Returns ``program(agent_state, items, weights) →
    (agent_state, metrics, td_errors)``; the caller jits it.
    """

    def program(agent_state, items, weights):
        return agent.learn(agent_state, items, weights)

    return program


def make_learner_step(agent: Agent, replay, cfg: LoopConfig):
    """One parallel-learner call: PER sample → TD update → priority
    write-back (write-after-read tolerated, §IV-D3; with
    ``cfg.lazy_replay`` the write-back is leaf-only and rides the next
    flush).

    ``replay`` may be a ``PrioritizedReplay`` or any object with the same
    sample/update_priorities signature (e.g. the sharded buffer, whose
    ``sample`` computes importance weights against psum'd global stats).
    The sharded gradient-psum variant lives in ``runtime/learner.py``;
    ``age`` (the staleness of the caller's acting copy) and ``ef`` (the
    error-feedback buffer of the compressed cross-pod reduce) are part of
    the shared learn-fn signature and are passed through unused here —
    only the sharded reduces consume them.  Learn fns return a metrics
    dict with ``LEARN_METRIC_KEYS`` (the fused path has no compressed
    reduce, so its error norm is 0).
    """

    def learner_step(agent_state, replay_state, rng, age=None, ef=None):
        del age  # fused learner: no cross-shard reduce to weight
        idx, items, is_w = replay.sample(replay_state, rng, cfg.batch_size, cfg.beta)
        agent_state, metrics, td = agent.learn(agent_state, items, is_w)
        replay_state = replay.update_priorities(replay_state, idx, td,
                                                lazy=cfg.lazy_replay)
        lmetrics = {"loss": metrics["loss"],
                    "compress_error_norm": jnp.zeros(())}
        return agent_state, replay_state, lmetrics, ef

    return learner_step


# -- composed step -----------------------------------------------------------


def make_step(
    agent: Agent,
    replay,
    v_step: Callable,
    cfg: LoopConfig,
    n_envs: int,
    *,
    schedule: Optional[RatioSchedule] = None,
    learn_fn: Optional[Callable] = None,
    shard_id: Union[int, Callable[[], jax.Array]] = 0,
    mean_across: Optional[Callable] = None,
    sum_across: Optional[Callable] = None,
    publish_interval: int = 0,
    external_publish: bool = False,
):
    """Compose actor + learner programs into one jit-able parallel_step.

    ``n_envs`` is the *local* env count (per shard); ``schedule`` carries
    the global env steps per iteration.  ``shard_id`` feeds the per-shard
    rng fold (a callable so ``lax.axis_index`` can be read inside
    ``shard_map``); ``mean_across``/``sum_across`` reduce reported metrics
    over shards (identity when fused).

    ``publish_interval=0`` is the synchronous loop: actors act on the
    fresh ``state.agent``.  ``publish_interval=P ≥ 1`` is the async loop:
    actors act on ``state.actor_params`` (snapshotted by
    ``init_loop_state(double_buffer=True)``), and at the end of iteration
    ``it`` shard ``d`` republishes its acting copy from the fresh learner
    params iff ``(it + 1 + d) % P == 0`` — the per-shard stagger
    decorrelates the shard clocks, so under ``shard_map`` the shards
    carry *different* parameter ages (0..P-1) and the bounded-staleness
    reduce has real work to do.  ``state.params_age`` is handed to
    ``learn_fn`` so that reduce can weight this shard's gradient.  At
    ``P=1`` every shard republishes every iteration and the async loop is
    the synchronous one (asserted trajectory-exact in
    tests/test_async_executor.py).

    ``external_publish=True`` (wall-clock mode, DESIGN.md §10) keeps the
    async acting-copy *reads* but removes the in-program republish: the
    host runtime owns the publish, performing a real device→host
    parameter transfer between chunks and rewriting
    ``actor_params``/``params_age`` on the carried state
    (``launch/multiprocess.py``).  ``params_age`` then just increments
    every iteration so the staleness-weighted reduce still sees honest
    ages between host publishes.
    """
    if external_publish and not publish_interval:
        raise ValueError(
            "external_publish=True needs publish_interval ≥ 1: the host "
            "publish rewrites the async acting copy, which only exists "
            "on the double-buffered (publish_interval > 0) loop")
    schedule = schedule or RatioSchedule.from_config(cfg, n_envs)
    actor_step = make_actor_step(agent, v_step, n_envs)
    learn_fn = learn_fn or make_learner_step(agent, replay, cfg)
    mean_across = mean_across or (lambda x: x)
    sum_across = sum_across or (lambda x: x)

    def step(state: LoopState) -> Tuple[LoopState, Dict[str, jax.Array]]:
        rng_next, k = jax.random.split(state.rng)
        sid = shard_id() if callable(shard_id) else shard_id
        k = jax.random.fold_in(k, sid)
        k_act, k_env, k_sample = jax.random.split(k, 3)

        # 1. parallel actors — on the delayed double-buffered copy when
        #    async, on the fresh learner params when synchronous
        acting = (agent.with_acting_params(state.agent, state.actor_params)
                  if publish_interval else state.agent)
        eps = epsilon_schedule(cfg, state.env_steps)
        env_state, obs_next, ep_ret, last_ret, transitions = actor_step(
            acting, state.env_state, state.obs,
            state.episode_return, state.last_return, k_act, k_env, eps)

        # 2. lazy write, phase 1: zero the in-flight slots' leaf
        #    priorities (propagation deferred to the flush below)
        lazy = cfg.lazy_replay
        replay_state, slots = replay.insert_begin(state.replay, n_envs,
                                                  lazy=lazy)

        # 3. THE flush boundary: one merged upward-propagation pass per
        #    iteration, coalescing the previous iteration's priority
        #    updates + insert-commit with this iteration's insert-begin.
        #    After this the tree is consistent and the in-flight slots
        #    are unsampleable (lazy ≡ eager bit-exact at this point).
        if lazy:
            replay_state = replay.flush(replay_state)

        # 4. parallel learners on the flushed tree state, at the scheduled
        #    collection/consumption ratio — always on the fresh params
        it = state.env_steps // schedule.env_steps_per_iter
        can_learn = (state.env_steps >= cfg.warmup) & (it % schedule.period == 0)
        age = state.params_age if publish_interval else jnp.zeros((), jnp.int32)

        def do_learn(args):
            agent_state, rstate, ef = args
            acc = {k: jnp.zeros(()) for k in LEARN_METRIC_KEYS}
            for i in range(schedule.learns):
                if lazy and i:
                    # extra learner calls in the same event must also
                    # sample a consistent tree: flush the previous
                    # call's priority write-back first
                    rstate = replay.flush(rstate)
                ki = jax.random.fold_in(k_sample, i)
                agent_state, rstate, lmetrics, ef = learn_fn(
                    agent_state, rstate, ki, age=age, ef=ef)
                acc = {k: acc[k] + lmetrics[k] for k in acc}
            means = {k: v / schedule.learns for k, v in acc.items()}
            return (agent_state, rstate, means,
                    state.learn_steps + schedule.learns, ef)

        def skip_learn(args):
            agent_state, rstate, ef = args
            zeros = {k: jnp.zeros(()) for k in LEARN_METRIC_KEYS}
            return agent_state, rstate, zeros, state.learn_steps, ef

        agent_state, replay_state, lmetrics, learn_steps, ef_error = jax.lax.cond(
            can_learn, do_learn, skip_learn,
            (state.agent, replay_state, state.ef_error))

        # 6. lazy write, phase 3: storage write + P_max restore (the
        #    leaf write is eager, its propagation rides the next flush)
        replay_state = replay.insert_commit(replay_state, slots, transitions,
                                            lazy=lazy)

        # 7. async publish: refresh this shard's acting copy from the
        #    fresh learner params on its (staggered) publish tick —
        #    unless the host runtime owns the publish (wall-clock mode:
        #    real D2H transfer between chunks, age just keeps counting)
        if publish_interval and external_publish:
            actor_params = state.actor_params
            params_age = state.params_age + 1
        elif publish_interval:
            publish = (it + 1 + sid) % publish_interval == 0
            actor_params = jax.tree.map(
                lambda fresh, held: jnp.where(publish, fresh, held),
                agent.params_for_acting(agent_state), state.actor_params)
            params_age = jnp.where(publish, 0, state.params_age + 1)
        else:
            actor_params, params_age = state.actor_params, state.params_age

        new_state = LoopState(
            agent=agent_state,
            replay=replay_state,
            env_state=env_state,
            obs=obs_next,
            rng=rng_next,
            env_steps=state.env_steps + schedule.env_steps_per_iter,
            episode_return=ep_ret,
            last_return=last_ret,
            learn_steps=learn_steps,
            actor_params=actor_params,
            params_age=params_age,
            ef_error=ef_error,
        )
        metrics = {
            "loss": mean_across(lmetrics["loss"]),
            "mean_episode_return": mean_across(jnp.mean(last_ret)),
            "env_steps": new_state.env_steps,
            "learn_steps": learn_steps,
            "buffer_size": sum_across(replay_state.count),
            "epsilon": eps,
            "compress_error_norm": mean_across(
                lmetrics["compress_error_norm"]),
        }
        assert set(metrics) == set(METRIC_KEYS)
        return new_state, metrics

    return step


def make_parallel_step(
    agent: Agent,
    replay: PrioritizedReplay,
    v_step: Callable,
    cfg: LoopConfig,
    n_envs: int,
):
    """Returns jit-able parallel_step(state) → (state, metrics) — the
    fused single-device composition (compat wrapper over ``make_step``)."""
    return make_step(agent, replay, v_step, cfg, n_envs)


def init_loop_state(
    agent: Agent,
    replay,
    v_reset: Callable,
    key: jax.Array,
    n_envs: int,
    shard_id: Union[int, jax.Array] = 0,
    double_buffer: bool = False,
    ef_buffer: bool = False,
    overlap: bool = False,
) -> LoopState:
    """Initial state.  ``shard_id`` decorrelates per-shard env resets while
    agent params (from the unfolded key) stay replicated across shards.
    ``double_buffer`` fills the async acting copy (``actor_params`` at age
    0, i.e. identical to the fresh params); ``ef_buffer`` fills the
    zero-initialized error-feedback buffer of the compressed cross-pod
    reduce (the gradient pytree of agents with the grads/apply_grads
    split matches ``state.params``, so params is the template);
    ``overlap`` widens it to the double-buffered reduce's ``{"ef",
    "prev_mean", "prev_partial"}`` triple — the quantizer residual plus
    the zero-initialized previous-event pod mean and intra-pod partial
    (``make_grad_reducer(..., overlap=True)``).  The synchronous/
    uncompressed executors leave these fields as empty pytrees — no
    memory overhead."""
    k1, k2, k3 = jax.random.split(key, 3)
    env_state, obs = v_reset(jax.random.fold_in(k1, shard_id))
    agent_state = agent.init(k2)
    return LoopState(
        agent=agent_state,
        replay=replay.init(),
        env_state=env_state,
        obs=obs,
        rng=k3,
        env_steps=jnp.zeros((), jnp.int32),
        episode_return=jnp.zeros((n_envs,)),
        last_return=jnp.zeros((n_envs,)),
        learn_steps=jnp.zeros((), jnp.int32),
        actor_params=(agent.params_for_acting(agent_state)
                      if double_buffer else ()),
        params_age=jnp.zeros((), jnp.int32) if double_buffer else (),
        ef_error=(({"ef": compress.init_error(agent_state.params),
                    "prev_mean": compress.init_error(agent_state.params),
                    "prev_partial": compress.init_error(agent_state.params)}
                   if overlap else compress.init_error(agent_state.params))
                  if ef_buffer else ()),
    )


def train(
    agent: Agent,
    replay: PrioritizedReplay,
    v_reset: Callable,
    v_step: Callable,
    cfg: LoopConfig,
    n_envs: int,
    iterations: int,
    key: jax.Array,
    log_every: int = 0,
    scan_chunk: int = 64,
) -> Tuple[LoopState, Dict[str, jax.Array]]:
    """Run the full fused loop — a thin wrapper over ``FusedExecutor``
    for callers that already hold (v_reset, v_step) instead of an env
    factory."""
    from repro.runtime.executors import FusedExecutor  # lazy: avoid cycle

    ex = FusedExecutor(agent, replay, lambda _n: (None, v_reset, v_step),
                       cfg, n_envs, scan_chunk=scan_chunk)
    return ex.train(iterations, key, log_every)
