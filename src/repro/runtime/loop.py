"""The paper's training loop (Alg. 1) with lazy-write overlap (§IV-D2).

``parallel_step`` is one fused iteration:

    1. ACTORS   — ε-greedy act on E vectorized envs, env step           (§V-A)
    2. INSERT-BEGIN — zero in-flight slot priorities (lazy write phase 1)
    3. LEARNERS — sample B from the tree state of (2), TD update        (§V-B)
    4. PRIORITY UPDATE — write-after-read tolerated                    (§IV-D3)
    5. INSERT-COMMIT — storage write + P_max restore (lazy write phase 3)

Step 3 never depends on step 5's storage write (in-flight slots are
invisible by construction), so XLA schedules the transition DMA
concurrently with learner compute — the same overlap the paper's lock
split buys on a multicore CPU.

``update_interval`` (actor steps per learn) matches the paper's desired
collection/consumption ratio; the DSE (dse.py) chooses parallelism so
the realized ratio hits it.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.agents.base import Agent, AgentState
from repro.core.replay import PrioritizedReplay, ReplayState

Pytree = Any


class LoopState(NamedTuple):
    agent: AgentState
    replay: ReplayState
    env_state: Pytree
    obs: jax.Array
    rng: jax.Array
    env_steps: jax.Array
    episode_return: jax.Array     # running per-env return accumulator
    last_return: jax.Array        # most recently finished episode returns


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    batch_size: int = 128
    update_interval: int = 1      # env steps per learn step (paper ratio)
    learns_per_step: int = 1      # parallel learners per iteration
    warmup: int = 1000            # env steps before learning starts
    epsilon: float = 0.1
    beta: float = 0.4             # PER importance exponent


def make_parallel_step(
    agent: Agent,
    replay: PrioritizedReplay,
    v_step: Callable,
    cfg: LoopConfig,
    n_envs: int,
):
    """Returns jit-able parallel_step(state) → (state, metrics)."""

    def parallel_step(state: LoopState) -> Tuple[LoopState, Dict[str, jax.Array]]:
        rng, k_act, k_env, k_sample = jax.random.split(state.rng, 4)

        # 1. parallel actors (no weight mutation → no sync; paper §V-A)
        actions = agent.act(state.agent, state.obs, k_act, cfg.epsilon)
        env_state, obs_next, rew, done, true_next = v_step(
            state.env_state, actions, k_env)
        ep_ret = state.episode_return + rew
        last_ret = jnp.where(done, ep_ret, state.last_return)
        ep_ret = jnp.where(done, 0.0, ep_ret)

        transitions = {
            "obs": state.obs,
            "action": actions,
            "reward": rew,
            "next_obs": true_next,
            "done": done.astype(jnp.float32),
        }

        # 2. lazy write, phase 1: in-flight slots become unsampleable
        replay_state, slots = replay.insert_begin(state.replay, n_envs)

        # 3. parallel learners on the phase-1 tree state
        can_learn = state.env_steps >= cfg.warmup

        def do_learn(args):
            agent_state, rstate = args
            metrics = None
            for i in range(cfg.learns_per_step):
                ki = jax.random.fold_in(k_sample, i)
                idx, items, is_w = replay.sample(
                    rstate, ki, cfg.batch_size, cfg.beta)
                agent_state, metrics, td = agent.learn(agent_state, items, is_w)
                # 4. priority update (write-after-read tolerated, §IV-D3)
                rstate = replay.update_priorities(rstate, idx, td)
            return agent_state, rstate, metrics["loss"]

        def skip_learn(args):
            agent_state, rstate = args
            return agent_state, rstate, jnp.zeros(())

        agent_state, replay_state, loss = jax.lax.cond(
            can_learn, do_learn, skip_learn, (state.agent, replay_state))

        # 5. lazy write, phase 3: storage write + P_max restore
        replay_state = replay.insert_commit(replay_state, slots, transitions)

        new_state = LoopState(
            agent=agent_state,
            replay=replay_state,
            env_state=env_state,
            obs=obs_next,
            rng=rng,
            env_steps=state.env_steps + n_envs,
            episode_return=ep_ret,
            last_return=last_ret,
        )
        metrics = {
            "loss": loss,
            "mean_episode_return": jnp.mean(last_ret),
            "env_steps": new_state.env_steps,
            "buffer_size": replay_state.count,
        }
        return new_state, metrics

    return parallel_step


def init_loop_state(
    agent: Agent,
    replay: PrioritizedReplay,
    v_reset: Callable,
    key: jax.Array,
    n_envs: int,
) -> LoopState:
    k1, k2, k3 = jax.random.split(key, 3)
    env_state, obs = v_reset(k1)
    return LoopState(
        agent=agent.init(k2),
        replay=replay.init(),
        env_state=env_state,
        obs=obs,
        rng=k3,
        env_steps=jnp.zeros((), jnp.int32),
        episode_return=jnp.zeros((n_envs,)),
        last_return=jnp.zeros((n_envs,)),
    )


def train(
    agent: Agent,
    replay: PrioritizedReplay,
    v_reset: Callable,
    v_step: Callable,
    cfg: LoopConfig,
    n_envs: int,
    iterations: int,
    key: jax.Array,
    log_every: int = 0,
    scan_chunk: int = 64,
) -> Tuple[LoopState, Dict[str, jax.Array]]:
    """Run the full loop; iterations are chunked through lax.scan."""
    step = make_parallel_step(agent, replay, v_step, cfg, n_envs)
    state = init_loop_state(agent, replay, v_reset, key, n_envs)

    @jax.jit
    def chunk(state):
        def body(s, _):
            s, m = step(s)
            return s, m
        return jax.lax.scan(body, state, None, length=scan_chunk)

    history = []
    done_iters = 0
    while done_iters < iterations:
        state, metrics = chunk(state)
        done_iters += scan_chunk
        last = jax.tree.map(lambda x: x[-1], metrics)
        history.append(last)
        if log_every and done_iters % log_every < scan_chunk:
            print(f"iter={done_iters} "
                  f"return={float(last['mean_episode_return']):.1f} "
                  f"loss={float(last['loss']):.4f} "
                  f"buffer={int(last['buffer_size'])}")
    return state, jax.tree.map(lambda *xs: jnp.stack(xs), *history)
