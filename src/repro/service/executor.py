"""In-process service executor (DESIGN.md §11).

``ServiceExecutor`` drives a ``ReplayService``'s shard states with the
split actor/learner programs of ``runtime/loop.py`` — the host owns the
window loop, the ``RateLimiter`` owns the learn cadence, and every
window compiles to ONE jit program composed from the service's pure
shard ops.  This is the single-process form of the decoupled runtime:
the same ops the TCP server applies per request, driven lockstep.

**Equivalence contract** (tested in tests/test_service.py): at
``n_shards=1`` with the limiter derived from the loop's ratio
(``RateLimiter.for_loop``), the executor is bit-exact with
``FusedExecutor`` from the same seed.  Two ingredients make that true:

- the window program replicates ``make_step``'s op order — actor →
  insert_begin(lazy) → flush (the admission window boundary) → L×
  (sample → learn → priority write-back, inter-learn flushes) →
  insert_commit(lazy) — and its exact rng chain
  (``split → fold_in(shard) → split3``, ``fold_in(k_sample, i)`` per
  learn), all inside one jit so XLA sees the same program;
- the greedy limiter drain (take batch-sized sample admissions until
  the debt band blocks) reproduces ``RatioSchedule``'s cadence exactly
  when ``error_buffer = max(batch, spi · n_envs)`` — the per-window
  sample quota — and ``warmup`` is a multiple of the learn period's env
  steps (otherwise the limiter starts learning up to one period earlier
  than the modulo-phased schedule; the *ratio* still holds, the phase
  differs).

At ``n_shards > 1`` the window routes each transition batch round-robin
across shards and samples stratified (B/N per shard) with importance
weights normalized against the cross-shard global distribution — the
host-composed form of ``ShardedPrioritizedReplay``'s psum/pmax math.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.agents.base import Agent
from repro.runtime.executors import Executor
from repro.runtime.loop import (METRIC_KEYS, LoopConfig, LoopState,
                                RatioSchedule, epsilon_schedule,
                                make_actor_step, make_learner_step)
from repro.service.rate_limiter import RateLimiter
from repro.service.server import ReplayService

Pytree = Any


class ServiceExecutor(Executor):
    """Train against an in-process ``ReplayService``.

    The service's shard states ride inside the carried ``LoopState``
    (``state.replay`` is the tuple of shard states), so the standard
    ``Executor.run`` driver works unchanged; the service object supplies
    the pure shard ops, the router policy and the rate limiter.
    """

    def __init__(
        self,
        agent: Agent,
        service: ReplayService,
        env_fn: Callable[[int], tuple],
        cfg: LoopConfig,
        n_envs: int,
        scan_chunk: int = 64,
        rate_limiter: Optional[RateLimiter] = None,
    ):
        n = service.config.n_shards
        if cfg.batch_size % n:
            raise ValueError(
                f"batch_size={cfg.batch_size} must divide evenly over "
                f"n_shards={n} (stratified sampling draws B/N per shard)")
        self.agent = agent
        self.service = service
        self.cfg = cfg
        self.n_envs = n_envs
        self.n_shards = n
        self.scan_chunk = scan_chunk
        self.spec, self._v_reset, self._v_step = env_fn(n_envs)
        self.schedule = RatioSchedule.from_config(cfg, n_envs)
        self.limiter = (rate_limiter or service.limiter
                        or RateLimiter.from_schedule(
                            self.schedule, cfg.batch_size, cfg.warmup))
        self._window_count = 0
        self._actor = make_actor_step(agent, self._v_step, n_envs)
        self._learn1 = make_learner_step(agent, service.replay, cfg)
        self._windows: Dict[Tuple[int, int], Callable] = {}
        self._chunks: Dict[int, Callable] = {}

    # -- the window program (one jit per (target shard, learn count)) -------

    def _window(self, target: int, n_learns: int) -> Callable:
        rb, cfg, n = self.service.replay, self.cfg, self.n_shards
        per = cfg.batch_size // n

        def stratified_learn(agent_state, states, ki):
            # host-composed ShardedPrioritizedReplay math: global stats
            # and the global max normalizer reduce over the shard tuple
            # instead of psum/pmax over a mesh axis
            g_tot = sum(s.tree[0] for s in states)
            g_cnt = sum(s.count for s in states)
            idxs, pris, parts = [], [], []
            for i, s in enumerate(states):
                u = jax.random.uniform(jax.random.fold_in(ki, i), (per,))
                if rb.config.fused_sample_gather_resolved:
                    idx, pri, items = rb.ops.sample_gather(
                        rb.spec, s.tree, u, s.storage)
                else:
                    idx, pri = rb.ops.sample(rb.spec, s.tree, u)
                    items = rb._gather(s.storage, idx)
                idxs.append(idx)
                pris.append(pri)
                parts.append(items)
            pri = jnp.concatenate(pris)
            prob = pri / jnp.maximum(g_tot, 1e-12)
            w = (jnp.maximum(g_cnt, 1).astype(jnp.float32)
                 * jnp.maximum(prob, 1e-12)) ** (-cfg.beta)
            w = jnp.where(pri > 0, w, 0.0)
            w = w / jnp.maximum(jnp.max(w), 1e-12)
            items = jax.tree.map(lambda *xs: jnp.concatenate(xs), *parts)
            agent_state, metrics, td = self.agent.learn(
                agent_state, items, w)
            states = tuple(
                rb.update_priorities(s, idxs[i], td[i * per:(i + 1) * per],
                                     lazy=True)
                for i, s in enumerate(states))
            return agent_state, states, metrics["loss"]

        def window(state: LoopState):
            # the exact rng chain of make_step (shard_id 0: the service
            # executor is one writer fleet — per-process decorrelation
            # happens through the service, not an rng fold)
            rng_next, k = jax.random.split(state.rng)
            k = jax.random.fold_in(k, 0)
            k_act, k_env, k_sample = jax.random.split(k, 3)
            eps = epsilon_schedule(cfg, state.env_steps)

            # 1. actor program
            env_state, obs_next, ep_ret, last_ret, transitions = self._actor(
                state.agent, state.env_state, state.obs,
                state.episode_return, state.last_return, k_act, k_env, eps)

            # 2. writer transaction phase 1 on the routed shard
            states = list(state.replay)
            states[target], slots = rb.insert_begin(states[target],
                                                    self.n_envs, lazy=True)

            # 3. the admission-window boundary: one propagation pass per
            #    shard with pending lazy writes
            states = [rb.flush(s) for s in states]

            # 4. learner program, as many times as the limiter admitted
            agent_state = state.agent
            loss = jnp.zeros(())
            for i in range(n_learns):
                if i:
                    states = [rb.flush(s) for s in states]
                ki = jax.random.fold_in(k_sample, i)
                if n == 1:
                    agent_state, states[0], lmetrics, _ = self._learn1(
                        agent_state, states[0], ki)
                    loss = loss + lmetrics["loss"]
                else:
                    agent_state, states, l = stratified_learn(
                        agent_state, tuple(states), ki)
                    states = list(states)
                    loss = loss + l

            # 5. writer transaction phase 2
            states[target] = rb.insert_commit(states[target], slots,
                                              transitions, lazy=True)

            new_state = state._replace(
                agent=agent_state,
                replay=tuple(states),
                env_state=env_state,
                obs=obs_next,
                rng=rng_next,
                env_steps=state.env_steps + self.n_envs,
                episode_return=ep_ret,
                last_return=last_ret,
                learn_steps=state.learn_steps + n_learns,
            )
            metrics = {
                "loss": loss / max(1, n_learns),
                "mean_episode_return": jnp.mean(last_ret),
                "env_steps": new_state.env_steps,
                "learn_steps": new_state.learn_steps,
                "buffer_size": sum(s.count for s in states),
                "epsilon": eps,
                "compress_error_norm": jnp.zeros(()),
            }
            assert set(metrics) == set(METRIC_KEYS)
            return new_state, metrics

        return jax.jit(window)

    # -- Executor API -------------------------------------------------------

    def _build_chunk(self, length: int) -> Callable:
        def run(state: LoopState):
            history = []
            for _ in range(length):
                # greedy limiter drain: the learn cadence is whatever
                # flow control admits — RatioSchedule generalized
                n_learns = 0
                while (not self.limiter.stopped
                       and self.limiter.can_sample(self.cfg.batch_size)):
                    self.limiter.note_sample(self.cfg.batch_size)
                    n_learns += 1
                target = self.service.router.route(
                    f"window-{self._window_count}")
                self._window_count += 1
                key = (target, n_learns)
                fn = self._windows.get(key)
                if fn is None:
                    fn = self._windows[key] = self._window(*key)
                state, metrics = fn(state)
                self.limiter.note_insert(self.n_envs)
                history.append(metrics)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *history)
            return state, stacked
        return run

    def init(self, key: jax.Array) -> LoopState:
        k1, k2, k3 = jax.random.split(key, 3)
        env_state, obs = self._v_reset(jax.random.fold_in(k1, 0))
        agent_state = self.agent.init(k2)
        return LoopState(
            agent=agent_state,
            replay=tuple(self.service.replay.init()
                         for _ in range(self.n_shards)),
            env_state=env_state,
            obs=obs,
            rng=k3,
            env_steps=jnp.zeros((), jnp.int32),
            episode_return=jnp.zeros((self.n_envs,)),
            last_return=jnp.zeros((self.n_envs,)),
            learn_steps=jnp.zeros((), jnp.int32),
        )

    def realized_samples_per_insert(self) -> float:
        return self.limiter.realized_samples_per_insert()
