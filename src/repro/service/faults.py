"""Deterministic fault injection for the replay service (DESIGN.md §14).

Long-running actor/learner fleets see connection drops, slow replies and
server crashes as a matter of course; the service's resilience contracts
(client reconnect with idempotent appends, snapshot restore, bounded
retry before clean exit) are only real if every one of those failure
modes is *drilled* by tests rather than hoped for.  A ``FaultPlan`` is a
seeded, deterministic schedule of wire-layer faults:

  * **drop-connection-after-N-frames** — the server (per connection) or
    the client (per request) closes the socket on every Nth frame,
    either *before* the frame crosses (request lost — retry must
    resend) or *after* (request applied, reply lost — retry must be
    deduplicated by the per-writer sequence number);
  * **seeded random drops** — ``drop_prob`` draws from a
    ``random.Random(seed)`` stream, so a "random" chaos run replays
    bit-identically under the same plan;
  * **delayed replies** — every Kth reply sleeps ``delay_reply_s``
    before crossing, driving client timeouts into the retry path while
    the original operation is still in flight server-side;
  * **crash-on-Kth-op** — the server dies when the Kth operation of a
    named command arrives: ``hard=True`` is a real ``os._exit`` (the
    multiprocess gang drill — SIGKILL semantics, no flush, no
    goodbye), ``hard=False`` simulates the crash in-process by closing
    the listener and every live connection (the in-process drills and
    the fig_serve ``--fault`` arm), so the restart-from-snapshot path
    runs in seconds inside one test process.

Injection sites are the wire layer only (``service/server.py``'s
handler loop and ``service/client.py``'s request path): faults tear
connections and processes, never the service's in-memory invariants —
exactly the failure model the resilience layer claims to survive.
"""

from __future__ import annotations

import dataclasses
import os
import random
import threading
import time
from typing import Dict, Optional, Tuple

#: exit code of a hard injected crash — the gang launcher treats this
#: (and only this) as the *expected* death of a server it plans to
#: restart from its shard snapshot
CRASH_EXIT_CODE = 42


class InjectedCrash(RuntimeError):
    """Raised on the soft (in-process) crash path after the server has
    been torn down — the handler thread dies without replying."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded, deterministic fault schedule (all counters 1-based).

    ``drop_after_frames=N`` drops on every Nth frame (recurring);
    ``drop_before_send`` selects whether the drop loses the request
    (before dispatch) or the reply (after dispatch — the dedup drill).
    ``crash_on_op="append:40"`` kills the server when the 40th append
    frame arrives, before it is applied.
    """

    seed: int = 0
    drop_after_frames: int = 0        # 0 = never
    drop_before_send: bool = False
    drop_prob: float = 0.0            # seeded per-frame drop probability
    delay_reply_s: float = 0.0
    delay_every: int = 0              # 0 = never
    crash_on_op: str = ""             # "cmd:K", e.g. "append:40"
    hard: bool = False                # os._exit vs in-process teardown

    def __post_init__(self):
        if self.drop_after_frames < 0:
            raise ValueError(f"drop_after_frames={self.drop_after_frames}: "
                             f"must be ≥ 0")
        if not 0.0 <= self.drop_prob <= 1.0:
            raise ValueError(f"drop_prob={self.drop_prob}: must be in [0, 1]")
        if self.crash_on_op:
            self.crash_target  # validates the "cmd:K" shape

    @property
    def crash_target(self) -> Optional[Tuple[str, int]]:
        """(command, 1-based op count) of the scheduled crash, if any."""
        if not self.crash_on_op:
            return None
        cmd, sep, k = self.crash_on_op.partition(":")
        if not sep or not cmd:
            raise ValueError(f"crash_on_op={self.crash_on_op!r}: expected "
                             f"'cmd:K' (e.g. 'append:40')")
        try:
            kth = int(k)
        except ValueError:
            raise ValueError(f"crash_on_op={self.crash_on_op!r}: K must be "
                             f"an integer") from None
        if kth < 1:
            raise ValueError(f"crash_on_op={self.crash_on_op!r}: K must be "
                             f"≥ 1")
        return cmd, kth

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Build a plan from a compact ``key=value,key=value`` string —
        the CLI form the gang launcher passes to worker processes, e.g.
        ``"crash_on_op=append:40,hard=1"``."""
        kw: Dict[str, object] = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            key, sep, val = part.partition("=")
            if not sep:
                raise ValueError(f"fault plan entry {part!r}: expected "
                                 f"key=value")
            field = {f.name: f for f in dataclasses.fields(cls)}.get(key)
            if field is None:
                raise ValueError(
                    f"unknown fault plan field {key!r}: expected one of "
                    f"{sorted(f.name for f in dataclasses.fields(cls))}")
            if field.type == "bool":
                kw[key] = val.lower() in ("1", "true", "yes")
            elif field.type == "int":
                kw[key] = int(val)
            elif field.type == "float":
                kw[key] = float(val)
            else:
                kw[key] = val
        return cls(**kw)  # type: ignore[arg-type]


class ServerFaultInjector:
    """Per-server fault state: frame counters per connection, op
    counters per command, one seeded rng stream.  Thread-safe — handler
    threads consult it concurrently."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._frames: Dict[int, int] = {}
        self._ops: Dict[str, int] = {}
        self._replies = 0
        self._rng = random.Random(plan.seed)
        self.dropped = 0
        self.delayed = 0

    def on_frame(self, conn_id: int, cmd: str) -> Optional[str]:
        """Classify one received frame: None (pass), ``"crash"``,
        ``"drop_request"`` (lose it pre-dispatch) or ``"drop_reply"``
        (apply it, lose the ack)."""
        plan = self.plan
        with self._lock:
            n = self._frames[conn_id] = self._frames.get(conn_id, 0) + 1
            k = self._ops[cmd] = self._ops.get(cmd, 0) + 1
            target = plan.crash_target
            if target is not None and cmd == target[0] and k == target[1]:
                return "crash"
            drop = bool(plan.drop_after_frames
                        and n % plan.drop_after_frames == 0)
            if plan.drop_prob:
                drop = drop or self._rng.random() < plan.drop_prob
            if drop:
                self.dropped += 1
                return ("drop_request" if plan.drop_before_send
                        else "drop_reply")
        return None

    def before_reply(self, cmd: str) -> None:
        """Injected reply latency (sleeps outside the lock)."""
        plan = self.plan
        if not (plan.delay_every and plan.delay_reply_s):
            return
        with self._lock:
            self._replies += 1
            due = self._replies % plan.delay_every == 0
            if due:
                self.delayed += 1
        if due:
            time.sleep(plan.delay_reply_s)

    def crash(self, server) -> None:
        """Execute the scheduled crash.  Hard: the process dies here
        (``os._exit`` — no atexit, no flush: SIGKILL semantics for the
        gang drill).  Soft: tear the server down in-process and kill
        this handler thread via ``InjectedCrash``."""
        if self.plan.hard:
            os._exit(CRASH_EXIT_CODE)
        server.simulate_crash()
        raise InjectedCrash(f"injected crash: {self.plan.crash_on_op}")


class ClientFaultInjector:
    """Client-side drops: every Nth *request attempt* (retries count —
    the schedule stays deterministic under its own consequences) loses
    either the request (pre-send) or the reply (post-send, the dedup
    drill).  Single client, but locked anyway: the client object allows
    cross-thread sharing."""

    def __init__(self, plan: FaultPlan):
        if plan.crash_on_op:
            raise ValueError("crash_on_op is a server-side fault; client "
                             "plans support drops and delays only")
        self.plan = plan
        self._lock = threading.Lock()
        self._requests = 0
        self._rng = random.Random(plan.seed)
        self.dropped = 0

    def on_request(self, cmd: str) -> Optional[str]:
        del cmd
        plan = self.plan
        with self._lock:
            n = self._requests = self._requests + 1
            drop = bool(plan.drop_after_frames
                        and n % plan.drop_after_frames == 0)
            if plan.drop_prob:
                drop = drop or self._rng.random() < plan.drop_prob
            if drop:
                self.dropped += 1
                return ("drop_request" if plan.drop_before_send
                        else "drop_reply")
        return None
