"""Replay-as-a-service (DESIGN.md §11).

The transaction layer of ``core/replay.py`` recast as a standalone
service: N independent ``PrioritizedReplay`` shards behind a router,
multi-writer lazy appends with one tree-propagation ``flush`` per
admission window, and a ``RateLimiter`` that generalizes the loop's
``RatioSchedule`` into explicit flow control between decoupled actor
and learner processes.
"""

from repro.service.faults import (ClientFaultInjector, FaultPlan,
                                  InjectedCrash, ServerFaultInjector)
from repro.service.rate_limiter import RateLimiter, ServiceStopped
from repro.service.router import Router
from repro.service.server import (ConnectionClosed, ReplayService,
                                  ReplayServiceConfig, serve)
from repro.service.client import (ReplayClient, RetryPolicy,
                                  backoff_delays, wait_for_service)
from repro.service.executor import ServiceExecutor

__all__ = [
    "ClientFaultInjector",
    "ConnectionClosed",
    "FaultPlan",
    "InjectedCrash",
    "RateLimiter",
    "RetryPolicy",
    "ServerFaultInjector",
    "ServiceStopped",
    "Router",
    "ReplayService",
    "ReplayServiceConfig",
    "ReplayClient",
    "ServiceExecutor",
    "backoff_delays",
    "serve",
    "wait_for_service",
]
