"""Shard addressing for the replay service (DESIGN.md §11).

A writer's append has to land on exactly one shard, and the choice must
be stable enough that a writer's transitions spread evenly without any
cross-shard coordination.  Two policies:

- ``hash``: shard = hash(writer_id) — every writer owns one shard for
  its whole lifetime (shard-affinity: a writer's appends serialize on
  one shard's ledger, so its own transitions are never reordered across
  shards).  With ≥ n_shards writers this is the fleet default.
- ``round_robin``: shard = next in cyclic order per append — spreads a
  *single* writer across all shards (the in-process executor and
  few-writer gangs would otherwise leave shards empty past warmup).
"""

from __future__ import annotations

import itertools
import threading
import zlib


class Router:
    POLICIES = ("hash", "round_robin")

    def __init__(self, n_shards: int, policy: str = "hash"):
        if n_shards < 1:
            raise ValueError(f"n_shards={n_shards}: must be ≥ 1")
        if policy not in self.POLICIES:
            raise ValueError(
                f"unknown router policy {policy!r}: expected one of "
                f"{self.POLICIES}")
        self.n_shards = n_shards
        self.policy = policy
        self._rr = itertools.count()
        self._lock = threading.Lock()

    def route(self, writer_id: str) -> int:
        """Shard index for one append by ``writer_id``."""
        if self.policy == "hash":
            # stable across processes/runs (python's hash() is salted)
            return zlib.crc32(writer_id.encode()) % self.n_shards
        with self._lock:
            return next(self._rr) % self.n_shards

    def describe(self) -> str:
        return f"{self.policy} over {self.n_shards} shard(s)"
