"""Flow control between decoupled writers and samplers (DESIGN.md §11).

The fused loop couples actors and learners through ``RatioSchedule``:
``update_interval`` realizes an *implicit* samples-per-insert ratio

    spi = batch_size · learns / (period · n_envs · steps)
        = batch_size / update_interval

by construction — both sides run in one program, so the ratio can never
drift.  Once actors and learners are separate processes the coupling has
to become *explicit*: the ``RateLimiter`` tracks cumulative inserts ``i``
and samples ``s`` and keeps the signed sample debt

    D = (i − min_size_to_sample) · spi − s

inside ``±error_buffer``.  Writers are back-pressured (an insert of
``b`` items blocks while ``D + b·spi > error_buffer`` — actors may not
run so far ahead that items churn out of the buffer unsampled) and
samplers block (a sample of ``b`` blocks while ``i < min_size_to_sample``
or ``D − b < −error_buffer`` — learners may not consume the same
experience more often than the configured ratio allows).  Equivalently
the realized ratio ``s / (i − min_size_to_sample)`` is pinned to

    spi − error_buffer/(i − min) ≤ realized ≤ spi + error_buffer/(i − min)

i.e. explicit *min/max samples-per-insert* bounds that tighten as the
run progresses.  ``min_size_to_sample`` generalizes the loop's
``warmup_steps``.
"""

from __future__ import annotations

import threading
import time
from typing import Optional


class ServiceStopped(Exception):
    """Raised by blocking admissions after ``stop()`` — the shutdown path
    for writers parked in backpressure when the learner finishes."""


class RateLimiter:
    def __init__(self, samples_per_insert: float, min_size_to_sample: int,
                 error_buffer: float):
        if samples_per_insert <= 0:
            raise ValueError(
                f"samples_per_insert={samples_per_insert}: must be > 0")
        if min_size_to_sample < 1:
            raise ValueError(
                f"min_size_to_sample={min_size_to_sample}: must be ≥ 1")
        if error_buffer < samples_per_insert:
            # a buffer tighter than one insert's worth of credit can
            # wedge both sides before steady state is ever reached
            raise ValueError(
                f"error_buffer={error_buffer}: must be ≥ samples_per_insert "
                f"({samples_per_insert}) or the limiter can deadlock")
        self.samples_per_insert = float(samples_per_insert)
        self.min_size_to_sample = int(min_size_to_sample)
        self.error_buffer = float(error_buffer)
        self._cond = threading.Condition()
        self._inserts = 0
        self._samples = 0
        self._stopped = False

    @classmethod
    def for_loop(cls, batch_size: int, update_interval: int,
                 warmup_steps: int, insert_burst: int = 1) -> "RateLimiter":
        """The limiter equivalent of ``RatioSchedule``: one ``batch_size``
        sample per ``update_interval`` env steps after ``warmup_steps``.
        ``insert_burst`` is the writer's append granularity (a gang actor
        appends a whole rollout chunk at once); the band must absorb one
        full burst's sample credit on top of a batch of debt or steady
        state wedges."""
        spi = batch_size / max(1, update_interval)
        return cls(samples_per_insert=spi,
                   min_size_to_sample=max(1, warmup_steps),
                   error_buffer=2.0 * max(batch_size, spi * insert_burst))

    @classmethod
    def from_schedule(cls, schedule, batch_size: int,
                      warmup_steps: int) -> "RateLimiter":
        """The *exact* limiter form of a ``RatioSchedule``: with
        ``error_buffer = learns · batch`` (the per-event sample quota) a
        greedy sampler drain admits exactly ``schedule.learns`` batches
        every ``schedule.period`` windows — the flow-control band is
        tight enough that the schedule's cadence is the only admissible
        trajectory (the ServiceExecutor equivalence contract,
        DESIGN.md §11)."""
        spi = (schedule.learns * batch_size
               / (schedule.period * schedule.env_steps_per_iter))
        return cls(samples_per_insert=spi,
                   min_size_to_sample=max(1, warmup_steps),
                   error_buffer=float(schedule.learns * batch_size))

    # -- accounting ---------------------------------------------------------

    # The three predicates below read the guarded counters without taking
    # self._cond themselves: every caller already holds it — the public
    # queries lock explicitly, and the await_* lambdas are evaluated
    # inside _await's `with self._cond:` loop.  Taking the (non-reentrant)
    # Condition here would deadlock.

    def _debt(self) -> float:  # repro-lint: disable=L301(callers hold self._cond)
        return ((self._inserts - self.min_size_to_sample)
                * self.samples_per_insert - self._samples)

    def _insert_ok(self, batch: int) -> bool:
        return (self._debt() + batch * self.samples_per_insert
                <= self.error_buffer)

    def _sample_ok(self, batch: int) -> bool:  # repro-lint: disable=L301(callers hold self._cond)
        return (self._inserts >= self.min_size_to_sample
                and self._debt() - batch >= -self.error_buffer)

    # -- non-blocking queries (host-driven executors poll these) ------------

    def can_insert(self, batch: int) -> bool:
        with self._cond:
            return self._insert_ok(batch)

    def can_sample(self, batch: int) -> bool:
        with self._cond:
            return self._sample_ok(batch)

    def note_insert(self, batch: int) -> None:
        with self._cond:
            self._inserts += batch
            self._cond.notify_all()

    def note_sample(self, batch: int) -> None:
        with self._cond:
            self._samples += batch
            self._cond.notify_all()

    # -- blocking admissions (service request threads) ----------------------

    def await_insert(self, batch: int,
                     timeout: Optional[float] = None) -> None:
        """Block until an insert of ``batch`` is admitted, then count it."""
        self._await(lambda: self._insert_ok(batch), timeout, "insert")
        with self._cond:
            self._inserts += batch
            self._cond.notify_all()

    def await_sample(self, batch: int,
                     timeout: Optional[float] = None) -> None:
        """Block until a sample of ``batch`` is admitted, then count it."""
        self._await(lambda: self._sample_ok(batch), timeout, "sample")
        with self._cond:
            self._samples += batch
            self._cond.notify_all()

    def _await(self, ok, timeout: Optional[float], what: str) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                if self._stopped:
                    raise ServiceStopped(f"{what} admission after stop()")
                if ok():
                    return
                wait = None if deadline is None else deadline - time.monotonic()
                if wait is not None and wait <= 0:
                    raise TimeoutError(
                        f"rate limiter: {what} not admitted within "
                        f"{timeout:.1f}s (inserts={self._inserts}, "
                        f"samples={self._samples}, debt={self._debt():.1f}, "
                        f"error_buffer={self.error_buffer:.1f})")
                self._cond.wait(wait)

    def stop(self) -> None:
        """Wake every parked waiter with ``ServiceStopped`` — writers in
        backpressure must not hang when the learner finishes first."""
        with self._cond:
            self._stopped = True
            self._cond.notify_all()

    @property
    def stopped(self) -> bool:
        with self._cond:
            return self._stopped

    def restore_counts(self, inserts: int, samples: int) -> None:
        """Reset the debt counters to a snapshot's values (server
        restart, DESIGN.md §14) — the flow-control band resumes exactly
        where the crashed server left it instead of re-running warmup."""
        if inserts < 0 or samples < 0:
            raise ValueError(f"restore_counts({inserts}, {samples}): "
                             f"counts must be ≥ 0")
        with self._cond:
            self._inserts = int(inserts)
            self._samples = int(samples)
            self._cond.notify_all()

    # -- stats --------------------------------------------------------------

    @property
    def inserts(self) -> int:
        with self._cond:
            return self._inserts

    @property
    def samples(self) -> int:
        with self._cond:
            return self._samples

    def realized_samples_per_insert(self) -> float:
        """Realized ratio past warmup — the quantity the configured
        ``samples_per_insert`` bounds to within ±error_buffer/(i−min)."""
        with self._cond:
            denom = self._inserts - self.min_size_to_sample
            return self._samples / denom if denom > 0 else 0.0

    def stats(self) -> dict:
        with self._cond:
            denom = self._inserts - self.min_size_to_sample
            return {
                "inserts": self._inserts,
                "samples": self._samples,
                "samples_per_insert": self.samples_per_insert,
                "realized_spi": self._samples / denom if denom > 0 else 0.0,
                "error_buffer": self.error_buffer,
                "min_size_to_sample": self.min_size_to_sample,
                "stopped": self._stopped,
            }
