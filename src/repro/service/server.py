"""The replay server (DESIGN.md §11).

``ReplayService`` is the transaction layer of ``core/replay.py`` recast
as a long-lived service: N independent ``PrioritizedReplay`` shards
addressed by a ``Router``, written by any number of writers through the
lazy ledger (every append is leaf-only + ledger bump; the interior
rebuild happens in **one** ``flush`` per shard per admission window —
the window boundary is the next sample that touches the shard), and
sampled by learners with importance weights computed against the
*global* cross-shard priority distribution (the same stratified-sample
math as ``ShardedPrioritizedReplay``, with the psum/pmax collectives
replaced by host-side reductions over the shard list).

Flow control is delegated to the ``RateLimiter``: append admissions
back-pressure writers, sample admissions block the learner, and the
realized samples-per-insert ratio is pinned to the configured one.

The wire layer is deliberately minimal: length-prefixed pickles over
localhost TCP (the gang launcher binds 127.0.0.1 and every worker runs
on the same host — this is a research harness transport, not an
authenticated RPC stack).  All numerical payloads cross as numpy.
"""

from __future__ import annotations

import dataclasses
import json
import pickle
import socket
import socketserver
import struct
import sys
import threading
import time
from collections import deque
from functools import partial
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.replay import PrioritizedReplay, ReplayConfig, ReplayState
from repro.service.faults import FaultPlan, InjectedCrash, ServerFaultInjector
from repro.service.rate_limiter import RateLimiter, ServiceStopped
from repro.service.router import Router

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ReplayServiceConfig:
    capacity_per_shard: int
    n_shards: int = 1
    fanout: int = 128
    alpha: float = 0.6
    eps: float = 1e-6
    backend: Optional[str] = None   # TreeOps backend: "xla" | "pallas"
    fused_sample_gather: Optional[bool] = None
    router: str = "hash"            # Router.POLICIES
    seed: int = 0                   # server-side sample rng stream

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards={self.n_shards}: must be ≥ 1")
        if self.capacity_per_shard < 1:
            raise ValueError(
                f"capacity_per_shard={self.capacity_per_shard}: must be ≥ 1")


class ReplayService:
    """Host-side service core.  Thread-safe: every shard mutation runs
    under one lock (the jitted shard ops release the GIL into XLA, so
    writer handler threads still overlap compute with the wire); blocking
    admissions happen *outside* the lock in the ``RateLimiter``."""

    def __init__(self, config: ReplayServiceConfig, example_item: Pytree,
                 rate_limiter: Optional[RateLimiter] = None):
        self.config = config
        self.replay = PrioritizedReplay(
            ReplayConfig(
                capacity=config.capacity_per_shard,
                fanout=config.fanout,
                alpha=config.alpha,
                eps=config.eps,
                backend=config.backend,
                fused_sample_gather=config.fused_sample_gather,
            ),
            example_item,
        )
        self.router = Router(config.n_shards, config.router)
        self.limiter = rate_limiter
        self.states: List[ReplayState] = [
            self.replay.init() for _ in range(config.n_shards)]
        self._lock = threading.RLock()
        self._stopped = threading.Event()
        # jitted shard ops — one cache for all shards (same shapes)
        self._append_op = jax.jit(partial(self.replay.append, lazy=True))
        self._update_op = jax.jit(
            partial(self.replay.update_priorities, lazy=True))
        self._sample_fns: Dict[int, Any] = {}
        self._sample_key = jax.random.PRNGKey(config.seed)
        # counters + learner-facing bookkeeping
        self._inserts = 0
        self._samples = 0
        self._sample_count = 0
        self._outstanding: Dict[int, Tuple[np.ndarray, ...]] = {}
        # idempotent appends (DESIGN.md §14): per-writer last-applied
        # sequence number + the set of seqs currently being applied.
        # A retry for an in-flight seq parks on the condition until the
        # original lands, then reads the dedup verdict — this closes the
        # retry-while-original-parked race without double-applying.
        self._seq_cond = threading.Condition(self._lock)
        self._writer_seq: Dict[str, int] = {}
        self._writer_appends: Dict[str, int] = {}
        self._inflight: Dict[str, Set[int]] = {}
        self._dup_appends = 0
        self._appends = 0
        # durability: optional snapshot sink (attach_snapshots)
        self._ckpt = None
        self._snap_every = 0
        self._snap_step = 0
        self._snapshots_taken = 0
        self._restored_step: Optional[int] = None
        # param channel (PUT/GET with versions; blobs are opaque bytes)
        self._params_cond = threading.Condition()
        self._params_blob: Optional[bytes] = None
        self._params_version = 0
        # writer-reported finished-episode returns (progress metric)
        self._returns: deque = deque(maxlen=256)

    # -- write path ---------------------------------------------------------

    def append(self, writer_id: str, items: Pytree, *,
               returns: Optional[List[float]] = None,
               timeout: Optional[float] = None,
               seq: Optional[int] = None) -> Dict[str, Any]:
        """One writer transaction: rate-limited admission, route to a
        shard, lazy leaf-only append (sampleable at the shard's next
        flush).  Returns progress the writer needs (global insert clock
        for its ε-schedule, current params version, stop flag) so the
        common actor loop costs one round trip per batch.

        ``seq`` (per-writer, monotonic, allocated client-side *before*
        the retry loop) makes the transaction idempotent: a seq at or
        below the writer's last applied one is acknowledged without
        re-inserting, so retry-after-reconnect — including the case
        where the reply, not the request, was lost — applies exactly
        once."""
        batch = int(jax.tree.leaves(items)[0].shape[0])
        if seq is not None:
            dup = self._admit_seq(writer_id, int(seq), timeout)
            if dup is not None:
                return dup
        try:
            if self.limiter is not None:
                try:
                    self.limiter.await_insert(batch, timeout)
                except ServiceStopped:
                    return {"stopped": True, "inserts": self.total_inserts(),
                            "params_version": self.params_version()}
            shard = self.router.route(writer_id)
            with self._lock:
                self.states[shard] = self._append_op(self.states[shard],
                                                     items)
                self._inserts += batch
                self._appends += 1
                if seq is not None:
                    self._writer_seq[writer_id] = int(seq)
                    self._writer_appends[writer_id] = (
                        self._writer_appends.get(writer_id, 0) + 1)
                if returns:
                    self._returns.extend(float(r) for r in returns)
                total = self._inserts
                if self._snap_every and self._appends % self._snap_every == 0:
                    # durable ack: the snapshot lands before the reply,
                    # so an acked append is a restored append — this is
                    # what makes per-writer counters bit-identical
                    # across a server crash (snapshot_every_appends=1
                    # in the drills; larger periods trade the tail of
                    # un-acked work for throughput, and dedup-on-retry
                    # still keeps the restore exactly-once)
                    self._save_snapshot_locked()
        finally:
            if seq is not None:
                self._release_seq(writer_id, int(seq))
        # "applied" is the exactly-once ack: set on real application and
        # on dedup (the original applied; this reply is its ack), absent
        # on the not-applied ServiceStopped path — clients count acked
        # appends off it, and the restart drill compares those counts
        # against the server's per-writer applied table
        return {"stopped": self._stopped.is_set(), "shard": shard,
                "applied": True, "inserts": total,
                "params_version": self.params_version()}

    def _admit_seq(self, writer_id: str, seq: int,
                   timeout: Optional[float]) -> Optional[Dict[str, Any]]:
        """Claim ``seq`` for application, or return the dedup reply if
        it already applied.  A retry that races its own original (still
        parked in limiter backpressure) waits here for the verdict."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._seq_cond:
            while True:
                if seq <= self._writer_seq.get(writer_id, 0):
                    self._dup_appends += 1
                    return {"stopped": self._stopped.is_set(),
                            "deduped": True, "applied": True,
                            "inserts": self._inserts,
                            "params_version": self.params_version()}
                inflight = self._inflight.setdefault(writer_id, set())
                if seq not in inflight:
                    inflight.add(seq)
                    return None
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"append seq {seq} from writer {writer_id!r} "
                        f"still in flight after {timeout}s")
                self._seq_cond.wait(remaining)

    def _release_seq(self, writer_id: str, seq: int) -> None:
        with self._seq_cond:
            inflight = self._inflight.get(writer_id)
            if inflight is not None:
                inflight.discard(seq)
                if not inflight:
                    self._inflight.pop(writer_id, None)
            self._seq_cond.notify_all()

    # -- read path ----------------------------------------------------------

    def _make_sample_fn(self, batch: int):
        """One jit per batch size: flush every shard that has pending
        lazy writes (the admission-window boundary), then draw the
        stratified batch with globally-normalized importance weights."""
        rb, n = self.replay, self.config.n_shards
        if batch % n:
            raise ValueError(
                f"sample batch={batch} must divide evenly over "
                f"n_shards={n} (stratified sampling draws B/N per shard)")
        per = batch // n

        @jax.jit
        def fn(states: Tuple[ReplayState, ...], rng, beta):
            states = tuple(rb.flush(s) for s in states)
            if n == 1:
                idx, items, w = rb.sample(states[0], rng, batch, beta)
                return states, (idx,), items, w
            g_tot = sum(s.tree[0] for s in states)
            g_cnt = sum(s.count for s in states)
            idxs, pris, parts = [], [], []
            for i, s in enumerate(states):
                u = jax.random.uniform(jax.random.fold_in(rng, i), (per,))
                if rb.config.fused_sample_gather_resolved:
                    idx, pri, items = rb.ops.sample_gather(
                        rb.spec, s.tree, u, s.storage)
                else:
                    idx, pri = rb.ops.sample(rb.spec, s.tree, u)
                    items = rb._gather(s.storage, idx)
                idxs.append(idx)
                pris.append(pri)
                parts.append(items)
            pri = jnp.concatenate(pris)
            prob = pri / jnp.maximum(g_tot, 1e-12)
            w = (jnp.maximum(g_cnt, 1).astype(jnp.float32)
                 * jnp.maximum(prob, 1e-12)) ** (-beta)
            w = jnp.where(pri > 0, w, 0.0)
            w = w / jnp.maximum(jnp.max(w), 1e-12)
            items = jax.tree.map(lambda *xs: jnp.concatenate(xs), *parts)
            return states, tuple(idxs), items, w

        return fn

    def sample(self, batch: int, beta: float = 0.4, *,
               timeout: Optional[float] = None) -> Dict[str, Any]:
        """One learner read: rate-limited admission, per-window flush,
        stratified draw.  Returns a ``sample_id`` handle the learner
        echoes into ``update_priorities`` — the service keeps the
        (shard → indices) map server-side so priorities route back
        without the learner knowing the sharding."""
        if self.limiter is not None:
            try:
                self.limiter.await_sample(batch, timeout)
            except ServiceStopped:
                return {"stopped": True}
        fn = self._sample_fns.setdefault(batch, self._make_sample_fn(batch))
        with self._lock:
            rng = jax.random.fold_in(self._sample_key, self._sample_count)
            states, idxs, items, w = fn(tuple(self.states), rng,
                                        jnp.float32(beta))
            self.states[:] = states
            self._sample_count += 1
            self._samples += batch
            sid = self._sample_count
            self._outstanding[sid] = tuple(np.asarray(i) for i in idxs)
            if len(self._outstanding) > 64:
                # a learner that never writes priorities back leaks
                # handles; drop the oldest (write-after-read is already
                # tolerated, a dropped update is a stale priority)
                self._outstanding.pop(next(iter(self._outstanding)))
        return {
            "stopped": self._stopped.is_set(),
            "sample_id": sid,
            "items": jax.tree.map(np.asarray, items),
            "weights": np.asarray(w),
        }

    def update_priorities(self, sample_id: int,
                          td_errors: np.ndarray) -> Dict[str, Any]:
        with self._lock:
            idxs = self._outstanding.pop(sample_id, None)
            if idxs is None:
                return {"applied": False}  # handle aged out — stale is ok
            td = np.asarray(td_errors)
            off = 0
            for shard, idx in enumerate(idxs):
                chunk = td[off:off + idx.shape[0]]
                off += idx.shape[0]
                self.states[shard] = self._update_op(
                    self.states[shard], jnp.asarray(idx), jnp.asarray(chunk))
        return {"applied": True}

    # -- durability (DESIGN.md §14) -----------------------------------------

    def attach_snapshots(self, manager, *, every_appends: int = 50) -> None:
        """Snapshot the full service state into ``manager`` (a
        ``checkpoint.CheckpointManager``) every N applied appends.
        ``every_appends=1`` gives durable acks — insert → snapshot →
        ack — which the restart drills rely on for exactly-once."""
        if every_appends < 1:
            raise ValueError(f"every_appends={every_appends}: must be ≥ 1")
        with self._lock:
            self._ckpt = manager
            self._snap_every = every_appends

    def _snapshot_tree(self) -> Pytree:
        return {"shards": list(self.states)}

    def _save_snapshot_locked(self) -> int:  # repro-lint: disable=L301(every caller holds self._lock — the _locked suffix is the contract)
        self._snap_step += 1
        meta = {
            "inserts": self._inserts,
            "samples": self._samples,
            "sample_count": self._sample_count,
            "appends": self._appends,
            "dup_appends": self._dup_appends,
            "writer_seq": dict(self._writer_seq),
            "writer_appends": dict(self._writer_appends),
            "returns": [float(r) for r in self._returns],
            "params_version": self.params_version(),
            "limiter": (None if self.limiter is None
                        else self.limiter.stats()),
        }
        extra = {"service.json": json.dumps(meta).encode()}
        with self._params_cond:
            blob = self._params_blob
        if blob is not None:
            extra["params.bin"] = blob
        self._ckpt.save(self._snap_step, self._snapshot_tree(), extra=extra)
        self._snapshots_taken += 1
        return self._snap_step

    def save_snapshot(self) -> int:
        """Force one snapshot now (requires ``attach_snapshots``)."""
        with self._lock:
            if self._ckpt is None:
                raise RuntimeError("no snapshot manager attached — call "
                                   "attach_snapshots first")
            return self._save_snapshot_locked()

    def restore_snapshot(self, manager) -> Optional[int]:
        """Rebuild the service from the latest snapshot in ``manager``:
        shard ReplayStates, per-writer seq tables (so dedup keeps
        rejecting already-acked retries from before the crash), sample
        rng position, limiter debt counters, and the last published
        params blob + version.  Returns the restored step, or None when
        the directory is empty (cold start)."""
        example = self._snapshot_tree()
        step, tree = manager.restore_latest(example)
        if step is None:
            return None
        meta = json.loads(manager.read_extra(step, "service.json").decode())
        blob = manager.read_extra(step, "params.bin")
        with self._lock:
            self.states[:] = tree["shards"]
            self._inserts = int(meta["inserts"])
            self._samples = int(meta["samples"])
            self._sample_count = int(meta["sample_count"])
            self._appends = int(meta["appends"])
            self._dup_appends = int(meta["dup_appends"])
            self._writer_seq = {k: int(v)
                                for k, v in meta["writer_seq"].items()}
            self._writer_appends = {k: int(v)
                                    for k, v in meta["writer_appends"].items()}
            self._returns.clear()
            self._returns.extend(float(r) for r in meta["returns"])
            self._snap_step = step
            self._restored_step = step
        if self.limiter is not None and meta["limiter"] is not None:
            self.limiter.restore_counts(int(meta["limiter"]["inserts"]),
                                        int(meta["limiter"]["samples"]))
        with self._params_cond:
            if blob is not None:
                self._params_blob = blob
            self._params_version = int(meta["params_version"])
            self._params_cond.notify_all()
        return step

    # -- param channel ------------------------------------------------------

    def put_params(self, blob: bytes) -> int:
        with self._params_cond:
            self._params_blob = blob
            self._params_version += 1
            self._params_cond.notify_all()
            return self._params_version

    def get_params(self, min_version: int = 1,
                   timeout: Optional[float] = None) -> Dict[str, Any]:
        with self._params_cond:
            if not self._params_cond.wait_for(
                    lambda: (self._params_version >= min_version
                             or self._stopped.is_set()),
                    timeout):
                raise TimeoutError(
                    f"get_params: version ≥ {min_version} not published "
                    f"within {timeout}s (at {self._params_version})")
            return {"version": self._params_version,
                    "blob": self._params_blob,
                    "stopped": self._stopped.is_set()}

    def params_version(self) -> int:
        with self._params_cond:
            return self._params_version

    # -- lifecycle + stats --------------------------------------------------

    def stop(self) -> None:
        self._stopped.set()
        if self.limiter is not None:
            self.limiter.stop()
        with self._params_cond:
            self._params_cond.notify_all()

    @property
    def stopped(self) -> bool:
        return self._stopped.is_set()

    def total_inserts(self) -> int:
        with self._lock:
            return self._inserts

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            per_shard = [int(s.count) for s in self.states]
            recent = list(self._returns)
            out = {
                "inserts": self._inserts,
                "samples": self._samples,
                "sample_calls": self._sample_count,
                "appends": self._appends,
                "dup_appends": self._dup_appends,
                "writer_seq": dict(self._writer_seq),
                "writer_appends": dict(self._writer_appends),
                "snapshots": self._snapshots_taken,
                "restored_step": self._restored_step,
                "per_shard_count": per_shard,
                "params_version": self.params_version(),
                "mean_recent_return": (float(np.mean(recent))
                                       if recent else 0.0),
                "n_returns": len(recent),
                "stopped": self._stopped.is_set(),
                "router": self.router.describe(),
            }
        if self.limiter is not None:
            out["rate_limiter"] = self.limiter.stats()
        return out


# -- wire layer (length-prefixed pickle over localhost TCP) ------------------

_LEN = struct.Struct("!Q")


class ConnectionClosed(ConnectionError):
    """Peer closed the connection — with where and how far through the
    frame it happened, so the retry layer can classify (mid-frame close
    after a send means the reply was lost and the request *may have
    applied*: only idempotent operations may be retried)."""

    def __init__(self, peer: str, bytes_read: int, expected: int):
        self.peer = peer
        self.bytes_read = bytes_read
        self.expected = expected
        if bytes_read:
            detail = (f"mid-frame ({bytes_read}/{expected} bytes read)")
        else:
            detail = "before a frame"
        super().__init__(
            f"replay-service peer {peer} closed connection {detail}")


def _peer_name(sock: socket.socket) -> str:
    try:
        host, port = sock.getpeername()[:2]
        return f"{host}:{port}"
    except (OSError, ValueError):
        # closed socket, or a non-INET family (unix socketpair in tests)
        return "unknown"


def send_msg(sock: socket.socket, obj: Any) -> None:
    blob = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_LEN.pack(len(blob)) + blob)


def recv_msg(sock: socket.socket) -> Any:
    header = _recv_exact(sock, _LEN.size)
    (n,) = _LEN.unpack(header)
    return pickle.loads(_recv_exact(sock, n))


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionClosed(_peer_name(sock), len(buf), n)
        buf.extend(chunk)
    return bytes(buf)


class _Handler(socketserver.BaseRequestHandler):
    def setup(self):
        self.server.track(self.request)  # type: ignore[attr-defined]

    def finish(self):
        self.server.untrack(self.request)  # type: ignore[attr-defined]

    def handle(self):  # one connection = one client, many requests
        service: ReplayService = self.server.service  # type: ignore
        injector: Optional[ServerFaultInjector] = (
            self.server.fault_injector)  # type: ignore[attr-defined]
        conn_id = id(self.request)
        while True:
            try:
                cmd, kw = recv_msg(self.request)
            except (ConnectionError, EOFError):
                return
            action = (injector.on_frame(conn_id, cmd)
                      if injector is not None else None)
            if action == "crash":
                injector.crash(self.server)  # hard: no return; soft: raises
            if action == "drop_request":
                self._drop()  # request lost before dispatch
                return
            try:
                reply = self._dispatch(service, cmd, kw)
                reply.setdefault("ok", True)
            except Exception as e:  # noqa: BLE001 — cross the wire, don't die
                reply = {"ok": False, "error": f"{type(e).__name__}: {e}"}
            if action == "drop_reply":
                self._drop()  # request applied, ack lost — the dedup drill
                return
            if injector is not None:
                injector.before_reply(cmd)
            try:
                send_msg(self.request, reply)
            except (ConnectionError, BrokenPipeError):
                return

    def _drop(self):
        try:
            self.request.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.request.close()
        except OSError:
            pass

    @staticmethod
    def _dispatch(service: ReplayService, cmd: str, kw: dict) -> dict:
        if cmd == "append":
            return service.append(**kw)
        if cmd == "sample":
            return service.sample(**kw)
        if cmd == "update_priorities":
            return service.update_priorities(**kw)
        if cmd == "put_params":
            return {"version": service.put_params(**kw)}
        if cmd == "get_params":
            return service.get_params(**kw)
        if cmd == "stats":
            return {"stats": service.stats()}
        if cmd == "stop":
            service.stop()
            return {"stopped": True}
        if cmd == "ping":
            return {"pong": True}
        raise ValueError(f"unknown replay-service command {cmd!r}")


class _Server(socketserver.ThreadingTCPServer):
    daemon_threads = True
    allow_reuse_address = True
    # blocking admissions park handler threads; the default request
    # queue of 5 is fine (one connection per worker, long-lived)

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.fault_injector: Optional[ServerFaultInjector] = None
        self.crashed = threading.Event()
        self._conn_lock = threading.Lock()
        self._conns: Set[socket.socket] = set()

    def track(self, sock: socket.socket) -> None:
        with self._conn_lock:
            self._conns.add(sock)

    def untrack(self, sock: socket.socket) -> None:
        with self._conn_lock:
            self._conns.discard(sock)

    def shutdown_connections(self) -> None:
        """Sever every live client connection (their next recv raises
        ``ConnectionClosed``)."""
        with self._conn_lock:
            conns = list(self._conns)
        for sock in conns:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass

    def simulate_crash(self) -> None:
        """In-process stand-in for a process kill: stop accepting,
        close the listener, sever every connection.  The service
        object's in-memory state is abandoned exactly as a real crash
        abandons it — a restart must come from the snapshot.

        ``crashed`` is set only after the listener is closed: a restart
        monitor waking on the event may rebind the port immediately."""
        self.shutdown()  # blocks until serve_forever exits (≤ poll tick)
        try:
            self.server_close()
        except OSError:
            pass
        self.crashed.set()
        self.shutdown_connections()

    def handle_error(self, request, client_address):
        # injected crashes and torn connections are expected events in
        # the fault drills — everything else keeps the stock traceback
        exc = sys.exc_info()[1]
        if isinstance(exc, (InjectedCrash, ConnectionError,
                            BrokenPipeError)):
            return
        if isinstance(exc, OSError) and self.crashed.is_set():
            # a simulated crash severs sockets under live handlers;
            # their dying sends (EBADF) are the drill, not a bug
            return
        super().handle_error(request, client_address)


def serve(service: ReplayService, host: str = "127.0.0.1", port: int = 0,
          *, fault_plan: Optional[FaultPlan] = None) -> Tuple[_Server, int]:
    """Start serving on a background thread; returns (server, bound
    port).  ``port=0`` lets the OS pick — the gang launcher passes the
    bound port to the workers.  Call ``server.shutdown()`` to stop.
    ``fault_plan`` arms deterministic wire-layer fault injection
    (``service/faults.py``) for the chaos drills."""
    server = _Server((host, port), _Handler)
    server.service = service  # type: ignore[attr-defined]
    if fault_plan is not None:
        server.fault_injector = ServerFaultInjector(fault_plan)
    thread = threading.Thread(target=server.serve_forever,
                              name="replay-service", daemon=True)
    thread.start()
    return server, server.server_address[1]
