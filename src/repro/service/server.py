"""The replay server (DESIGN.md §11).

``ReplayService`` is the transaction layer of ``core/replay.py`` recast
as a long-lived service: N independent ``PrioritizedReplay`` shards
addressed by a ``Router``, written by any number of writers through the
lazy ledger (every append is leaf-only + ledger bump; the interior
rebuild happens in **one** ``flush`` per shard per admission window —
the window boundary is the next sample that touches the shard), and
sampled by learners with importance weights computed against the
*global* cross-shard priority distribution (the same stratified-sample
math as ``ShardedPrioritizedReplay``, with the psum/pmax collectives
replaced by host-side reductions over the shard list).

Flow control is delegated to the ``RateLimiter``: append admissions
back-pressure writers, sample admissions block the learner, and the
realized samples-per-insert ratio is pinned to the configured one.

The wire layer is deliberately minimal: length-prefixed pickles over
localhost TCP (the gang launcher binds 127.0.0.1 and every worker runs
on the same host — this is a research harness transport, not an
authenticated RPC stack).  All numerical payloads cross as numpy.
"""

from __future__ import annotations

import dataclasses
import pickle
import socket
import socketserver
import struct
import threading
from collections import deque
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.replay import PrioritizedReplay, ReplayConfig, ReplayState
from repro.service.rate_limiter import RateLimiter, ServiceStopped
from repro.service.router import Router

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ReplayServiceConfig:
    capacity_per_shard: int
    n_shards: int = 1
    fanout: int = 128
    alpha: float = 0.6
    eps: float = 1e-6
    backend: Optional[str] = None   # TreeOps backend: "xla" | "pallas"
    fused_sample_gather: Optional[bool] = None
    router: str = "hash"            # Router.POLICIES
    seed: int = 0                   # server-side sample rng stream

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards={self.n_shards}: must be ≥ 1")
        if self.capacity_per_shard < 1:
            raise ValueError(
                f"capacity_per_shard={self.capacity_per_shard}: must be ≥ 1")


class ReplayService:
    """Host-side service core.  Thread-safe: every shard mutation runs
    under one lock (the jitted shard ops release the GIL into XLA, so
    writer handler threads still overlap compute with the wire); blocking
    admissions happen *outside* the lock in the ``RateLimiter``."""

    def __init__(self, config: ReplayServiceConfig, example_item: Pytree,
                 rate_limiter: Optional[RateLimiter] = None):
        self.config = config
        self.replay = PrioritizedReplay(
            ReplayConfig(
                capacity=config.capacity_per_shard,
                fanout=config.fanout,
                alpha=config.alpha,
                eps=config.eps,
                backend=config.backend,
                fused_sample_gather=config.fused_sample_gather,
            ),
            example_item,
        )
        self.router = Router(config.n_shards, config.router)
        self.limiter = rate_limiter
        self.states: List[ReplayState] = [
            self.replay.init() for _ in range(config.n_shards)]
        self._lock = threading.RLock()
        self._stopped = threading.Event()
        # jitted shard ops — one cache for all shards (same shapes)
        self._append_op = jax.jit(partial(self.replay.append, lazy=True))
        self._update_op = jax.jit(
            partial(self.replay.update_priorities, lazy=True))
        self._sample_fns: Dict[int, Any] = {}
        self._sample_key = jax.random.PRNGKey(config.seed)
        # counters + learner-facing bookkeeping
        self._inserts = 0
        self._samples = 0
        self._sample_count = 0
        self._outstanding: Dict[int, Tuple[np.ndarray, ...]] = {}
        # param channel (PUT/GET with versions; blobs are opaque bytes)
        self._params_cond = threading.Condition()
        self._params_blob: Optional[bytes] = None
        self._params_version = 0
        # writer-reported finished-episode returns (progress metric)
        self._returns: deque = deque(maxlen=256)

    # -- write path ---------------------------------------------------------

    def append(self, writer_id: str, items: Pytree, *,
               returns: Optional[List[float]] = None,
               timeout: Optional[float] = None) -> Dict[str, Any]:
        """One writer transaction: rate-limited admission, route to a
        shard, lazy leaf-only append (sampleable at the shard's next
        flush).  Returns progress the writer needs (global insert clock
        for its ε-schedule, current params version, stop flag) so the
        common actor loop costs one round trip per batch."""
        batch = int(jax.tree.leaves(items)[0].shape[0])
        if self.limiter is not None:
            try:
                self.limiter.await_insert(batch, timeout)
            except ServiceStopped:
                return {"stopped": True, "inserts": self.total_inserts(),
                        "params_version": self.params_version()}
        shard = self.router.route(writer_id)
        with self._lock:
            self.states[shard] = self._append_op(self.states[shard], items)
            self._inserts += batch
            if returns:
                self._returns.extend(float(r) for r in returns)
            total = self._inserts
        return {"stopped": self._stopped.is_set(), "shard": shard,
                "inserts": total, "params_version": self.params_version()}

    # -- read path ----------------------------------------------------------

    def _make_sample_fn(self, batch: int):
        """One jit per batch size: flush every shard that has pending
        lazy writes (the admission-window boundary), then draw the
        stratified batch with globally-normalized importance weights."""
        rb, n = self.replay, self.config.n_shards
        if batch % n:
            raise ValueError(
                f"sample batch={batch} must divide evenly over "
                f"n_shards={n} (stratified sampling draws B/N per shard)")
        per = batch // n

        @jax.jit
        def fn(states: Tuple[ReplayState, ...], rng, beta):
            states = tuple(rb.flush(s) for s in states)
            if n == 1:
                idx, items, w = rb.sample(states[0], rng, batch, beta)
                return states, (idx,), items, w
            g_tot = sum(s.tree[0] for s in states)
            g_cnt = sum(s.count for s in states)
            idxs, pris, parts = [], [], []
            for i, s in enumerate(states):
                u = jax.random.uniform(jax.random.fold_in(rng, i), (per,))
                if rb.config.fused_sample_gather_resolved:
                    idx, pri, items = rb.ops.sample_gather(
                        rb.spec, s.tree, u, s.storage)
                else:
                    idx, pri = rb.ops.sample(rb.spec, s.tree, u)
                    items = rb._gather(s.storage, idx)
                idxs.append(idx)
                pris.append(pri)
                parts.append(items)
            pri = jnp.concatenate(pris)
            prob = pri / jnp.maximum(g_tot, 1e-12)
            w = (jnp.maximum(g_cnt, 1).astype(jnp.float32)
                 * jnp.maximum(prob, 1e-12)) ** (-beta)
            w = jnp.where(pri > 0, w, 0.0)
            w = w / jnp.maximum(jnp.max(w), 1e-12)
            items = jax.tree.map(lambda *xs: jnp.concatenate(xs), *parts)
            return states, tuple(idxs), items, w

        return fn

    def sample(self, batch: int, beta: float = 0.4, *,
               timeout: Optional[float] = None) -> Dict[str, Any]:
        """One learner read: rate-limited admission, per-window flush,
        stratified draw.  Returns a ``sample_id`` handle the learner
        echoes into ``update_priorities`` — the service keeps the
        (shard → indices) map server-side so priorities route back
        without the learner knowing the sharding."""
        if self.limiter is not None:
            try:
                self.limiter.await_sample(batch, timeout)
            except ServiceStopped:
                return {"stopped": True}
        fn = self._sample_fns.setdefault(batch, self._make_sample_fn(batch))
        with self._lock:
            rng = jax.random.fold_in(self._sample_key, self._sample_count)
            states, idxs, items, w = fn(tuple(self.states), rng,
                                        jnp.float32(beta))
            self.states[:] = states
            self._sample_count += 1
            self._samples += batch
            sid = self._sample_count
            self._outstanding[sid] = tuple(np.asarray(i) for i in idxs)
            if len(self._outstanding) > 64:
                # a learner that never writes priorities back leaks
                # handles; drop the oldest (write-after-read is already
                # tolerated, a dropped update is a stale priority)
                self._outstanding.pop(next(iter(self._outstanding)))
        return {
            "stopped": self._stopped.is_set(),
            "sample_id": sid,
            "items": jax.tree.map(np.asarray, items),
            "weights": np.asarray(w),
        }

    def update_priorities(self, sample_id: int,
                          td_errors: np.ndarray) -> Dict[str, Any]:
        with self._lock:
            idxs = self._outstanding.pop(sample_id, None)
            if idxs is None:
                return {"applied": False}  # handle aged out — stale is ok
            td = np.asarray(td_errors)
            off = 0
            for shard, idx in enumerate(idxs):
                chunk = td[off:off + idx.shape[0]]
                off += idx.shape[0]
                self.states[shard] = self._update_op(
                    self.states[shard], jnp.asarray(idx), jnp.asarray(chunk))
        return {"applied": True}

    # -- param channel ------------------------------------------------------

    def put_params(self, blob: bytes) -> int:
        with self._params_cond:
            self._params_blob = blob
            self._params_version += 1
            self._params_cond.notify_all()
            return self._params_version

    def get_params(self, min_version: int = 1,
                   timeout: Optional[float] = None) -> Dict[str, Any]:
        with self._params_cond:
            if not self._params_cond.wait_for(
                    lambda: (self._params_version >= min_version
                             or self._stopped.is_set()),
                    timeout):
                raise TimeoutError(
                    f"get_params: version ≥ {min_version} not published "
                    f"within {timeout}s (at {self._params_version})")
            return {"version": self._params_version,
                    "blob": self._params_blob,
                    "stopped": self._stopped.is_set()}

    def params_version(self) -> int:
        with self._params_cond:
            return self._params_version

    # -- lifecycle + stats --------------------------------------------------

    def stop(self) -> None:
        self._stopped.set()
        if self.limiter is not None:
            self.limiter.stop()
        with self._params_cond:
            self._params_cond.notify_all()

    @property
    def stopped(self) -> bool:
        return self._stopped.is_set()

    def total_inserts(self) -> int:
        with self._lock:
            return self._inserts

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            per_shard = [int(s.count) for s in self.states]
            recent = list(self._returns)
            out = {
                "inserts": self._inserts,
                "samples": self._samples,
                "sample_calls": self._sample_count,
                "per_shard_count": per_shard,
                "params_version": self.params_version(),
                "mean_recent_return": (float(np.mean(recent))
                                       if recent else 0.0),
                "n_returns": len(recent),
                "stopped": self._stopped.is_set(),
                "router": self.router.describe(),
            }
        if self.limiter is not None:
            out["rate_limiter"] = self.limiter.stats()
        return out


# -- wire layer (length-prefixed pickle over localhost TCP) ------------------

_LEN = struct.Struct("!Q")


def send_msg(sock: socket.socket, obj: Any) -> None:
    blob = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_LEN.pack(len(blob)) + blob)


def recv_msg(sock: socket.socket) -> Any:
    header = _recv_exact(sock, _LEN.size)
    (n,) = _LEN.unpack(header)
    return pickle.loads(_recv_exact(sock, n))


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("replay-service peer closed connection")
        buf.extend(chunk)
    return bytes(buf)


class _Handler(socketserver.BaseRequestHandler):
    def handle(self):  # one connection = one client, many requests
        service: ReplayService = self.server.service  # type: ignore
        while True:
            try:
                cmd, kw = recv_msg(self.request)
            except (ConnectionError, EOFError):
                return
            try:
                reply = self._dispatch(service, cmd, kw)
                reply.setdefault("ok", True)
            except Exception as e:  # noqa: BLE001 — cross the wire, don't die
                reply = {"ok": False, "error": f"{type(e).__name__}: {e}"}
            try:
                send_msg(self.request, reply)
            except (ConnectionError, BrokenPipeError):
                return

    @staticmethod
    def _dispatch(service: ReplayService, cmd: str, kw: dict) -> dict:
        if cmd == "append":
            return service.append(**kw)
        if cmd == "sample":
            return service.sample(**kw)
        if cmd == "update_priorities":
            return service.update_priorities(**kw)
        if cmd == "put_params":
            return {"version": service.put_params(**kw)}
        if cmd == "get_params":
            return service.get_params(**kw)
        if cmd == "stats":
            return {"stats": service.stats()}
        if cmd == "stop":
            service.stop()
            return {"stopped": True}
        if cmd == "ping":
            return {"pong": True}
        raise ValueError(f"unknown replay-service command {cmd!r}")


class _Server(socketserver.ThreadingTCPServer):
    daemon_threads = True
    allow_reuse_address = True
    # blocking admissions park handler threads; the default request
    # queue of 5 is fine (one connection per worker, long-lived)


def serve(service: ReplayService, host: str = "127.0.0.1",
          port: int = 0) -> Tuple[_Server, int]:
    """Start serving on a background thread; returns (server, bound
    port).  ``port=0`` lets the OS pick — the gang launcher passes the
    bound port to the workers.  Call ``server.shutdown()`` to stop."""
    server = _Server((host, port), _Handler)
    server.service = service  # type: ignore[attr-defined]
    thread = threading.Thread(target=server.serve_forever,
                              name="replay-service", daemon=True)
    thread.start()
    return server, server.server_address[1]
