"""Client side of the replay service wire protocol (DESIGN.md §11).

One long-lived TCP connection per worker; requests are serialized on a
lock (each worker is single-threaded anyway — the lock guards against
accidental sharing).  Blocking admissions (writer backpressure, sampler
waits) happen server-side, so the client just waits on the socket; the
socket timeout therefore defaults high and bounds *deadlock*, not flow
control.
"""

from __future__ import annotations

import pickle
import socket
import threading
from typing import Any, Dict, List, Optional

import numpy as np

from repro.service.server import recv_msg, send_msg


class ReplayClient:
    def __init__(self, host: str, port: int, *, timeout: float = 300.0):
        self.address = (host, port)
        self._sock = socket.create_connection(self.address, timeout=timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._lock = threading.Lock()

    @classmethod
    def from_address(cls, addr: str, **kw) -> "ReplayClient":
        host, _, port = addr.rpartition(":")
        return cls(host or "127.0.0.1", int(port), **kw)

    def _call(self, cmd: str, **kw) -> Dict[str, Any]:
        with self._lock:
            send_msg(self._sock, (cmd, kw))
            reply = recv_msg(self._sock)
        if not reply.pop("ok", False):
            raise RuntimeError(
                f"replay service rejected {cmd}: "
                f"{reply.get('error', 'unknown error')}")
        return reply

    # -- writer API ---------------------------------------------------------

    def append(self, writer_id: str, items: Any, *,
               returns: Optional[List[float]] = None,
               timeout: Optional[float] = None) -> Dict[str, Any]:
        items = _as_numpy(items)
        return self._call("append", writer_id=writer_id, items=items,
                          returns=returns, timeout=timeout)

    # -- learner API --------------------------------------------------------

    def sample(self, batch: int, beta: float = 0.4, *,
               timeout: Optional[float] = None) -> Dict[str, Any]:
        return self._call("sample", batch=batch, beta=float(beta),
                          timeout=timeout)

    def update_priorities(self, sample_id: int,
                          td_errors: np.ndarray) -> bool:
        return self._call("update_priorities", sample_id=sample_id,
                          td_errors=np.asarray(td_errors))["applied"]

    # -- param channel ------------------------------------------------------

    def put_params(self, params: Any) -> int:
        blob = pickle.dumps(_as_numpy(params),
                            protocol=pickle.HIGHEST_PROTOCOL)
        return self._call("put_params", blob=blob)["version"]

    def get_params(self, min_version: int = 1,
                   timeout: Optional[float] = None) -> Dict[str, Any]:
        reply = self._call("get_params", min_version=min_version,
                           timeout=timeout)
        if reply.get("blob") is not None:
            reply["params"] = pickle.loads(reply["blob"])
        return reply

    # -- lifecycle + stats --------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        return self._call("stats")["stats"]

    def stop(self) -> None:
        self._call("stop")

    def ping(self) -> bool:
        return self._call("ping")["pong"]

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


def _as_numpy(tree: Any) -> Any:
    import jax
    return jax.tree.map(np.asarray, tree)


def wait_for_service(host: str, port: int, timeout: float = 30.0) -> None:
    """Poll until the server accepts (gang startup ordering)."""
    import time
    deadline = time.monotonic() + timeout
    while True:
        try:
            with socket.create_connection((host, port), timeout=1.0):
                return
        except OSError:
            if time.monotonic() >= deadline:
                raise RuntimeError(
                    f"replay service at {host}:{port} not reachable "
                    f"within {timeout:.0f}s") from None
            time.sleep(0.2)
