"""Client side of the replay service wire protocol (DESIGN.md §11, §14).

One long-lived TCP connection per worker; requests are serialized on a
lock (each worker is single-threaded anyway — the lock guards against
accidental sharing).  Blocking admissions (writer backpressure, sampler
waits) happen server-side, so the client just waits on the socket; the
socket timeout therefore defaults high and bounds *deadlock*, not flow
control.

Resilience (DESIGN.md §14): every request runs inside a reconnecting
retry loop — capped exponential backoff with seeded multiplicative
jitter, bounded by an overall per-call deadline (``RetryPolicy``).  A
retry is safe for every command by construction:

  * ``append`` carries a per-writer monotonic sequence number allocated
    *before* the retry loop; the server applies each seq exactly once
    and acknowledges duplicates without re-inserting, so a lost *reply*
    (request applied, ack dropped) retries idempotently;
  * ``sample`` allocates a fresh ``sample_id`` server-side per draw —
    a retried sample is simply a new draw, and the orphaned handle ages
    out of the server's bounded outstanding map;
  * ``update_priorities`` is keyed by ``sample_id`` and the server
    tolerates stale/duplicate handles (``applied=False``);
  * ``put_params`` retried bumps the version twice — versions are
    opaque monotonic tokens, readers only ever want the newest;
  * everything else is read-only.
"""

from __future__ import annotations

import dataclasses
import pickle
import random
import socket
import threading
import time
from typing import Any, Dict, Iterator, List, Optional

import numpy as np

from repro.service.faults import ClientFaultInjector, FaultPlan
from repro.service.server import ConnectionClosed, recv_msg, send_msg


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with multiplicative jitter and an
    overall deadline.  ``deadline=0`` disables retry (single attempt).
    Seeded: two clients with the same policy draw the same jitter
    stream, keeping chaos drills reproducible."""

    base: float = 0.05      # first sleep, seconds
    cap: float = 2.0        # per-sleep ceiling
    factor: float = 2.0     # exponential growth
    jitter: float = 0.5     # sleep *= uniform(1-j, 1+j)
    deadline: float = 60.0  # overall retry budget per call, seconds
    seed: int = 0

    def __post_init__(self):
        if self.base <= 0:
            raise ValueError(f"base={self.base}: must be > 0")
        if self.factor < 1.0:
            raise ValueError(f"factor={self.factor}: must be ≥ 1")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(f"jitter={self.jitter}: must be in [0, 1)")
        if self.deadline < 0:
            raise ValueError(f"deadline={self.deadline}: must be ≥ 0")


def backoff_delays(policy: RetryPolicy,
                   rng: random.Random) -> Iterator[float]:
    """The shared backoff schedule: min(cap, base·factor^k), jittered."""
    attempt = 0
    while True:
        delay = min(policy.cap, policy.base * policy.factor ** attempt)
        yield delay * rng.uniform(1.0 - policy.jitter, 1.0 + policy.jitter)
        attempt += 1


class ReplayClient:
    def __init__(self, host: str, port: int, *, timeout: float = 300.0,
                 retry: Optional[RetryPolicy] = None,
                 fault_plan: Optional[FaultPlan] = None):
        self.address = (host, port)
        self._timeout = timeout
        self._retry = RetryPolicy() if retry is None else retry
        self._rng = random.Random(self._retry.seed)
        self._fault = (ClientFaultInjector(fault_plan)
                       if fault_plan is not None else None)
        self._lock = threading.RLock()
        self._sock: Optional[socket.socket] = None
        self._connects = 0
        self._reconnects = 0
        self._seq_lock = threading.Lock()
        self._seqs: Dict[str, int] = {}
        self._deduped = 0
        self._acked_appends = 0
        # eager connect: constructing against a dead server fails fast
        # (gang workers gate on wait_for_service first)
        with self._lock:
            self._ensure_connected()

    @classmethod
    def from_address(cls, addr: str, **kw) -> "ReplayClient":
        host, _, port = addr.rpartition(":")
        return cls(host or "127.0.0.1", int(port), **kw)

    # -- connection management ----------------------------------------------

    def _ensure_connected(self) -> None:
        with self._lock:
            if self._sock is not None:
                return
            sock = socket.create_connection(self.address,
                                            timeout=self._timeout)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._sock = sock
            self._connects += 1
            if self._connects > 1:
                self._reconnects += 1

    def _disconnect(self) -> None:
        with self._lock:
            if self._sock is not None:
                try:
                    self._sock.close()
                except OSError:
                    pass
                self._sock = None

    @property
    def reconnects(self) -> int:
        with self._lock:
            return self._reconnects

    @property
    def deduped_appends(self) -> int:
        """Appends whose retry was acknowledged-without-reinsert by the
        server's seq table — each one is a duplicate that did NOT land."""
        with self._seq_lock:
            return self._deduped

    @property
    def acked_appends(self) -> int:
        """Appends this client has received a (non-stopped) ack for —
        the client-side truth the restart drill compares against the
        server's per-writer applied counters."""
        with self._seq_lock:
            return self._acked_appends

    # -- request path -------------------------------------------------------

    def _attempt(self, cmd: str, kw: Dict[str, Any]) -> Dict[str, Any]:
        with self._lock:
            self._ensure_connected()
            action = (self._fault.on_request(cmd)
                      if self._fault is not None else None)
            if action != "drop_request":
                send_msg(self._sock, (cmd, kw))
            if action is not None:
                # injected connection loss: before the request crossed
                # (drop_request) or after it applied but before the
                # reply (drop_reply — the dedup drill)
                self._disconnect()
                raise ConnectionClosed(
                    f"{self.address[0]}:{self.address[1]} (injected)", 0, 0)
            return recv_msg(self._sock)

    def _call(self, cmd: str, **kw) -> Dict[str, Any]:
        start = time.monotonic()
        delays = backoff_delays(self._retry, self._rng)
        while True:
            try:
                reply = self._attempt(cmd, kw)
                break
            except (OSError, EOFError) as e:
                # connection-level failure (ConnectionClosed, refused,
                # reset, socket timeout): the framing state is gone —
                # drop the socket and retry the whole request on a
                # fresh connection, within the policy's deadline
                self._disconnect()
                elapsed = time.monotonic() - start
                if elapsed >= self._retry.deadline:
                    host, port = self.address
                    raise ConnectionError(
                        f"replay service at {host}:{port}: {cmd!r} still "
                        f"failing after {elapsed:.1f}s of reconnect "
                        f"attempts (deadline {self._retry.deadline:.0f}s): "
                        f"{e}") from e
                time.sleep(min(next(delays),
                               self._retry.deadline - elapsed))
        if not reply.pop("ok", False):
            raise RuntimeError(
                f"replay service rejected {cmd}: "
                f"{reply.get('error', 'unknown error')}")
        return reply

    # -- writer API ---------------------------------------------------------

    def append(self, writer_id: str, items: Any, *,
               returns: Optional[List[float]] = None,
               timeout: Optional[float] = None) -> Dict[str, Any]:
        items = _as_numpy(items)
        # the seq is allocated BEFORE the retry loop: every resend of
        # this logical append carries the same number, which is what
        # lets the server apply it exactly once
        with self._seq_lock:
            seq = self._seqs[writer_id] = self._seqs.get(writer_id, 0) + 1
        reply = self._call("append", writer_id=writer_id, items=items,
                           returns=returns, timeout=timeout, seq=seq)
        with self._seq_lock:
            if reply.get("deduped"):
                self._deduped += 1
            if reply.get("applied"):
                # counts exactly-once application acks (dedup replies
                # included — the original applied, this is its ack), so
                # this total matches the server's per-writer table even
                # when a stop() races the final append
                self._acked_appends += 1
        return reply

    # -- learner API --------------------------------------------------------

    def sample(self, batch: int, beta: float = 0.4, *,
               timeout: Optional[float] = None) -> Dict[str, Any]:
        return self._call("sample", batch=batch, beta=float(beta),
                          timeout=timeout)

    def update_priorities(self, sample_id: int,
                          td_errors: np.ndarray) -> bool:
        return self._call("update_priorities", sample_id=sample_id,
                          td_errors=np.asarray(td_errors))["applied"]

    # -- param channel ------------------------------------------------------

    def put_params(self, params: Any) -> int:
        blob = pickle.dumps(_as_numpy(params),
                            protocol=pickle.HIGHEST_PROTOCOL)
        return self._call("put_params", blob=blob)["version"]

    def get_params(self, min_version: int = 1,
                   timeout: Optional[float] = None) -> Dict[str, Any]:
        reply = self._call("get_params", min_version=min_version,
                           timeout=timeout)
        if reply.get("blob") is not None:
            reply["params"] = pickle.loads(reply["blob"])
        return reply

    # -- lifecycle + stats --------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        return self._call("stats")["stats"]

    def stop(self) -> None:
        self._call("stop")

    def ping(self) -> bool:
        return self._call("ping")["pong"]

    def close(self) -> None:
        self._disconnect()


def _as_numpy(tree: Any) -> Any:
    import jax
    return jax.tree.map(np.asarray, tree)


def wait_for_service(host: str, port: int, timeout: float = 30.0) -> None:
    """Poll until the server accepts (gang startup ordering).  Rides
    the same capped-exponential backoff as the client's retry loop —
    fast first probes, then settling toward the cap instead of hammering
    a fixed-rate poll."""
    policy = RetryPolicy(base=0.05, cap=1.0, jitter=0.25,
                         deadline=timeout)
    delays = backoff_delays(policy, random.Random(policy.seed))
    deadline = time.monotonic() + timeout
    while True:
        try:
            with socket.create_connection((host, port), timeout=1.0):
                return
        except OSError:
            now = time.monotonic()
            if now >= deadline:
                raise RuntimeError(
                    f"replay service at {host}:{port} not reachable "
                    f"within {timeout:.0f}s") from None
            time.sleep(min(next(delays), deadline - now))
