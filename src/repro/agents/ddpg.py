"""DDPG learner (paper's continuous-action algorithm set)."""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.agents.base import Agent, AgentState, mlp_apply, mlp_init
from repro.envs.classic import EnvSpec
from repro.optim import adam


@dataclasses.dataclass(frozen=True)
class DDPGConfig:
    hidden: Tuple[int, ...] = (256, 256)
    gamma: float = 0.99
    tau: float = 0.005
    expl_noise: float = 0.1
    opt: adam.AdamConfig = adam.AdamConfig(lr=1e-3)


def make_ddpg(spec: EnvSpec, cfg: DDPGConfig) -> Agent:
    assert not spec.discrete
    scale = (spec.action_high - spec.action_low) / 2.0
    mid = (spec.action_high + spec.action_low) / 2.0

    def pi(params, obs):
        return mlp_apply(params, obs, final_act=jnp.tanh) * scale + mid

    def q(params, obs, act):
        return mlp_apply(params, jnp.concatenate([obs, act], -1))[..., 0]

    def init(key) -> AgentState:
        k1, k2 = jax.random.split(key)
        params = {
            "pi": mlp_init(k1, (spec.obs_dim, *cfg.hidden, spec.action_dim)),
            "q": mlp_init(k2, (spec.obs_dim + spec.action_dim, *cfg.hidden, 1)),
        }
        return AgentState(params, jax.tree.map(jnp.copy, params),
                          adam.init(params, cfg.opt), jnp.zeros((), jnp.int32))

    def act(state, obs, rng, epsilon=0.0):
        a = pi(state.params["pi"], obs)
        noise = jax.random.normal(rng, a.shape) * cfg.expl_noise * scale * (epsilon > 0)
        return jnp.clip(a + noise, spec.action_low, spec.action_high)

    def learn(state, batch, is_w) -> Tuple[AgentState, Dict, jax.Array]:
        obs, act_, rew = batch["obs"], batch["action"], batch["reward"]
        nobs, done = batch["next_obs"], batch["done"]
        a_next = pi(state.target["pi"], nobs)
        tgt = rew + cfg.gamma * (1 - done) * q(state.target["q"], nobs, a_next)

        def loss_fn(params):
            td = q(params["q"], obs, act_) - jax.lax.stop_gradient(tgt)
            critic = jnp.mean(is_w * jnp.square(td))
            actor = -jnp.mean(q(jax.lax.stop_gradient(params)["q"], obs,
                                pi(params["pi"], obs)))
            return critic + actor, td

        (loss, td), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        new_params, new_opt, gnorm = adam.update(grads, state.opt, state.params, cfg.opt)
        new_target = adam.ema_update(state.target, new_params, cfg.tau)
        return (AgentState(new_params, new_target, new_opt, state.step + 1),
                {"loss": loss, "grad_norm": gnorm}, jnp.abs(td))

    return Agent("ddpg", init, act, learn)
