"""DQN / DDQN learners (paper §II-C, Eq. 1-3) with PER importance weights."""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.agents.base import Agent, AgentState, mlp_apply, mlp_init
from repro.envs.classic import EnvSpec
from repro.optim import adam


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    hidden: Tuple[int, ...] = (256, 256)
    gamma: float = 0.99
    tau: float = 0.005             # Polyak target update
    double_q: bool = False         # DDQN
    opt: adam.AdamConfig = adam.AdamConfig(lr=1e-3)


def make_dqn(spec: EnvSpec, cfg: DQNConfig) -> Agent:
    assert spec.discrete
    sizes = (spec.obs_dim, *cfg.hidden, spec.action_dim)

    def init(key) -> AgentState:
        params = mlp_init(key, sizes)
        return AgentState(
            params=params,
            target=jax.tree.map(jnp.copy, params),
            opt=adam.init(params, cfg.opt),
            step=jnp.zeros((), jnp.int32),
        )

    def act(state: AgentState, obs, rng, epsilon=0.0):
        q = mlp_apply(state.params, obs)
        greedy = jnp.argmax(q, axis=-1)
        rnd = jax.random.randint(rng, greedy.shape, 0, spec.action_dim)
        take_rnd = jax.random.uniform(jax.random.fold_in(rng, 1), greedy.shape) < epsilon
        return jnp.where(take_rnd, rnd, greedy)

    def grads_fn(state: AgentState, batch, is_w):
        """TD-loss gradients only — no optimizer step, no collectives.

        The sharded learner pmeans the returned pytree across shards
        before ``apply_fn`` (paper §V-B push/aggregate/pull)."""
        obs, act_, rew = batch["obs"], batch["action"], batch["reward"]
        nobs, done = batch["next_obs"], batch["done"]

        q_next_t = mlp_apply(state.target, nobs)
        if cfg.double_q:
            sel = jnp.argmax(mlp_apply(state.params, nobs), axis=-1)
            v_next = jnp.take_along_axis(q_next_t, sel[:, None], 1)[:, 0]
        else:
            v_next = jnp.max(q_next_t, axis=-1)
        tgt = rew + cfg.gamma * (1.0 - done) * v_next

        def loss_fn(params):
            q = mlp_apply(params, obs)
            q_sa = jnp.take_along_axis(q, act_[:, None].astype(jnp.int32), 1)[:, 0]
            td = q_sa - jax.lax.stop_gradient(tgt)
            return jnp.mean(is_w * jnp.square(td)), td

        (loss, td), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        return grads, {"loss": loss, "td": td, "q_mean": jnp.mean(td + tgt)}

    def apply_fn(state: AgentState, grads, aux
                 ) -> Tuple[AgentState, Dict[str, jax.Array], jax.Array]:
        new_params, new_opt, gnorm = adam.update(grads, state.opt, state.params, cfg.opt)
        new_target = adam.ema_update(state.target, new_params, cfg.tau)
        metrics = {"loss": aux["loss"], "grad_norm": gnorm, "q_mean": aux["q_mean"]}
        return (
            AgentState(new_params, new_target, new_opt, state.step + 1),
            metrics,
            jnp.abs(aux["td"]),
        )

    def learn(state: AgentState, batch, is_w
              ) -> Tuple[AgentState, Dict[str, jax.Array], jax.Array]:
        grads, aux = grads_fn(state, batch, is_w)
        return apply_fn(state, grads, aux)

    return Agent(name="ddqn" if cfg.double_q else "dqn",
                 init=init, act=act, learn=learn,
                 grads=grads_fn, apply_grads=apply_fn)
