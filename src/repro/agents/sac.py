"""SAC learner — tanh-Gaussian actor, twin critics, learned temperature."""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.agents.base import Agent, AgentState, mlp_apply, mlp_init
from repro.envs.classic import EnvSpec
from repro.optim import adam

LOG_STD_MIN, LOG_STD_MAX = -10.0, 2.0


@dataclasses.dataclass(frozen=True)
class SACConfig:
    hidden: Tuple[int, ...] = (256, 256)
    gamma: float = 0.99
    tau: float = 0.005
    init_alpha: float = 0.2
    learn_alpha: bool = True
    opt: adam.AdamConfig = adam.AdamConfig(lr=3e-4)


def make_sac(spec: EnvSpec, cfg: SACConfig) -> Agent:
    assert not spec.discrete
    scale = (spec.action_high - spec.action_low) / 2.0
    mid = (spec.action_high + spec.action_low) / 2.0
    target_entropy = -float(spec.action_dim)

    def actor_dist(params, obs):
        out = mlp_apply(params, obs)
        mu, log_std = jnp.split(out, 2, axis=-1)
        log_std = jnp.clip(log_std, LOG_STD_MIN, LOG_STD_MAX)
        return mu, jnp.exp(log_std)

    def sample_action(params, obs, rng):
        mu, std = actor_dist(params, obs)
        eps = jax.random.normal(rng, mu.shape)
        pre = mu + std * eps
        a = jnp.tanh(pre)
        # log prob with tanh correction
        logp = (-0.5 * (eps**2 + jnp.log(2 * jnp.pi)) - jnp.log(std)).sum(-1)
        logp = logp - jnp.sum(jnp.log(1 - a**2 + 1e-6), axis=-1)
        return a * scale + mid, logp

    def q(params, obs, act):
        return mlp_apply(params, jnp.concatenate([obs, act], -1))[..., 0]

    def init(key) -> AgentState:
        ks = jax.random.split(key, 3)
        params = {
            "pi": mlp_init(ks[0], (spec.obs_dim, *cfg.hidden, 2 * spec.action_dim)),
            "q1": mlp_init(ks[1], (spec.obs_dim + spec.action_dim, *cfg.hidden, 1)),
            "q2": mlp_init(ks[2], (spec.obs_dim + spec.action_dim, *cfg.hidden, 1)),
        }
        log_alpha = jnp.asarray(jnp.log(cfg.init_alpha), jnp.float32)
        alpha_opt = adam.init(log_alpha, cfg.opt)
        return AgentState(params, jax.tree.map(jnp.copy, params),
                          adam.init(params, cfg.opt), jnp.zeros((), jnp.int32),
                          extra=(log_alpha, alpha_opt))

    def act(state, obs, rng, epsilon=0.0):
        mu, std = actor_dist(state.params["pi"], obs)
        a_det = jnp.tanh(mu) * scale + mid
        a_sto, _ = sample_action(state.params["pi"], obs, rng)
        return jnp.where(epsilon > 0, a_sto, a_det)

    def learn(state, batch, is_w) -> Tuple[AgentState, Dict, jax.Array]:
        obs, act_, rew = batch["obs"], batch["action"], batch["reward"]
        nobs, done = batch["next_obs"], batch["done"]
        log_alpha, alpha_opt = state.extra
        alpha = jnp.exp(log_alpha)
        rng = jax.random.fold_in(jax.random.PRNGKey(23), state.step)
        k1, k2 = jax.random.split(rng)

        a_next, logp_next = sample_action(state.params["pi"], nobs, k1)
        v_next = jnp.minimum(q(state.target["q1"], nobs, a_next),
                             q(state.target["q2"], nobs, a_next)) - alpha * logp_next
        tgt = rew + cfg.gamma * (1 - done) * v_next

        def loss_fn(params):
            td1 = q(params["q1"], obs, act_) - jax.lax.stop_gradient(tgt)
            td2 = q(params["q2"], obs, act_) - jax.lax.stop_gradient(tgt)
            critic = jnp.mean(is_w * (jnp.square(td1) + jnp.square(td2)))
            a_pi, logp = sample_action(params["pi"], obs, k2)
            q_pi = jnp.minimum(q(jax.lax.stop_gradient(params)["q1"], obs, a_pi),
                               q(jax.lax.stop_gradient(params)["q2"], obs, a_pi))
            actor = jnp.mean(alpha * logp - q_pi)
            return critic + actor, (0.5 * (jnp.abs(td1) + jnp.abs(td2)), logp)

        (loss, (td, logp)), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        new_params, new_opt, gnorm = adam.update(grads, state.opt, state.params, cfg.opt)
        new_target = adam.ema_update(state.target, new_params, cfg.tau)

        if cfg.learn_alpha:
            def alpha_loss_fn(la):
                return -jnp.exp(la) * jnp.mean(jax.lax.stop_gradient(logp) + target_entropy)
            ga = jax.grad(alpha_loss_fn)(log_alpha)
            log_alpha_new, alpha_opt, _ = adam.update(ga, alpha_opt, log_alpha, cfg.opt)
        else:
            log_alpha_new = log_alpha

        return (AgentState(new_params, new_target, new_opt, state.step + 1,
                           extra=(log_alpha_new, alpha_opt)),
                {"loss": loss, "grad_norm": gnorm, "alpha": alpha}, td)

    return Agent("sac", init, act, learn)
