"""Token-MDP Q-learner over the assigned LM backbones.

The paper's learner (§V-B) at LM scale: Q(s, ·) = the backbone's logits;
a transition is one position of a trajectory segment (state = prefix,
action = next token, per-position reward/done).  The DQN/DDQN TD rule
(paper Eq. 1-3) applies verbatim, PER importance weights included, and
per-*sequence* mean |TD| is the new buffer priority.

``train_step`` is the function the multi-pod dry-run lowers for the
``train_4k`` cells.  Memory discipline at 32B–400B scale:
  * params FSDP(data[,pod]) × TP(model); optimizer state same sharding
    (= ZeRO-1), bf16 m/v for the big archs;
  * gradient accumulation over ``accum`` microbatches (lax.scan);
  * per-layer remat inside the backbone scan;
  * EMA target network (bf16 copy, same sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import backbone
from repro.models.config import ModelConfig, ShardingConfig
from repro.optim import adam

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TokenDQNConfig:
    gamma: float = 0.99
    target_tau: float = 0.01
    double_q: bool = True
    accum: int = 1                 # gradient-accumulation microbatches
    opt: adam.AdamConfig = adam.AdamConfig(lr=3e-5)


class TrainState(NamedTuple):
    params: Pytree
    target: Pytree
    opt: adam.AdamState
    step: jax.Array


def init_train_state(cfg: ModelConfig, tcfg: TokenDQNConfig, key) -> TrainState:
    params = backbone.init_params(cfg, key)
    return TrainState(
        params=params,
        target=jax.tree.map(jnp.copy, params),
        opt=adam.init(params, tcfg.opt),
        step=jnp.zeros((), jnp.int32),
    )


def state_specs(cfg: ModelConfig, shd: ShardingConfig, state_shape: TrainState):
    """PartitionSpec tree for TrainState (ZeRO-1: opt state mirrors params)."""
    from jax.sharding import PartitionSpec as P
    pspec = backbone.param_specs(cfg, shd, state_shape.params)
    mspec = backbone.param_specs(cfg, shd, state_shape.opt.m)
    return TrainState(
        params=pspec,
        target=pspec,
        opt=adam.AdamState(count=P(), m=mspec, v=mspec),
        step=P(),
    )


def _td_loss(cfg: ModelConfig, tcfg: TokenDQNConfig, params, target_params,
             shd: ShardingConfig, mb: Dict[str, jax.Array]):
    """Per-microbatch TD loss.  mb: tokens/actions/rewards/dones (b, S),
    is_weights (b,), optional extra_embeds."""
    tokens, actions = mb["tokens"], mb["actions"]
    rewards, dones, is_w = mb["rewards"], mb["dones"], mb["is_weights"]
    extra = mb.get("extra_embeds")

    logits = backbone.forward(cfg, shd, params, tokens, extra)      # (b,S*,V)
    off = logits.shape[1] - tokens.shape[1]          # vlm: patch offset
    q = logits[:, off:, :].astype(jnp.float32)

    tgt_logits = backbone.forward(cfg, shd, target_params, tokens, extra)
    qt = tgt_logits[:, off:, :].astype(jnp.float32)

    q_sa = jnp.take_along_axis(q, actions[..., None], axis=-1)[..., 0]
    if tcfg.double_q:   # DDQN: select with online, evaluate with target
        sel = jnp.argmax(q, axis=-1)
        v_next_all = jnp.take_along_axis(qt, sel[..., None], axis=-1)[..., 0]
    else:
        v_next_all = jnp.max(qt, axis=-1)
    # s' of position t is position t+1; terminal segment tail bootstraps 0
    v_next = jnp.concatenate(
        [v_next_all[:, 1:], jnp.zeros_like(v_next_all[:, :1])], axis=1)
    tgt = rewards + tcfg.gamma * (1.0 - dones) * v_next
    td = q_sa - jax.lax.stop_gradient(tgt)
    loss = jnp.mean(is_w[:, None] * jnp.square(td))
    seq_td = jnp.mean(jnp.abs(td), axis=1)           # (b,) → new priorities
    return loss, (seq_td, jnp.mean(q_sa))


def train_step(
    cfg: ModelConfig,
    shd: ShardingConfig,
    tcfg: TokenDQNConfig,
    state: TrainState,
    batch: Dict[str, jax.Array],
) -> Tuple[TrainState, Dict[str, jax.Array], jax.Array]:
    """One learner update (paper Alg. 1 lines 12-18, token MDP).

    Returns (state', metrics, per-sequence |TD| for priority update).
    Data parallelism comes from batch sharding (GSPMD inserts the
    gradient reduce — the parameter-server push/pull, DESIGN.md §2).
    """
    accum = max(1, tcfg.accum)
    b = batch["tokens"].shape[0]
    assert b % accum == 0, (b, accum)
    mbs = jax.tree.map(
        lambda x: x.reshape((accum, b // accum) + x.shape[1:]), batch)
    # §Perf iteration 2: the (B,…)→(accum, B/accum,…) reshape is sharding-
    # ambiguous — GSPMD may place the data axis on the *accum* dim, fully
    # replicating every microbatch's activations.  Pin the batch axis.
    from repro.models.layers import dp as _dp, shard as _shard
    mbs = jax.tree.map(
        lambda x: _shard(x, shd, None, _dp(shd), *(None,) * (x.ndim - 2)),
        mbs)

    grad_fn = jax.value_and_grad(
        lambda p, mb: _td_loss(cfg, tcfg, p, state.target, shd, mb),
        has_aux=True)

    def micro(carry, mb):
        gsum, losssum, qsum = carry
        (loss, (seq_td, qmean)), g = grad_fn(state.params, mb)
        gsum = jax.tree.map(jnp.add, gsum, g)
        return (gsum, losssum + loss, qsum + qmean), seq_td

    gzero = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
    if accum == 1:
        (loss, (seq_td, qmean)), grads = grad_fn(
            state.params, jax.tree.map(lambda x: x[0], mbs))
        tds = seq_td
    else:
        (grads, loss, qmean), tds = jax.lax.scan(
            micro, (gzero, jnp.zeros(()), jnp.zeros(())), mbs)
        grads = jax.tree.map(lambda g: g / accum, grads)
        loss, qmean = loss / accum, qmean / accum
        tds = tds.reshape(b)

    new_params, new_opt, gnorm = adam.update(grads, state.opt, state.params, tcfg.opt)
    new_target = adam.ema_update(state.target, new_params, tcfg.target_tau)
    metrics = {"loss": loss, "grad_norm": gnorm, "q_mean": qmean}
    return TrainState(new_params, new_target, new_opt, state.step + 1), metrics, tds


def serve_step(cfg: ModelConfig, shd: ShardingConfig, params, cache,
               tokens, slot_mask=None) -> Tuple[jax.Array, Any]:
    """Actor act(): one KV-cached decode step → greedy Q action + cache.

    ``slot_mask`` is the continuous-batching hook (DESIGN.md §13): a
    boolean that must broadcast against every cache leaf — scalar under
    the serve engine's per-slot vmap.  A masked-out (free) slot still
    rides the batched compute, but its cache (including ``pos``) is
    frozen in place and its action pinned to 0, so a stale slot can
    never advance state between a release and the next admission.
    """
    logits, new_cache = backbone.decode_step(cfg, shd, params, cache, tokens)
    action = jnp.argmax(logits[:, -1, :], axis=-1)
    if slot_mask is None:
        return action, new_cache
    new_cache = jax.tree.map(
        lambda new, old: jnp.where(slot_mask, new, old), new_cache, cache)
    return jnp.where(slot_mask, action, jnp.zeros_like(action)), new_cache
