"""TD3 learner — twin critics, delayed policy, target smoothing."""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.agents.base import Agent, AgentState, mlp_apply, mlp_init
from repro.envs.classic import EnvSpec
from repro.optim import adam


@dataclasses.dataclass(frozen=True)
class TD3Config:
    hidden: Tuple[int, ...] = (256, 256)
    gamma: float = 0.99
    tau: float = 0.005
    expl_noise: float = 0.1
    policy_noise: float = 0.2
    noise_clip: float = 0.5
    policy_delay: int = 2
    opt: adam.AdamConfig = adam.AdamConfig(lr=1e-3)


def make_td3(spec: EnvSpec, cfg: TD3Config) -> Agent:
    assert not spec.discrete
    scale = (spec.action_high - spec.action_low) / 2.0
    mid = (spec.action_high + spec.action_low) / 2.0

    def pi(params, obs):
        return mlp_apply(params, obs, final_act=jnp.tanh) * scale + mid

    def q(params, obs, act):
        return mlp_apply(params, jnp.concatenate([obs, act], -1))[..., 0]

    def init(key) -> AgentState:
        ks = jax.random.split(key, 3)
        params = {
            "pi": mlp_init(ks[0], (spec.obs_dim, *cfg.hidden, spec.action_dim)),
            "q1": mlp_init(ks[1], (spec.obs_dim + spec.action_dim, *cfg.hidden, 1)),
            "q2": mlp_init(ks[2], (spec.obs_dim + spec.action_dim, *cfg.hidden, 1)),
        }
        return AgentState(params, jax.tree.map(jnp.copy, params),
                          adam.init(params, cfg.opt), jnp.zeros((), jnp.int32))

    def act(state, obs, rng, epsilon=0.0):
        a = pi(state.params["pi"], obs)
        noise = jax.random.normal(rng, a.shape) * cfg.expl_noise * scale * (epsilon > 0)
        return jnp.clip(a + noise, spec.action_low, spec.action_high)

    def learn(state, batch, is_w) -> Tuple[AgentState, Dict, jax.Array]:
        obs, act_, rew = batch["obs"], batch["action"], batch["reward"]
        nobs, done = batch["next_obs"], batch["done"]
        rng = jax.random.fold_in(jax.random.PRNGKey(17), state.step)

        noise = jnp.clip(
            jax.random.normal(rng, act_.shape) * cfg.policy_noise,
            -cfg.noise_clip, cfg.noise_clip) * scale
        a_next = jnp.clip(pi(state.target["pi"], nobs) + noise,
                          spec.action_low, spec.action_high)
        v_next = jnp.minimum(q(state.target["q1"], nobs, a_next),
                             q(state.target["q2"], nobs, a_next))
        tgt = rew + cfg.gamma * (1 - done) * v_next
        do_policy = (state.step % cfg.policy_delay) == 0

        def loss_fn(params):
            td1 = q(params["q1"], obs, act_) - jax.lax.stop_gradient(tgt)
            td2 = q(params["q2"], obs, act_) - jax.lax.stop_gradient(tgt)
            critic = jnp.mean(is_w * (jnp.square(td1) + jnp.square(td2)))
            actor = -jnp.mean(q(jax.lax.stop_gradient(params)["q1"], obs,
                                pi(params["pi"], obs)))
            loss = critic + jnp.where(do_policy, actor, 0.0)
            return loss, 0.5 * (jnp.abs(td1) + jnp.abs(td2))

        (loss, td), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        new_params, new_opt, gnorm = adam.update(grads, state.opt, state.params, cfg.opt)
        new_target = jax.tree.map(
            lambda t, o: jnp.where(do_policy,
                                   adam.ema_update(t, o, cfg.tau), t),
            state.target, new_params)
        return (AgentState(new_params, new_target, new_opt, state.step + 1),
                {"loss": loss, "grad_norm": gnorm}, td)

    return Agent("td3", init, act, learn)
