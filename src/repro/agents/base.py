"""Agent API (paper §II-A): act(s) → a, learn(data, is) → new priorities.

Every agent is a pure-functional bundle over an ``AgentState``; ``learn``
returns per-item |TD| for the prioritized replay buffer update (paper
Alg. 1 lines 17-18)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax

Pytree = Any


class AgentState(NamedTuple):
    params: Pytree
    target: Pytree
    opt: Pytree
    step: jax.Array
    extra: Pytree = ()     # algorithm-specific (e.g. SAC log-alpha, its opt)


def default_params_for_acting(state: AgentState) -> Pytree:
    """The pytree ``act`` reads — every built-in agent acts on
    ``state.params`` (DQN reads Q-params, the actor-critics their "pi"
    sub-tree of it), so the whole params pytree is the snapshot unit."""
    return state.params


def default_with_acting_params(state: AgentState, params: Pytree) -> AgentState:
    """Inverse of ``default_params_for_acting``: substitute a (possibly
    stale) acting copy back into the state handed to ``act``."""
    return state._replace(params=params)


@dataclasses.dataclass(frozen=True)
class Agent:
    """act/learn function bundle; see dqn.py etc. for constructors.

    ``grads``/``apply_grads`` are an optional two-phase split of ``learn``
    (``learn ≡ apply_grads(state, *grads(state, batch, is_w))``) exposing
    the gradient pytree so a sharded learner can pmean it between the two
    phases (paper §V-B parameter-server reduce; runtime/learner.py).
    Agents that don't provide the split still run sharded via a
    parameter-average fallback.

    ``params_for_acting``/``with_acting_params`` are the double-buffer
    contract for async executors: the runtime snapshots
    ``params_for_acting(state)`` into ``LoopState.actor_params`` every
    ``publish_interval`` iterations and acts on
    ``with_acting_params(state, actor_params)``, so actors read a bounded
    -staleness copy while learners keep updating the fresh params
    (runtime/loop.py).  The defaults cover every agent whose ``act``
    reads only ``state.params``; override both together if an agent acts
    on a different sub-tree.
    """

    name: str
    init: Callable[[jax.Array], AgentState]
    act: Callable[..., jax.Array]          # (state, obs, rng, explore) → action
    learn: Callable[..., Tuple[AgentState, Dict[str, jax.Array], jax.Array]]
    # learn(state, batch, is_weights) → (state', metrics, |td|)
    grads: Optional[Callable] = None
    # grads(state, batch, is_weights) → (grad_pytree, aux)
    apply_grads: Optional[Callable] = None
    # apply_grads(state, grad_pytree, aux) → (state', metrics, |td|)
    params_for_acting: Callable[[AgentState], Pytree] = default_params_for_acting
    with_acting_params: Callable[[AgentState, Pytree], AgentState] = \
        default_with_acting_params


def mlp_init(key, sizes, dtype=None):
    import jax.numpy as jnp
    dt = dtype or jnp.float32
    params = []
    ks = jax.random.split(key, len(sizes) - 1)
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(ks[i], (a, b)) * (2.0 / (a + b)) ** 0.5
        params.append({"w": w.astype(dt), "b": jnp.zeros((b,), dt)})
    return params


def mlp_apply(params, x, final_act=None):
    import jax.numpy as jnp
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    if final_act is not None:
        x = final_act(x)
    return x
