"""Agent API (paper §II-A): act(s) → a, learn(data, is) → new priorities.

Every agent is a pure-functional bundle over an ``AgentState``; ``learn``
returns per-item |TD| for the prioritized replay buffer update (paper
Alg. 1 lines 17-18)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax

Pytree = Any


class AgentState(NamedTuple):
    params: Pytree
    target: Pytree
    opt: Pytree
    step: jax.Array
    extra: Pytree = ()     # algorithm-specific (e.g. SAC log-alpha, its opt)


@dataclasses.dataclass(frozen=True)
class Agent:
    """act/learn function bundle; see dqn.py etc. for constructors.

    ``grads``/``apply_grads`` are an optional two-phase split of ``learn``
    (``learn ≡ apply_grads(state, *grads(state, batch, is_w))``) exposing
    the gradient pytree so a sharded learner can pmean it between the two
    phases (paper §V-B parameter-server reduce; runtime/learner.py).
    Agents that don't provide the split still run sharded via a
    parameter-average fallback.
    """

    name: str
    init: Callable[[jax.Array], AgentState]
    act: Callable[..., jax.Array]          # (state, obs, rng, explore) → action
    learn: Callable[..., Tuple[AgentState, Dict[str, jax.Array], jax.Array]]
    # learn(state, batch, is_weights) → (state', metrics, |td|)
    grads: Optional[Callable] = None
    # grads(state, batch, is_weights) → (grad_pytree, aux)
    apply_grads: Optional[Callable] = None
    # apply_grads(state, grad_pytree, aux) → (state', metrics, |td|)


def mlp_init(key, sizes, dtype=None):
    import jax.numpy as jnp
    dt = dtype or jnp.float32
    params = []
    ks = jax.random.split(key, len(sizes) - 1)
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(ks[i], (a, b)) * (2.0 / (a + b)) ** 0.5
        params.append({"w": w.astype(dt), "b": jnp.zeros((b,), dt)})
    return params


def mlp_apply(params, x, final_act=None):
    import jax.numpy as jnp
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    if final_act is not None:
        x = final_act(x)
    return x
