"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallel-head)
and sLSTM (scalar memory with recurrent mixing), attention-free.

Faithful recurrent formulation with exponential input gates and
max-stabilizers; training runs the exact recurrence with ``lax.scan``
over the sequence (the 125M assigned config makes this tractable), and
decoding is the O(1) per-token state update — which is why this family
*runs* the ``long_500k`` cell that full-attention archs must skip.

Simplifications vs the paper (documented in DESIGN.md): the depthwise
conv4 branch and block-diagonal projections are omitted; gates are
per-head scalars.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShardingConfig
from repro.models.layers import Params, dense_init, dp, shard


def _heads(cfg: ModelConfig) -> Tuple[int, int]:
    return cfg.num_heads, cfg.d_model // cfg.num_heads


# ---------------------------------------------------------------- mLSTM ----

class MLSTMState(NamedTuple):
    c: jax.Array   # (B, H, DK, DV) matrix memory
    n: jax.Array   # (B, H, DK) normalizer
    m: jax.Array   # (B, H) stabilizer


def mlstm_init(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    h, hd = _heads(cfg)
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, h * hd, dt),
        "wv": dense_init(ks[2], d, h * hd, dt),
        "wi": dense_init(ks[3], d, h, jnp.float32),   # exp input gate (pre-act)
        "wf": dense_init(ks[4], d, h, jnp.float32),   # forget gate (pre-act)
        "wo_gate": dense_init(ks[5], d, h * hd, dt),  # output gate
        "w_out": dense_init(ks[6], h * hd, d, dt),
    }


def _mlstm_qkvif(cfg, p, x):
    b, s, d = x.shape
    h, hd = _heads(cfg)
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"]).reshape(b, s, h, hd) / math.sqrt(hd)
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"]).reshape(b, s, h, hd)
    it = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wi"])   # log-space
    ft = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wf"])
    logf = jax.nn.log_sigmoid(ft)
    og = jax.nn.sigmoid(jnp.einsum("bsd,dk->bsk", x, p["wo_gate"]).astype(jnp.float32))
    return q, k, v, it, logf, og.reshape(b, s, h, hd)


def mlstm_step(state: MLSTMState, q, k, v, it, logf):
    """One stabilized mLSTM step.  q/k/v: (B,H,hd); it/logf: (B,H).

    Denominator floor is exp(-m) in the scaled space — i.e. 1.0 in the
    unscaled space, the paper's max(|qᵀn|, 1) (clipped against overflow).
    """
    m_new = jnp.maximum(logf + state.m, it)
    f_ = jnp.exp(logf + state.m - m_new)[..., None]
    i_ = jnp.exp(it - m_new)[..., None]
    c = state.c * f_[..., None] + i_[..., None] * k[..., :, None] * v[..., None, :]
    n = state.n * f_ + i_ * k
    num = jnp.einsum("bhk,bhkv->bhv", q, c)
    floor = jnp.exp(jnp.minimum(-m_new, 60.0))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)), floor)[..., None]
    return MLSTMState(c, n, m_new), num / den


def mlstm_forward(cfg: ModelConfig, shd: ShardingConfig, p: Params,
                  x: jax.Array) -> jax.Array:
    """Training path: exact recurrence scanned over the sequence."""
    b, s, d = x.shape
    h, hd = _heads(cfg)
    q, k, v, it, logf, og = _mlstm_qkvif(cfg, p, x)
    init = MLSTMState(
        c=jnp.zeros((b, h, hd, hd), jnp.float32),
        n=jnp.zeros((b, h, hd), jnp.float32),
        m=jnp.full((b, h), -1e30, jnp.float32),
    )
    seq = (
        q.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        it.transpose(1, 0, 2),
        logf.transpose(1, 0, 2),
    )
    _, hs = jax.lax.scan(lambda st, inp: mlstm_step(st, *inp), init, seq)
    hs = hs.transpose(1, 0, 2, 3)                    # (B,S,H,hd)
    y = (hs * og).reshape(b, s, h * hd).astype(x.dtype)
    y = shard(y, shd, dp(shd), None, shd.tp)
    return jnp.einsum("bsk,kd->bsd", y, p["w_out"])


def mlstm_prefill_state(cfg: ModelConfig, p: Params, x: jax.Array) -> MLSTMState:
    """Final recurrent state after processing x (prefill priming)."""
    b, s, d = x.shape
    h, hd = _heads(cfg)
    q, k, v, it, logf, og = _mlstm_qkvif(cfg, p, x)
    init = MLSTMState(
        c=jnp.zeros((b, h, hd, hd), jnp.float32),
        n=jnp.zeros((b, h, hd), jnp.float32),
        m=jnp.full((b, h), -1e30, jnp.float32),
    )
    seq = (
        q.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        it.transpose(1, 0, 2),
        logf.transpose(1, 0, 2),
    )
    final, _ = jax.lax.scan(lambda st, inp: mlstm_step(st, *inp), init, seq)
    return final


MLSTM_CHUNK = 64


def mlstm_forward_chunked(cfg: ModelConfig, shd: ShardingConfig, p: Params,
                          x: jax.Array) -> jax.Array:
    """§Perf optimized training path: chunkwise-parallel stabilized mLSTM.

    The sequential scan stores an (B,H,hd,hd) matrix state per *step* for
    the backward pass (the xlstm train_4k memory wall); the chunked form
    stores it per *chunk* (64×) and computes within-chunk interactions as
    masked quadratic einsums (MXU-friendly).  Exact up to fp reordering —
    tested against mlstm_forward.

    Scaled-state bookkeeping (per head): carry (S̃, ñ, m) with the true
    state C = S̃·eᵐ.  Within a chunk, with F_t = Σ_{≤t} log f, g_j =
    i_j − F_j, M_t = cummax g, mx_t = max(m, M_t):
        h_t = [Σ_{j≤t} e^{g_j−mx_t}(q_t·k_j)v_j + e^{m−mx_t}(q_t·S̃)]
              / max(|analogous n-sum|, e^{−(F_t+mx_t)})
    and the carry advances with mx_L = max(m, M_L):
        S̃' = S̃·e^{m−mx_L} + Σ_j e^{g_j−mx_L} k_j v_jᵀ ,  m' = F_L + mx_L.
    """
    b, s, d = x.shape
    h, hd = _heads(cfg)
    q, k, v, it, logf, og = _mlstm_qkvif(cfg, p, x)
    chunk = min(MLSTM_CHUNK, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    resh = lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).astype(jnp.float32)
    qc, kc, vc = resh(q), resh(k), resh(v)           # (B,NC,Q,H,hd)
    itc, lfc = resh(it), resh(logf)                  # (B,NC,Q,H)
    F = jnp.cumsum(lfc, axis=2)
    g = itc - F
    M = jax.lax.cummax(g, axis=2)
    btot = F[:, :, -1, :]                            # (B,NC,H)
    iota = jnp.arange(chunk)
    causal = (iota[:, None] >= iota[None, :])[None, :, :, None]

    def body(carry, inp):
        S, n, m = carry                              # (B,H,hd,hd),(B,H,hd),(B,H)
        qb, kb, vb, Fb, gb, Mb, btb = inp
        mx = jnp.maximum(m[:, None], Mb)             # (B,Q,H)
        wmat = jnp.exp(gb[:, None, :, :] - mx[:, :, None, :])
        wmat = jnp.where(causal, wmat, 0.0)          # (B,Tq,Tj,H)
        scores = jnp.einsum("bqhd,bjhd->bqjh", qb, kb) * wmat
        inter = jnp.exp(m[:, None] - mx)             # (B,Q,H)
        numer = (jnp.einsum("bqjh,bjhd->bqhd", scores, vb)
                 + inter[..., None] * jnp.einsum("bqhk,bhkv->bqhv", qb, S))
        qn = (jnp.sum(scores, axis=2)
              + inter * jnp.einsum("bqhk,bhk->bqh", qb, n))
        mu = Fb + mx
        floor = jnp.exp(jnp.minimum(-mu, 60.0))
        hout = numer / jnp.maximum(jnp.abs(qn), floor)[..., None]
        # carry advance
        mxl = jnp.maximum(m, Mb[:, -1])              # (B,H)
        wl = jnp.exp(gb - mxl[:, None])              # (B,Q,H)
        decay = jnp.exp(m - mxl)
        S_new = (S * decay[..., None, None]
                 + jnp.einsum("bjh,bjhk,bjhv->bhkv", wl, kb, vb))
        n_new = n * decay[..., None] + jnp.einsum("bjh,bjhk->bhk", wl, kb)
        return (S_new, n_new, btb + mxl), hout

    init = (jnp.zeros((b, h, hd, hd), jnp.float32),
            jnp.zeros((b, h, hd), jnp.float32),
            jnp.full((b, h), -1e30, jnp.float32))
    xs = tuple(t.transpose(1, 0, *range(2, t.ndim))
               for t in (qc, kc, vc, F, g, M, btot))
    _, hs = jax.lax.scan(body, init, xs)             # (NC,B,Q,H,hd)
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    y = (hs * og).reshape(b, s, h * hd).astype(x.dtype)
    y = shard(y, shd, dp(shd), None, shd.tp)
    return jnp.einsum("bsk,kd->bsd", y, p["w_out"])


def mlstm_decode_init(cfg: ModelConfig, batch: int) -> MLSTMState:
    h, hd = _heads(cfg)
    return MLSTMState(
        c=jnp.zeros((batch, h, hd, hd), jnp.float32),
        n=jnp.zeros((batch, h, hd), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
    )


def mlstm_decode_step(cfg, shd, p, x, state):
    """x: (B,1,d) → (B,1,d), new state."""
    b = x.shape[0]
    h, hd = _heads(cfg)
    q, k, v, it, logf, og = _mlstm_qkvif(cfg, p, x)
    state, hs = mlstm_step(
        state, q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
        v[:, 0].astype(jnp.float32), it[:, 0], logf[:, 0],
    )
    y = (hs[:, None] * og).reshape(b, 1, h * hd).astype(x.dtype)
    return jnp.einsum("bsk,kd->bsd", y, p["w_out"]), state


# ---------------------------------------------------------------- sLSTM ----

class SLSTMState(NamedTuple):
    c: jax.Array   # (B, H, hd) cell
    n: jax.Array   # (B, H, hd) normalizer
    m: jax.Array   # (B, H, hd) stabilizer
    h: jax.Array   # (B, H, hd) hidden (recurrent input)


def slstm_init(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    h, hd = _heads(cfg)
    ks = jax.random.split(key, 9)
    p = {}
    for i, name in enumerate(["z", "i", "f", "o"]):
        p[f"w{name}"] = dense_init(ks[i], d, h * hd, jnp.float32)
        # head-local recurrent mixing R: (H, hd, hd)
        p[f"r{name}"] = (
            jax.random.normal(ks[4 + i], (h, hd, hd)) / math.sqrt(hd)
        ).astype(jnp.float32)
    p["w_out"] = dense_init(ks[8], d, d, dt)
    return p


def slstm_step(p, state: SLSTMState, xz, xi, xf, xo):
    """All inputs (B,H,hd) f32 pre-activations from x."""
    rec = lambda name: jnp.einsum("bhk,hkv->bhv", state.h, p[f"r{name}"])
    zt = jnp.tanh(xz + rec("z"))
    it = xi + rec("i")                              # log-space input gate
    ft = jax.nn.log_sigmoid(xf + rec("f"))          # log forget gate
    ot = jax.nn.sigmoid(xo + rec("o"))
    m_new = jnp.maximum(ft + state.m, it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + state.m - m_new)
    c = f_ * state.c + i_ * zt
    n = f_ * state.n + i_
    h_new = ot * c / jnp.maximum(n, 1.0)
    return SLSTMState(c, n, m_new, h_new), h_new


def _slstm_inputs(cfg, p, x):
    b, s, d = x.shape
    h, hd = _heads(cfg)
    xf32 = x.astype(jnp.float32)
    pre = lambda name: jnp.einsum("bsd,dk->bsk", xf32, p[f"w{name}"]).reshape(b, s, h, hd)
    return pre("z"), pre("i"), pre("f"), pre("o")


def slstm_forward(cfg: ModelConfig, shd: ShardingConfig, p: Params,
                  x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    h, hd = _heads(cfg)
    xz, xi, xf, xo = _slstm_inputs(cfg, p, x)
    init = SLSTMState(
        c=jnp.zeros((b, h, hd), jnp.float32),
        n=jnp.zeros((b, h, hd), jnp.float32),
        m=jnp.full((b, h, hd), -1e30, jnp.float32),
        h=jnp.zeros((b, h, hd), jnp.float32),
    )
    seq = tuple(t.transpose(1, 0, 2, 3) for t in (xz, xi, xf, xo))
    _, hs = jax.lax.scan(lambda st, inp: slstm_step(p, st, *inp), init, seq)
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, h * hd).astype(x.dtype)
    return jnp.einsum("bsk,kd->bsd", y, p["w_out"])


def slstm_prefill_state(cfg: ModelConfig, p: Params, x: jax.Array) -> SLSTMState:
    b, s, d = x.shape
    xz, xi, xf, xo = _slstm_inputs(cfg, p, x)
    init = SLSTMState(
        c=jnp.zeros((b, *xz.shape[2:]), jnp.float32),
        n=jnp.zeros((b, *xz.shape[2:]), jnp.float32),
        m=jnp.full((b, *xz.shape[2:]), -1e30, jnp.float32),
        h=jnp.zeros((b, *xz.shape[2:]), jnp.float32),
    )
    seq = tuple(t.transpose(1, 0, 2, 3) for t in (xz, xi, xf, xo))
    final, _ = jax.lax.scan(lambda st, inp: slstm_step(p, st, *inp), init, seq)
    return final


def slstm_decode_init(cfg: ModelConfig, batch: int) -> SLSTMState:
    h, hd = _heads(cfg)
    z = lambda: jnp.zeros((batch, h, hd), jnp.float32)
    return SLSTMState(c=z(), n=z(), m=jnp.full((batch, h, hd), -1e30), h=z())


def slstm_decode_step(cfg, shd, p, x, state):
    b = x.shape[0]
    h, hd = _heads(cfg)
    xz, xi, xf, xo = _slstm_inputs(cfg, p, x)
    state, hs = slstm_step(p, state, xz[:, 0], xi[:, 0], xf[:, 0], xo[:, 0])
    y = hs.reshape(b, 1, h * hd).astype(x.dtype)
    return jnp.einsum("bsk,kd->bsd", y, p["w_out"]), state
