"""Unified agent-network backbone covering all 10 assigned families.

Paths:
  * ``forward``      — full-sequence (training / prefill) logits
  * ``init_cache`` / ``prefill`` / ``decode_step`` — KV/state-cached serving
    (= the paper's *actor* ``act()`` at LM scale, DESIGN.md §2)

Structure per family:
  dense / vlm         embed(+patches) → scan[attn → mlp] → norm → unembed
  moe (mixtral)       scan[attn → moe]
  moe (llama4)        scan over pairs [attn → mlp][attn → moe] (alternating)
  hybrid (hymba)      scan[(attn ∥ mamba) → mlp]   (parallel heads, averaged)
  ssm (xlstm)         unrolled mLSTM/sLSTM blocks (pattern from cfg.slstm_at)
  audio (whisper)     encoder scan[attn_bidir → mlp] + decoder
                      scan[attn → cross-attn → mlp], conv frontend stubbed

Memory discipline: scan-over-layers keeps HLO size O(1) in depth;
``jax.checkpoint`` around each scan unit gives per-layer remat; the
learner additionally microbatches (agents/token_dqn.py).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import xlstm as X
from repro.models.config import ModelConfig, ShardingConfig

Params = Dict[str, Any]


# ===========================================================================
# Init
# ===========================================================================

def _stack_init(fn, key, n: int):
    """vmap an init over layer keys → params stacked on a leading L dim."""
    return jax.vmap(fn)(jax.random.split(key, n))


def _unit_init(cfg: ModelConfig, sub: Tuple[str, ...]):
    def init_one(key):
        ks = jax.random.split(key, len(sub) * 2)
        p = {}
        for i, kind in enumerate(sub):
            kp, kn = ks[2 * i], ks[2 * i + 1]
            if kind in ("attn", "attn_nc", "cross"):
                p[kind] = {"norm": L.norm_init(cfg, cfg.d_model), "w": L.attn_init(cfg, kp)}
            elif kind == "mlp":
                p[kind] = {"norm": L.norm_init(cfg, cfg.d_model), "w": L.mlp_init(cfg, kp)}
            elif kind == "moe":
                p[kind] = {"norm": L.norm_init(cfg, cfg.d_model), "w": MOE.moe_init(cfg, kp)}
            elif kind == "hybrid":
                p[kind] = {
                    "norm": L.norm_init(cfg, cfg.d_model),
                    "attn": L.attn_init(cfg, kp),
                    "ssm": M.mamba_init(cfg, kn),
                    "norm_attn": L.norm_init(cfg, cfg.d_model),
                    "norm_ssm": L.norm_init(cfg, cfg.d_model),
                }
            else:
                raise ValueError(kind)
        return p

    return init_one


def unit_structure(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int]:
    """(sub-layer kinds per scan unit, number of scan units)."""
    if cfg.family == "hybrid":
        return ("hybrid", "mlp"), cfg.num_layers
    if cfg.family == "moe":
        if cfg.moe_layer_period == 1:
            return ("attn", "moe"), cfg.num_layers
        assert cfg.moe_layer_period == 2
        return ("attn", "mlp", "attn", "moe"), cfg.num_layers // 2
    return ("attn", "mlp"), cfg.num_layers  # dense / vlm


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"embed": L.embed_init(cfg, ks[0]),
                 "final_norm": L.norm_init(cfg, cfg.d_model)}

    if cfg.family == "ssm":  # xLSTM — unrolled heterogeneous blocks
        blocks = []
        bks = jax.random.split(ks[1], cfg.num_layers)
        for i in range(cfg.num_layers):
            kind = "slstm" if i in cfg.slstm_at else "mlstm"
            sub = {"norm": L.norm_init(cfg, cfg.d_model)}
            if kind == "slstm":
                sub["slstm"] = X.slstm_init(cfg, bks[i])
                sub["mlp"] = {"norm": L.norm_init(cfg, cfg.d_model),
                              "w": L.mlp_init(cfg, bks[i], d_ff=(cfg.d_model * 4) // 3)}
            else:
                sub["mlstm"] = X.mlstm_init(cfg, bks[i])
                sub["mlp"] = {"norm": L.norm_init(cfg, cfg.d_model),
                              "w": L.mlp_init(cfg, bks[i], d_ff=cfg.d_model * 2)}
            blocks.append(sub)
        p["blocks"] = blocks
        return p

    if cfg.family == "audio":  # Whisper enc-dec (learned abs positions, no RoPE)
        p["enc_pos"] = jnp.zeros((cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        p["enc_units"] = _stack_init(_unit_init(cfg, ("attn_nc", "mlp")), ks[2], cfg.encoder_layers)
        p["enc_norm"] = L.norm_init(cfg, cfg.d_model)
        p["dec_units"] = _stack_init(_unit_init(cfg, ("attn", "cross", "mlp")), ks[3], cfg.num_layers)
        return p

    sub, n_units = unit_structure(cfg)
    p["units"] = _stack_init(_unit_init(cfg, sub), ks[2], n_units)
    return p


# ===========================================================================
# Sharding specs
# ===========================================================================

def param_specs(cfg: ModelConfig, shd: ShardingConfig, params_shape) -> Any:
    """PartitionSpec pytree mirroring ``params`` (works on shapes or arrays)."""
    fsdp = shd.fsdp if shd.fsdp else None
    tp = shd.tp

    def rule(path, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        shape = leaf.shape
        nd = len(shape)
        stacked = "units" in names or "blocks" in names
        base_nd = nd - 1 if stacked else nd

        def wrap(*spec):
            spec = spec + (None,) * (base_nd - len(spec))
            return P(*((None,) + spec)) if stacked else P(*spec)

        if name in ("scale", "bias", "bq", "bk", "bv", "A_log", "w_dt", "enc_pos"):
            return wrap()
        if name == "tok":
            return wrap(tp, fsdp)
        if name == "out":
            return wrap(fsdp, tp)
        if name == "router":
            return wrap(fsdp, None)
        ep_ok = (shape[-3] % max(1, shd.tp_extent) == 0
                 or not cfg.moe_ff_tp_fallback) if base_nd == 3 else True
        if base_nd == 3 and name in ("w_gate", "w_up"):     # MoE experts (E,d,f)
            # EP when experts divide the model axis; else dense-style TP on
            # d_ff (replicated experts) — avoids GSPMD reducing expert
            # outputs over a padded expert sharding (§Perf, mixtral)
            return wrap(tp, fsdp, None) if ep_ok else wrap(None, fsdp, tp)
        if base_nd == 3 and name == "w_down":               # (E,f,d)
            return wrap(tp, None, fsdp) if ep_ok else wrap(None, tp, fsdp)
        if base_nd == 3 and name.startswith("r"):           # sLSTM (H,hd,hd)
            return wrap(tp, None, None)
        if name in ("wo", "w_down", "w_out"):               # row-parallel
            return wrap(tp, fsdp)
        if base_nd == 2:                                    # column-parallel
            return wrap(fsdp, tp)
        return wrap()

    if not shd.enabled:
        return jax.tree.map(lambda _: P(), params_shape)
    return jax.tree_util.tree_map_with_path(rule, params_shape)


# ===========================================================================
# Forward (training / prefill)
# ===========================================================================

def _apply_sub(cfg, shd, kind, p, x, positions, freqs, is_global, enc_out=None):
    h = L.apply_norm(cfg, p[kind]["norm"] if kind != "hybrid" else p["hybrid"]["norm"], x)
    if kind == "attn":
        return x + L.mha(cfg, shd, p["attn"]["w"], h, positions, freqs,
                         is_global, use_rope=cfg.family != "audio")
    if kind == "attn_nc":
        return x + L.mha(cfg, shd, p["attn_nc"]["w"], h, positions, freqs,
                         True, causal=False, use_rope=False)
    if kind == "cross":
        return x + L.mha(cfg, shd, p["cross"]["w"], h, positions, freqs,
                         True, kv_override=enc_out, causal=False)
    if kind == "mlp":
        return x + L.mlp(cfg, shd, p["mlp"]["w"], h)
    if kind == "moe":
        y, _metrics = MOE.moe(cfg, shd, p["moe"]["w"], h)
        return x + y
    if kind == "hybrid":  # Hymba: parallel attention + mamba heads, averaged
        a = L.mha(cfg, shd, p["hybrid"]["attn"], h, positions, freqs, is_global)
        s = M.mamba_scan(cfg, shd, p["hybrid"]["ssm"], h)
        a = L.apply_norm(cfg, p["hybrid"]["norm_attn"], a)
        s = L.apply_norm(cfg, p["hybrid"]["norm_ssm"], s)
        return x + 0.5 * (a + s)
    raise ValueError(kind)


def _maybe_scan(cfg: ModelConfig, body, carry, xs):
    """lax.scan over stacked layers, or a python unroll (cost probes /
    heterogeneous stacks).  Matches lax.scan's (carry, ys) contract."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *t: jnp.stack(t), *ys)
    else:
        ys = None
    return carry, ys


def _global_flags(cfg: ModelConfig, n_units: int, sub: Tuple[str, ...]) -> jnp.ndarray:
    """(n_units, n_attn_sublayers) bool — which attn sub-layers are global."""
    per_unit = [k in ("attn", "hybrid") for k in sub]
    idx = 0
    flags = []
    for u in range(n_units):
        row = []
        for is_attn in per_unit:
            if is_attn:
                row.append(cfg.layer_is_global_attn(idx))
                idx += 1
        flags.append(row)
    return jnp.asarray(flags, bool)


def _scan_units(cfg, shd, params, x, positions, freqs, enc_out=None,
                units_key="units", sub=None, n_units=None):
    if sub is None:
        sub, n_units = unit_structure(cfg)
    flags = _global_flags(cfg, n_units, sub)

    def unit(x, inp):
        p_u, flag_row = inp
        fi = 0
        for kind in sub:
            g = flag_row[fi] if kind in ("attn", "hybrid") else True
            if kind in ("attn", "hybrid"):
                fi += 1
            x = _apply_sub(cfg, shd, kind, p_u, x, positions, freqs, g, enc_out)
        return x, None

    body = jax.checkpoint(unit) if cfg.remat else unit
    x, _ = _maybe_scan(cfg, body, x, (params[units_key], flags))
    return x


def forward(
    cfg: ModelConfig,
    shd: ShardingConfig,
    params: Params,
    tokens: jax.Array,                          # (B, S_text)
    extra_embeds: Optional[jax.Array] = None,   # vision patches / audio frames
) -> jax.Array:
    """Full-sequence logits (B, S_total, V)."""
    freqs = L.rope_freqs(cfg)

    if cfg.family == "audio":
        return _whisper_forward(cfg, shd, params, tokens, extra_embeds, freqs)

    x = L.embed(cfg, shd, params["embed"], tokens)
    if cfg.family == "vlm" and extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    if cfg.family == "ssm":
        x = _xlstm_forward(cfg, shd, params, x)
    else:
        x = _scan_units(cfg, shd, params, x, positions, freqs)

    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.unembed(cfg, shd, params["embed"], x)


def _xlstm_forward(cfg, shd, params, x):
    for i, bp in enumerate(params["blocks"]):
        h = L.apply_norm(cfg, bp["norm"], x)
        if "slstm" in bp:
            x = x + X.slstm_forward(cfg, shd, bp["slstm"], h)
        elif cfg.mlstm_chunked:
            x = x + X.mlstm_forward_chunked(cfg, shd, bp["mlstm"], h)
        else:
            x = x + X.mlstm_forward(cfg, shd, bp["mlstm"], h)
        h2 = L.apply_norm(cfg, bp["mlp"]["norm"], x)
        x = x + L.mlp(cfg, shd, bp["mlp"]["w"], h2)
    return x


def _whisper_forward(cfg, shd, params, tokens, frames, freqs):
    """frames: (B, S_enc, d) stub embeddings (conv frontend is stubbed —
    input_specs supplies precomputed frame embeddings per the assignment)."""
    enc = frames.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"][None]
    b, se, _ = enc.shape
    pos_e = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32), (b, se))
    enc = _scan_units(cfg, shd, params, enc, pos_e, freqs,
                      units_key="enc_units", sub=("attn_nc", "mlp"),
                      n_units=cfg.encoder_layers)
    enc = L.apply_norm(cfg, params["enc_norm"], enc)

    x = L.embed(cfg, shd, params["embed"], tokens)
    bd, sd, _ = x.shape
    pos_d = jnp.broadcast_to(jnp.arange(sd, dtype=jnp.int32), (bd, sd))
    # cross K/V computed per decoder layer inside the unit (enc_out passed)
    kv = cfg.num_kv_heads
    hd = cfg.hd

    def cross_kv(p_u):
        k = jnp.einsum("bsd,dk->bsk", enc, p_u["cross"]["w"]["wk"]).reshape(b, se, kv, hd)
        v = jnp.einsum("bsd,dk->bsk", enc, p_u["cross"]["w"]["wv"]).reshape(b, se, kv, hd)
        return k, v

    flags = _global_flags(cfg, cfg.num_layers, ("attn", "cross", "mlp"))

    def unit(x, inp):
        p_u, flag_row = inp
        x = _apply_sub(cfg, shd, "attn", p_u, x, pos_d, freqs, True)
        ck, cv = cross_kv(p_u)
        x = _apply_sub(cfg, shd, "cross", p_u, x, pos_d, freqs, True, (ck, cv))
        x = _apply_sub(cfg, shd, "mlp", p_u, x, pos_d, freqs, True)
        return x, None

    body = jax.checkpoint(unit) if cfg.remat else unit
    x, _ = _maybe_scan(cfg, body, x, (params["dec_units"], flags))
    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.unembed(cfg, shd, params["embed"], x)


# ===========================================================================
# Serving: KV/state caches, prefill, decode_step (the paper's actor act())
# ===========================================================================

def _cache_kv_spec(cfg: ModelConfig, shd: ShardingConfig):
    """Sharding for (U, B, S, KV, hd): batch→data; heads→model when the
    head count divides evenly, else sequence→model (flash-decoding style,
    GSPMD inserts the log-sum-exp combine collectives)."""
    if not shd.enabled:
        return P()
    mode = cfg.cache_shard
    if mode == "auto":
        mode = "heads" if cfg.num_kv_heads % 16 == 0 else "seq"
    if mode == "heads":
        return P(None, shd.fsdp, None, shd.tp, None)
    return P(None, shd.fsdp, shd.tp, None, None)


def init_cache(cfg: ModelConfig, shd: ShardingConfig, batch: int, max_len: int,
               dtype=None) -> Dict[str, Any]:
    dt = dtype or jnp.dtype(cfg.dtype)
    kv, hd = cfg.num_kv_heads, cfg.hd

    def kv_buf(n_units):
        z = jnp.zeros((n_units, batch, max_len, kv, hd), dt)
        return L.shard(z, shd, *(_cache_kv_spec(cfg, shd) or ()))

    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        states = []
        for i in range(cfg.num_layers):
            if i in cfg.slstm_at:
                states.append({"slstm": X.slstm_decode_init(cfg, batch)})
            else:
                states.append({"mlstm": X.mlstm_decode_init(cfg, batch)})
        cache["blocks"] = states
        return cache
    if cfg.family == "audio":
        cache["k"] = kv_buf(cfg.num_layers)
        cache["v"] = kv_buf(cfg.num_layers)
        cache["cross_k"] = jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq, kv, hd), dt)
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
        return cache
    sub, n_units = unit_structure(cfg)
    n_attn = sum(1 for k in sub if k in ("attn", "hybrid"))
    cache["k"] = kv_buf(n_units * n_attn)
    cache["v"] = kv_buf(n_units * n_attn)
    if cfg.family == "hybrid":
        h, pd = M.mamba_heads(cfg)
        cache["ssm"] = jnp.zeros((n_units, batch, h, cfg.ssm_state, pd), jnp.float32)
    return cache


def _decode_mask(cfg: ModelConfig, k_pos: jax.Array, pos: jax.Array,
                 is_global) -> jax.Array:
    """(S_cache,) bool validity of cached entries for query at ``pos``."""
    m = k_pos <= pos
    if cfg.attention == "full":
        return m
    if cfg.attention == "sliding":
        local = m & (k_pos > pos - cfg.window)
    else:  # chunked
        local = m & ((k_pos // cfg.window) == (pos // cfg.window))
    return jnp.where(is_global, m, local)


def _attn_decode(cfg, shd, p, x, k_cache, v_cache, pos, freqs, is_global,
                 use_rope=True):
    """x: (B,1,d); k_cache/v_cache: (B,S,KV,hd). Returns out, new caches."""
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    b = x.shape[0]
    s_cache = k_cache.shape[1]
    pos_b = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)

    q = jnp.einsum("bsd,dk->bsk", x, p["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, h, hd)
    k = k.reshape(b, 1, kv, hd)
    v = v.reshape(b, 1, kv, hd)
    if use_rope:
        q = L.apply_rope(q, pos_b, freqs)
        k = L.apply_rope(k, pos_b, freqs)    # cache stores post-RoPE keys

    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))

    qg = q.reshape(b, 1, kv, cfg.q_per_kv, hd)
    scores = jnp.einsum("bsgqh,btgh->bgqst", qg, k_cache).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    k_pos = jnp.arange(s_cache, dtype=jnp.int32)
    mask = _decode_mask(cfg, k_pos, pos, is_global)
    scores = jnp.where(mask[None, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bgqst,btgh->bsgqh", w, v_cache).reshape(b, 1, h * hd)
    return jnp.einsum("bsk,kd->bsd", out, p["wo"]), k_cache, v_cache


def decode_step(
    cfg: ModelConfig,
    shd: ShardingConfig,
    params: Params,
    cache: Dict[str, Any],
    tokens: jax.Array,                    # (B, 1) the newest token ids
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One autoregressive step: logits for the next token + updated cache.
    This is the paper's ``act()`` inference at LM scale."""
    freqs = L.rope_freqs(cfg)
    pos = cache["pos"]
    x = L.embed(cfg, shd, params["embed"], tokens)

    if cfg.family == "ssm":
        new_blocks = []
        for bp, blk in zip(params["blocks"], cache["blocks"]):
            h = L.apply_norm(cfg, bp["norm"], x)
            if "slstm" in blk:
                y, st = X.slstm_decode_step(cfg, shd, bp["slstm"], h, blk["slstm"])
                new_blocks.append({"slstm": st})
            else:
                y, st = X.mlstm_decode_step(cfg, shd, bp["mlstm"], h, blk["mlstm"])
                new_blocks.append({"mlstm": st})
            x = x + y
            h2 = L.apply_norm(cfg, bp["mlp"]["norm"], x)
            x = x + L.mlp(cfg, shd, bp["mlp"]["w"], h2)
        cache = dict(cache, pos=pos + 1, blocks=new_blocks)
        x = L.apply_norm(cfg, params["final_norm"], x)
        return L.unembed(cfg, shd, params["embed"], x), cache

    if cfg.family == "audio":
        return _whisper_decode(cfg, shd, params, cache, x, freqs)

    sub, n_units = unit_structure(cfg)
    flags = _global_flags(cfg, n_units, sub)
    attn_per_unit = sum(1 for k in sub if k in ("attn", "hybrid"))
    kr = cache["k"].reshape((n_units, attn_per_unit) + cache["k"].shape[1:])
    vr = cache["v"].reshape((n_units, attn_per_unit) + cache["v"].shape[1:])

    def unit(x, inp):
        p_u, flag_row, kc_u, vc_u, ssm_u = inp
        fi = 0
        new_k, new_v, new_ssm = [], [], ssm_u
        for kind in sub:
            hdn = L.apply_norm(
                cfg, p_u[kind]["norm"] if kind != "hybrid" else p_u["hybrid"]["norm"], x)
            if kind == "attn":
                y, nk, nv = _attn_decode(cfg, shd, p_u["attn"]["w"], hdn,
                                         kc_u[fi], vc_u[fi], pos, freqs,
                                         flag_row[fi],
                                         use_rope=cfg.family != "audio")
                new_k.append(nk); new_v.append(nv); fi += 1
                x = x + y
            elif kind == "hybrid":
                ya, nk, nv = _attn_decode(cfg, shd, p_u["hybrid"]["attn"], hdn,
                                          kc_u[fi], vc_u[fi], pos, freqs,
                                          flag_row[fi])
                ys, new_ssm = M.mamba_decode_step(cfg, shd, p_u["hybrid"]["ssm"],
                                                  hdn, ssm_u)
                ya = L.apply_norm(cfg, p_u["hybrid"]["norm_attn"], ya)
                ys = L.apply_norm(cfg, p_u["hybrid"]["norm_ssm"], ys)
                new_k.append(nk); new_v.append(nv); fi += 1
                x = x + 0.5 * (ya + ys)
            elif kind == "mlp":
                x = x + L.mlp(cfg, shd, p_u["mlp"]["w"], hdn)
            elif kind == "moe":
                y, _ = MOE.moe(cfg, shd, p_u["moe"]["w"], hdn)
                x = x + y
        return x, (jnp.stack(new_k), jnp.stack(new_v), new_ssm)

    ssm = cache.get("ssm")
    if ssm is None:
        ssm = jnp.zeros((n_units, 1), jnp.float32)  # dummy xs
    x, (nk, nv, nssm) = _maybe_scan(cfg, unit, x, (params["units"], flags, kr, vr, ssm))
    cache = dict(cache,
                 pos=pos + 1,
                 k=nk.reshape(cache["k"].shape),
                 v=nv.reshape(cache["v"].shape))
    if cfg.family == "hybrid":
        cache["ssm"] = nssm
    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.unembed(cfg, shd, params["embed"], x), cache


def _whisper_decode(cfg, shd, params, cache, x, freqs):
    pos = cache["pos"]
    b = x.shape[0]
    pos_b = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)

    def unit(x, inp):
        p_u, kc, vc, ck, cv = inp
        h = L.apply_norm(cfg, p_u["attn"]["norm"], x)
        y, nk, nv = _attn_decode(cfg, shd, p_u["attn"]["w"], h, kc, vc, pos,
                                 freqs, True, use_rope=False)
        x = x + y
        h = L.apply_norm(cfg, p_u["cross"]["norm"], x)
        x = x + L.mha(cfg, shd, p_u["cross"]["w"], h, pos_b, freqs, True,
                      kv_override=(ck, cv), causal=False)
        h = L.apply_norm(cfg, p_u["mlp"]["norm"], x)
        x = x + L.mlp(cfg, shd, p_u["mlp"]["w"], h)
        return x, (nk, nv)

    x, (nk, nv) = _maybe_scan(
        cfg, unit, x,
        (params["dec_units"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
    )
    cache = dict(cache, pos=pos + 1, k=nk, v=nv)
    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.unembed(cfg, shd, params["embed"], x), cache


def prefill(
    cfg: ModelConfig,
    shd: ShardingConfig,
    params: Params,
    tokens: jax.Array,
    max_len: int,
    extra_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Process a full prompt, returning logits and a primed cache.

    Implementation: full forward capturing per-layer K/V (and final SSM /
    xLSTM states), written into a fresh ``init_cache`` buffer.  At LM
    scale this is the actor's episode bootstrap.
    """
    freqs = L.rope_freqs(cfg)
    b = tokens.shape[0]
    cache = init_cache(cfg, shd, b, max_len)

    if cfg.family == "audio":
        logits = _whisper_forward(cfg, shd, params, tokens, extra_embeds, freqs)
        # prime cross K/V from the encoder output
        enc = extra_embeds.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"][None]
        se = enc.shape[1]
        pos_e = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32), (b, se))
        enc = _scan_units(cfg, shd, params, enc, pos_e, freqs,
                          units_key="enc_units", sub=("attn_nc", "mlp"),
                          n_units=cfg.encoder_layers)
        enc = L.apply_norm(cfg, params["enc_norm"], enc)
        kv, hd = cfg.num_kv_heads, cfg.hd

        def one(p_u):
            k = jnp.einsum("bsd,dk->bsk", enc, p_u["cross"]["w"]["wk"]).reshape(b, se, kv, hd)
            v = jnp.einsum("bsd,dk->bsk", enc, p_u["cross"]["w"]["wv"]).reshape(b, se, kv, hd)
            return k, v

        ck, cv = jax.vmap(one)(params["dec_units"])
        # decoder self K/V for the prompt (cross-attention included)
        sk, sv = _capture_self_kv(cfg, shd, params["dec_units"], tokens, params,
                                  freqs, (ck, cv))
        cache = dict(cache, cross_k=ck.astype(cache["cross_k"].dtype),
                     cross_v=cv.astype(cache["cross_v"].dtype))
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], sk.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], sv.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
        cache["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
        return logits, cache

    # decoder-only families: replay the prompt through decode-like capture
    logits = forward(cfg, shd, params, tokens, extra_embeds)
    if cfg.family != "ssm":
        x = L.embed(cfg, shd, params["embed"], tokens)
        if cfg.family == "vlm" and extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        sk, sv, ssm = _capture_kv_states(cfg, shd, params, x, freqs)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], sk.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], sv.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
        if ssm is not None:
            cache["ssm"] = ssm
        cache["pos"] = jnp.asarray(x.shape[1], jnp.int32)
    else:
        # xLSTM: run block-by-block capturing final recurrent states
        x = L.embed(cfg, shd, params["embed"], tokens)
        states = []
        for i, bp in enumerate(params["blocks"]):
            h = L.apply_norm(cfg, bp["norm"], x)
            if "slstm" in bp:
                y, st = _slstm_prefill(cfg, shd, bp["slstm"], h)
                states.append({"slstm": st})
            else:
                y, st = _mlstm_prefill(cfg, shd, bp["mlstm"], h)
                states.append({"mlstm": st})
            x = x + y
            h2 = L.apply_norm(cfg, bp["mlp"]["norm"], x)
            x = x + L.mlp(cfg, shd, bp["mlp"]["w"], h2)
        cache["blocks"] = states
        cache["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
    return logits, cache


def _capture_kv_states(cfg, shd, params, x, freqs):
    """Run the unit scan, emitting per-attn-sublayer K/V (+ final ssm)."""
    sub, n_units = unit_structure(cfg)
    flags = _global_flags(cfg, n_units, sub)
    b, s, _ = x.shape
    kv, hd = cfg.num_kv_heads, cfg.hd
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def unit(x, inp):
        p_u, flag_row = inp
        fi = 0
        ks, vs, ssm_f = [], [], None
        for kind in sub:
            h = L.apply_norm(
                cfg, p_u[kind]["norm"] if kind != "hybrid" else p_u["hybrid"]["norm"], x)
            if kind in ("attn", "hybrid"):
                w = p_u["attn"]["w"] if kind == "attn" else p_u["hybrid"]["attn"]
                k = jnp.einsum("bsd,dk->bsk", h, w["wk"]).reshape(b, s, kv, hd)
                v = jnp.einsum("bsd,dk->bsk", h, w["wv"]).reshape(b, s, kv, hd)
                if "bk" in w:
                    k, v = k + w["bk"].reshape(kv, hd), v + w["bv"].reshape(kv, hd)
                if cfg.family != "audio":
                    k = L.apply_rope(k, positions, freqs)
                ks.append(k); vs.append(v)
            if kind == "hybrid":
                ssm_f = _mamba_final_state(cfg, shd, p_u["hybrid"]["ssm"], h)
            x = _apply_sub(cfg, shd, kind, p_u, x, positions, freqs,
                           flag_row[fi] if kind in ("attn", "hybrid") else True)
            if kind in ("attn", "hybrid"):
                fi += 1
        if ssm_f is None:
            ssm_f = jnp.zeros((1,), jnp.float32)
        return x, (jnp.stack(ks), jnp.stack(vs), ssm_f)

    _, (ks, vs, ssm) = _maybe_scan(cfg, unit, x, (params["units"], flags))
    n_attn = ks.shape[1]
    ks = ks.reshape((n_units * n_attn,) + ks.shape[2:])
    vs = vs.reshape((n_units * n_attn,) + vs.shape[2:])
    return ks, vs, (ssm if cfg.family == "hybrid" else None)


def _capture_self_kv(cfg, shd, dec_units, tokens, params, freqs, cross_kvs):
    """Whisper decoder prompt replay capturing per-layer self K/V."""
    x = L.embed(cfg, shd, params["embed"], tokens)
    b, s, _ = x.shape
    kv, hd = cfg.num_kv_heads, cfg.hd
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def unit(x, inp):
        p_u, ck, cv = inp
        h = L.apply_norm(cfg, p_u["attn"]["norm"], x)
        k = jnp.einsum("bsd,dk->bsk", h, p_u["attn"]["w"]["wk"]).reshape(b, s, kv, hd)
        v = jnp.einsum("bsd,dk->bsk", h, p_u["attn"]["w"]["wv"]).reshape(b, s, kv, hd)
        x = _apply_sub(cfg, shd, "attn", p_u, x, positions, freqs, True)
        x = _apply_sub(cfg, shd, "cross", p_u, x, positions, freqs, True, (ck, cv))
        x = _apply_sub(cfg, shd, "mlp", p_u, x, positions, freqs, True)
        return x, (k, v)

    _, (ks, vs) = _maybe_scan(cfg, unit, x, (dec_units, *cross_kvs))
    return ks, vs


def _mamba_final_state(cfg, shd, p, x):
    """Final SSM state after processing x — via the chunked scan carry."""
    return M.mamba_prefill_state(cfg, shd, p, x)


def _mlstm_prefill(cfg, shd, p, x):
    y = X.mlstm_forward(cfg, shd, p, x)
    st = X.mlstm_prefill_state(cfg, p, x)
    return y, st


def _slstm_prefill(cfg, shd, p, x):
    y = X.slstm_forward(cfg, shd, p, x)
    st = X.slstm_prefill_state(cfg, p, x)
    return y, st
