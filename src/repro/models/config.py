"""Model / sharding configuration dataclasses (all 10 assigned families)."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShardingConfig:
    """Mesh-axis roles.  ``fsdp`` axes shard params+batch; ``tp`` shards
    heads/d_ff/vocab/experts (the 'model' axis)."""

    fsdp: Tuple[str, ...] = ("data",)
    tp: Optional[str] = "model"
    tp_extent: int = 16          # production model-axis size (spec choices)
    dp_extent: int = 16          # total data-axes extent (local dispatch)
    enabled: bool = True

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return self.fsdp


NO_SHARDING = ShardingConfig(fsdp=(), tp=None, enabled=False)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | audio | ssm | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // num_heads
    qkv_bias: bool = False
    # attention variant
    attention: str = "full"          # full | sliding | chunked
    window: int = 4096
    global_layer_period: int = 0     # every p-th layer uses full attention
    global_layers: Tuple[int, ...] = ()  # explicit global layer indices
    rope_theta: float = 10_000.0
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_layer_period: int = 1        # every p-th layer is MoE (1 = all)
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid (Hymba: parallel attention + mamba heads)
    ssm_state: int = 0
    hybrid: bool = False
    ssm_expand: int = 2              # d_inner = ssm_expand * d_model
    # xLSTM
    slstm_at: Tuple[int, ...] = ()   # layer indices using sLSTM blocks
    # encoder-decoder (Whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500          # stub frame count (30 s @ 50 Hz)
    # modality frontend stubs (input_specs supplies embeddings)
    frontend: str = "none"           # none | audio | vision
    num_patch_tokens: int = 0        # vision tokens prepended to the text
    # numerics / structure
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"
    # attention implementation (§Perf): 'naive' materializes (…,S,S)
    # scores (paper-faithful baseline); 'chunked_q' scans query chunks
    # with exact row softmax — no S² residency (beyond-paper optimized)
    attn_impl: str = "naive"
    attn_q_chunk: int = 512
    seq_shard_residual: bool = False  # Megatron-SP-style residual sharding
    # §Perf (mixtral): when num_experts doesn't divide the model axis,
    # shard expert d_ff instead of (padded) experts — baseline keeps the
    # padded-EP layout for comparability
    moe_ff_tp_fallback: bool = False
    # §Perf (xlstm): chunkwise-parallel mLSTM training path (per-chunk
    # state storage instead of per-step) — baseline keeps the exact
    # sequential scan
    mlstm_chunked: bool = False
    # §Perf (mixtral): per-data-shard MoE dispatch — token ranks and
    # capacity are computed within each shard, so the (E, C, d) expert
    # buffers shard over data with no cross-shard collectives (standard
    # distributed-MoE semantics; per-shard token dropping)
    moe_local_dispatch: bool = False
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    # decode-time cache sharding: "heads" when kv_heads % tp == 0, else "seq"
    cache_shard: str = "auto"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if a 500k-token context is tractable (DESIGN.md §5).
        Hymba's few global layers are fine: decode cost is linear in the
        cache and only 3 layers keep full history."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attention == "sliding" and self.global_layer_period == 0

    def layer_is_moe(self, layer: int) -> bool:
        if self.num_experts == 0:
            return False
        return (layer + 1) % self.moe_layer_period == 0

    def layer_is_global_attn(self, layer: int) -> bool:
        if self.attention == "full":
            return True
        if layer in self.global_layers:
            return True
        if self.global_layer_period == 0:
            return False
        return (layer + 1) % self.global_layer_period == 0
