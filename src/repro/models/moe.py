"""Mixture-of-Experts layer: top-k routing with capacity + rank dispatch.

Dense, sort-free dispatch that scales to 128 experts (Llama-4) without the
(T, E, C) GShard one-hot blow-up:

  1. router top-k picks expert ids (T, k) and gate weights;
  2. rank of each token within its expert via a (T, E) masked cumsum;
  3. tokens over capacity ``C = cf·T·k/E`` are dropped (standard GShard
     semantics, counted in aux metrics);
  4. scatter into an (E, C, d) buffer → batched expert GLU → gather back.

Experts shard over the ``model`` axis (expert parallelism); token dims
shard over the data axes.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShardingConfig
from repro.models.layers import Params, _act, dense_init, dp, shard


def moe_init(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * scale).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * scale).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) / math.sqrt(f)).astype(dt),
    }
    if cfg.num_shared_experts:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(cfg, ks[4], d_ff=cfg.d_ff * cfg.num_shared_experts)
    return p


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.experts_per_token / cfg.num_experts)
    return max(8, ((c + 127) // 128) * 128)  # lane-align expert buffers


def moe(
    cfg: ModelConfig,
    shd: ShardingConfig,
    p: Params,
    x: jax.Array,            # (B, S, d)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(t, d)
    xt = shard(xt, shd, dp(shd), None)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_id = jax.lax.top_k(probs, k)          # (T, k) each
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch/GShard)
    me = jnp.mean(probs, axis=0)                         # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(expert_id[:, 0], e, dtype=jnp.float32), axis=0
    )
    aux_loss = e * jnp.sum(me * ce)

    # expert-dim sharding when E divides the model axis (EP), else the
    # token/capacity dim stays on the data axes and d_ff shards over the
    # model axis (dense-style TP inside each expert) — §Perf, mixtral
    ep = e % max(1, shd.tp_extent) == 0 or not cfg.moe_ff_tp_fallback
    e_ax = shd.tp if ep else None
    f_ax = None if ep else shd.tp

    # §Perf (mixtral): per-data-shard dispatch — ranks/capacity local to
    # each shard so the expert buffers shard over data with no cross-
    # shard dispatch collectives (per-shard drops, standard practice)
    ds = 1
    if cfg.moe_local_dispatch and shd.enabled and shd.fsdp:
        if t % shd.dp_extent == 0:
            ds = shd.dp_extent
    tl = t // ds
    cl = capacity(cfg, tl)
    dpa = shd.fsdp if shd.fsdp else None

    xs = xt.reshape(ds, tl, d)
    xs = shard(xs, shd, dpa, None, None) if ds > 1 else xs
    eids = expert_id.reshape(ds, tl, k)
    gws = gate_w.reshape(ds, tl, k)
    sidx = jnp.arange(ds)[:, None]

    out = jnp.zeros((ds, tl, d), jnp.float32)
    dropped = jnp.zeros((), jnp.float32)
    for slot in range(k):
        eid = eids[:, :, slot]                            # (DS, Tl)
        onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)  # (DS, Tl, E)
        rank = jnp.cumsum(onehot, axis=1) - onehot        # rank within shard
        pos = jnp.take_along_axis(rank, eid[..., None], axis=2)[..., 0]
        keep = pos < cl
        dropped = dropped + jnp.sum(1.0 - keep.astype(jnp.float32))
        safe_pos = jnp.where(keep, pos, cl - 1)
        contrib = jnp.where(keep[..., None], xs, 0)
        buf = jnp.zeros((ds, e, cl, d), x.dtype)
        buf = shard(buf, shd, dpa if ds > 1 else None, e_ax, None, None)
        buf_s = buf.at[sidx, eid, safe_pos].add(contrib)  # (DS,E,Cl,d)
        buf_s = shard(buf_s, shd, dpa if ds > 1 else None, e_ax, None, None)
        h_g = jnp.einsum("secd,edf->secf", buf_s, p["w_gate"])
        h_u = jnp.einsum("secd,edf->secf", buf_s, p["w_up"])
        h = _act(cfg, h_g) * h_u
        h = shard(h, shd, dpa if ds > 1 else None, e_ax, None, f_ax)
        y_e = jnp.einsum("secf,efd->secd", h, p["w_down"])
        y_e = shard(y_e, shd, dpa if ds > 1 else None, e_ax, None, None)
        y_t = y_e[sidx, eid, safe_pos]                    # (DS, Tl, d)
        out = out + jnp.where(
            keep[..., None],
            y_t.astype(jnp.float32) * gws[:, :, slot:slot + 1], 0)
    out = out.reshape(t, d)

    if cfg.num_shared_experts:
        from repro.models.layers import mlp
        out = out + mlp(cfg, shd, p["shared"], x).reshape(t, d).astype(jnp.float32)

    metrics = {"aux_loss": aux_loss, "dropped_frac": dropped / (t * k)}
    return out.reshape(b, s, d).astype(x.dtype), metrics
