"""Shared neural layers: norms, RoPE, GQA attention (full/sliding/chunked),
GLU MLP — functional style, params as nested dicts, sharding via
``with_sharding_constraint`` (no-op when no mesh is active)."""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig, ShardingConfig

Params = Dict[str, Any]


# -- sharding helpers ----------------------------------------------------------

def shard(x: jax.Array, shd: ShardingConfig, *spec) -> jax.Array:
    """Apply a sharding constraint if a mesh is active; otherwise no-op."""
    if not shd.enabled:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


def dp(shd: ShardingConfig):
    """Batch/fsdp axes tuple (possibly multi-axis: ('pod','data'))."""
    return shd.fsdp if shd.fsdp else None


def tp_size(shd: ShardingConfig) -> int:
    """Extent of the tensor-parallel axis in the ambient (abstract) mesh."""
    if not shd.enabled or shd.tp is None:
        return 1
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return 1
        return dict(mesh.shape).get(shd.tp, 1)
    except Exception:
        return 1


def tp_if_divisible(shd: ShardingConfig, dim: int):
    """'model' axis name if it divides ``dim`` evenly, else None —
    avoids GSPMD involuntary-remat on padded shardings (e.g. 8 kv heads
    on a 16-way model axis → replicate kv, shard q heads: MQA-style TP)."""
    t = tp_size(shd)
    return shd.tp if (t > 1 and dim % t == 0) else None


# -- initialization -------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


# -- norms ----------------------------------------------------------------------

def norm_init(cfg: ModelConfig, d: int) -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return out.astype(x.dtype)


# -- rotary position embedding ---------------------------------------------------

def rope_freqs(cfg: ModelConfig) -> jax.Array:
    hd = cfg.hd
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, freqs: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    ang = positions[..., :, None].astype(jnp.float32)[..., None, :] * freqs  # (...,S,1,hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- attention --------------------------------------------------------------------

def attn_init(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    p = {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, kv * hd, dt),
        "wv": dense_init(ks[2], d, kv * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    return p


def _attn_mask(
    cfg: ModelConfig,
    q_pos: jax.Array,     # (Sq,)
    k_pos: jax.Array,     # (Sk,)
    is_global: bool,
    causal: bool = True,
) -> jax.Array:
    """(Sq, Sk) boolean mask — full / sliding-window / chunked-local."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    m = (kp <= qp) if causal else jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if cfg.attention == "full":
        return m
    if cfg.attention == "sliding":
        local = m & (kp > qp - cfg.window)
    elif cfg.attention == "chunked":  # Llama-4 style chunked-local
        local = m & ((kp // cfg.window) == (qp // cfg.window))
    else:
        raise ValueError(cfg.attention)
    # is_global may be a traced per-layer flag (scan-over-layers)
    return jnp.where(jnp.asarray(is_global), m, local)


def mha(
    cfg: ModelConfig,
    shd: ShardingConfig,
    p: Params,
    x: jax.Array,                      # (B, S, d)
    positions: jax.Array,              # (B, S)
    freqs: jax.Array,
    is_global: bool,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
    causal: bool = True,
    use_rope: bool = True,
) -> jax.Array:
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd

    q = jnp.einsum("bsd,dk->bsk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(b, s, h, hd)
    if kv_override is None:
        k = jnp.einsum("bsd,dk->bsk", x, p["wk"])
        v = jnp.einsum("bsd,dk->bsk", x, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = k.reshape(b, s, kv, hd)
        v = v.reshape(b, s, kv, hd)
        if causal and use_rope:  # RoPE on self-attention only (Whisper: learned abs pos)
            k = apply_rope(k, positions, freqs)
        k_pos = positions[0]
    else:
        k, v = kv_override
        k_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    if causal and use_rope:
        q = apply_rope(q, positions, freqs)

    if (cfg.attn_impl == "flash" and kv_override is None
            and s == k.shape[1] and s % 128 == 0):
        out = _attn_flash(cfg, shd, q, k, v, is_global, causal)
    elif cfg.attn_impl == "chunked_q":
        out = _attn_chunked_q(cfg, shd, q, k, v, positions, k_pos,
                              is_global, causal)
    else:
        out = _attn_naive(cfg, shd, q, k, v, positions, k_pos,
                          is_global, causal)
    out = out.reshape(b, s, h * hd)
    out = shard(out, shd, dp(shd), None, shd.tp)
    return jnp.einsum("bsk,kd->bsd", out, p["wo"])


def _attn_naive(cfg, shd, q, k, v, positions, k_pos, is_global, causal):
    """Paper-faithful baseline: full (…,S,S) score materialization."""
    b, s, h, hd = q.shape
    q = shard(q, shd, dp(shd), None, tp_if_divisible(shd, h), None)
    k = shard(k, shd, dp(shd), None, tp_if_divisible(shd, k.shape[2]), None)
    qg = q.reshape(b, s, k.shape[2], -1, hd)     # grouped-query folding
    scores = jnp.einsum("bsgqh,btgh->bgqst", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    mask = _attn_mask(cfg, positions[0], k_pos, is_global, causal)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgqst,btgh->bsgqh", w, v)
    return out


def _attn_flash(cfg, shd, q, k, v, is_global, causal):
    """§Perf optimized path: Pallas flash-attention kernels (fwd + bwd) —
    no S² HBM residency.  KV heads expand to full heads and heads pad to
    a model-axis multiple so the kernel shards evenly via shard_map over
    the ambient mesh (kernels/flash_attention.py)."""
    from repro.kernels import flash_attention as FA

    b, s, h, hd = q.shape
    kvh = k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    t = tp_size(shd)
    h_pad = ((h + t - 1) // t) * t
    if h_pad != h:
        pad = ((0, 0), (0, 0), (0, h_pad - h), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)

    interpret = jax.default_backend() != "tpu"
    window = cfg.window if cfg.attention in ("sliding", "chunked") else 0
    glob = jnp.asarray(is_global, jnp.int32).reshape(1)

    def local(qs, ks, vs, g):
        bl, sl, hl, _ = qs.shape
        fold = lambda x: x.transpose(0, 2, 1, 3).reshape(bl * hl, sl, hd)
        o = FA.flash_attention_nhsd(
            fold(qs), fold(ks), fold(vs), cfg.attention, window, causal,
            g[0] != 0, FA.BQ, FA.BK, interpret)
        return o.reshape(bl, hl, sl, hd).transpose(0, 2, 1, 3)

    mesh = None
    if shd.enabled:
        try:
            m = jax.sharding.get_abstract_mesh()
            mesh = None if (m is None or m.empty) else m
        except Exception:
            mesh = None
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        spec = P(dp(shd), None, shd.tp, None)
        out = shard_map(local, mesh=mesh, in_specs=(spec,) * 3 + (P(None),),
                        out_specs=spec, check_rep=False)(q, k, v, glob)
    else:
        out = local(q, k, v, glob)
    return out[:, :, :h, :]


def _attn_chunked_q(cfg, shd, q, k, v, positions, k_pos, is_global, causal):
    """§Perf optimized path: scan over query chunks with exact row
    softmax — peak scores residency is (b, h, Qc, S) per chunk instead of
    (b, h, S, S); KV heads are expanded to full heads so the head dim
    shards evenly over the model axis (beyond-paper change, EXPERIMENTS.md
    §Perf iteration 1)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    if kvh != h:                                  # GQA → full heads
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    q = shard(q, shd, dp(shd), None, shd.tp, None)
    k = shard(k, shd, dp(shd), None, shd.tp, None)
    v = shard(v, shd, dp(shd), None, shd.tp, None)
    qc = min(cfg.attn_q_chunk, s)
    nc = s // qc if s % qc == 0 else 1
    qc = s // nc
    scale = 1.0 / math.sqrt(hd)
    q_chunks = q.reshape(b, nc, qc, h, hd).transpose(1, 0, 2, 3, 4)
    pos_chunks = positions[0].reshape(nc, qc)

    def chunk_fn(_, inp):
        qb, pos_q = inp                           # (b,qc,h,hd), (qc,)
        sc = jnp.einsum("bqhd,bthd->bhqt", qb, k).astype(jnp.float32) * scale
        mask = _attn_mask(cfg, pos_q, k_pos, is_global, causal)
        sc = jnp.where(mask[None, None], sc, -1e30)
        w = jax.nn.softmax(sc, axis=-1).astype(qb.dtype)
        ob = jnp.einsum("bhqt,bthd->bqhd", w, v)
        return None, ob

    _, out_chunks = jax.lax.scan(chunk_fn, None, (q_chunks, pos_chunks))
    out = out_chunks.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return out


def kv_groups(cfg: ModelConfig, k: jax.Array) -> int:
    return k.shape[2]


def _tp_size(shd: ShardingConfig) -> int:
    return 1  # resolved by GSPMD; constraint validity handled by `shard`


# -- GLU MLP -----------------------------------------------------------------------

def mlp_init(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Params:
    dt = jnp.dtype(cfg.dtype)
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], cfg.d_model, f, dt),
        "w_up": dense_init(ks[1], cfg.d_model, f, dt),
        "w_down": dense_init(ks[2], f, cfg.d_model, dt),
    }


def _act(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "silu":
        return jax.nn.silu(x)
    if cfg.act == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(cfg.act)


def mlp(cfg: ModelConfig, shd: ShardingConfig, p: Params, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    hdn = _act(cfg, g) * u
    hdn = shard(hdn, shd, dp(shd), None, shd.tp)
    return jnp.einsum("bsf,fd->bsd", hdn, p["w_down"])


# -- embeddings ----------------------------------------------------------------------

def embed_init(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2)
    p = {"tok": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["out"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size, dt)
    return p


def embed(cfg: ModelConfig, shd: ShardingConfig, p: Params, tokens: jax.Array) -> jax.Array:
    e = jnp.take(p["tok"], tokens, axis=0)
    return shard(e, shd, dp(shd), None, None)


def unembed(cfg: ModelConfig, shd: ShardingConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["tok"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["out"])
    return shard(logits, shd, dp(shd), None, shd.tp)
