"""Selective SSM (Mamba-2 / SSD form) for the Hymba hybrid heads.

Chunked "state-space dual" algorithm: scalar per-head decay a_t, input
projection B_t, readout C_t, state size N (= cfg.ssm_state):

    h_t = exp(a_t) · h_{t-1} + B_t ⊗ x_t         (h: (H, P, N))
    y_t = C_t · h_t

Training uses chunk-parallel form (intra-chunk masked quadratic + inter-
chunk state scan) so the materialized state is (B, S/Q, H, P, N) at chunk
boundaries only — the memory-feasible adaptation for 4k–500k contexts.
Decoding is the O(1) recurrence.

Note (DESIGN.md): Hymba's Mamba-1 (diagonal per-channel A) is simplified
to Mamba-2's scalar-per-head A — the SSD parallel form requires it, and
it is the TPU-native (matmul-friendly) variant of the same insight.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShardingConfig
from repro.models.layers import Params, dense_init, dp, shard

CHUNK = 128


def mamba_heads(cfg: ModelConfig) -> Tuple[int, int]:
    """(num_heads, head_dim) of the SSM branch — mirrors attention heads."""
    d_inner = cfg.ssm_expand * cfg.d_model
    h = cfg.num_heads
    return h, d_inner // h


def mamba_init(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    h, p_dim = mamba_heads(cfg)
    n = cfg.ssm_state
    d_inner = h * p_dim
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], d, d_inner, dt),       # value path
        "w_z": dense_init(ks[1], d, d_inner, dt),       # gate path
        "w_B": dense_init(ks[2], d, h * n, dt),
        "w_C": dense_init(ks[3], d, h * n, dt),
        "w_dt": dense_init(ks[4], d, h, dt),            # per-head step size
        "A_log": jnp.zeros((h,), jnp.float32),          # a = -exp(A_log)·softplus(dt)
        "w_out": dense_init(ks[5], d_inner, d, dt),
    }


def _proj(cfg, p, x):
    b, s, d = x.shape
    h, pd = mamba_heads(cfg)
    n = cfg.ssm_state
    xv = jnp.einsum("bsd,di->bsi", x, p["w_x"]).reshape(b, s, h, pd)
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"]).reshape(b, s, h, pd)
    bm = jnp.einsum("bsd,di->bsi", x, p["w_B"]).reshape(b, s, h, n)
    cm = jnp.einsum("bsd,di->bsi", x, p["w_C"]).reshape(b, s, h, n)
    dt_ = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)
    )
    a = -jnp.exp(p["A_log"])[None, None] * dt_          # (B,S,H) log-decay ≤ 0
    return xv, z, bm, cm, dt_, a


def mamba_scan(
    cfg: ModelConfig, shd: ShardingConfig, p: Params, x: jax.Array,
    return_state: bool = False,
):
    """Training/prefill path — chunked SSD. x: (B, S, d) → (B, S, d)."""
    b, s, d = x.shape
    h, pd = mamba_heads(cfg)
    n = cfg.ssm_state
    q = min(CHUNK, s)
    assert s % q == 0, (s, q)
    nc = s // q

    xv, z, bm, cm, dt_, a = _proj(cfg, p, x)
    xv = xv * dt_[..., None]                            # fold Δt into input
    # reshape to chunks
    ch = lambda t: t.reshape(b, nc, q, *t.shape[2:])
    xv, bm, cm, a = ch(xv.astype(jnp.float32)), ch(bm.astype(jnp.float32)), ch(cm.astype(jnp.float32)), ch(a)

    acs = jnp.cumsum(a, axis=2)                         # (B,NC,Q,H) within-chunk
    # --- intra-chunk (masked quadratic in Q) ---
    decay = acs[:, :, :, None, :] - acs[:, :, None, :, :]   # (B,NC,Qq,Qk,H)
    iota = jnp.arange(q)
    causal = (iota[:, None] >= iota[None, :])[None, None, :, :, None]
    gm = jnp.where(causal, jnp.exp(decay), 0.0)              # (B,NC,Q,Q,H)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", cm, bm) * gm
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xv)

    # --- chunk states + inter-chunk scan ---
    tail = acs[:, :, -1:, :] - acs                      # (B,NC,Q,H) decay to chunk end
    st = jnp.einsum("bcqhn,bcqhp,bcqh->bchnp", bm, xv, jnp.exp(tail))
    chunk_decay = jnp.exp(acs[:, :, -1, :])             # (B,NC,H)

    def step(carry, inp):
        st_c, dec = inp
        new = carry * dec[:, :, None, None] + st_c
        return new, carry                                # emit state BEFORE chunk

    init = jnp.zeros((b, h, n, pd), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step,
        init,
        (st.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,NC,H,N,P)

    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", cm, prev_states, jnp.exp(acs))
    y = (y_intra + y_inter).reshape(b, s, h, pd)
    y = y * jax.nn.silu(z.astype(jnp.float32)).reshape(b, s, h, pd)
    y = shard(y, shd, dp(shd), None, shd.tp, None)
    out = jnp.einsum("bsi,id->bsd", y.reshape(b, s, h * pd).astype(x.dtype), p["w_out"])
    if return_state:
        return out, final_state
    return out


def mamba_prefill_state(cfg, shd, p, x):
    """Final (B,H,N,P) state after processing x (prefill priming)."""
    _, st = mamba_scan(cfg, shd, p, x, return_state=True)
    return st


def mamba_decode_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, pd = mamba_heads(cfg)
    return jnp.zeros((batch, h, cfg.ssm_state, pd), dtype)


def mamba_decode_step(
    cfg: ModelConfig, shd: ShardingConfig, p: Params,
    x: jax.Array,            # (B, 1, d)
    state: jax.Array,        # (B, H, N, P)
) -> Tuple[jax.Array, jax.Array]:
    b = x.shape[0]
    h, pd = mamba_heads(cfg)
    xv, z, bm, cm, dt_, a = _proj(cfg, p, x)
    xv = (xv * dt_[..., None]).astype(jnp.float32)[:, 0]   # (B,H,P)
    bm, cm, a = bm.astype(jnp.float32)[:, 0], cm.astype(jnp.float32)[:, 0], a[:, 0]
    new_state = state * jnp.exp(a)[:, :, None, None] + jnp.einsum(
        "bhn,bhp->bhnp", bm, xv
    )
    y = jnp.einsum("bhn,bhnp->bhp", cm, new_state)
    y = y * jax.nn.silu(z.astype(jnp.float32)[:, 0])
    out = jnp.einsum("bsi,id->bsd", y.reshape(b, 1, h * pd).astype(x.dtype), p["w_out"])
    return out, new_state
