"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family; hf]"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256, dtype="float32",
)
