"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 (per expert), vocab=202048, MoE 128 experts top-1 + shared
expert, alternating dense/MoE layers, chunked-local attention (8192)
with periodic global (RoPE-free "NoPE") layers.
[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified]"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    attention="chunked",
    window=8192,
    global_layer_period=4,     # every 4th layer attends globally
    num_experts=128,
    experts_per_token=1,
    moe_layer_period=2,        # interleaved dense / MoE
    num_shared_experts=1,
    rope_theta=500_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama4-smoke", num_layers=4, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, num_experts=4,
    experts_per_token=1, window=32, global_layer_period=2, dtype="float32",
)
