"""xlstm-125m [ssm] — 12 blocks, d_model=768, 4 heads, vocab=50304,
attention-free: mLSTM blocks with sLSTM blocks interleaved (positions
1 and 7, the paper's 7:1-style mix).  d_ff=0 in the assignment — block
MLPs use the xLSTM projection factors (mLSTM 2×, sLSTM 4/3×).
[arXiv:2405.04517; unverified]"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_at=(1, 7),
    scan_layers=False,        # heterogeneous blocks → unrolled
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="xlstm-smoke", num_layers=3, d_model=64, num_heads=2,
    num_kv_heads=2, vocab_size=256, slstm_at=(1,), dtype="float32",
)
