"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    attention="sliding",
    window=4096,
    num_experts=8,
    experts_per_token=2,
    moe_layer_period=1,
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="mixtral-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, num_experts=4,
    experts_per_token=2, window=32, dtype="float32",
)
