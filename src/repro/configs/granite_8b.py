"""granite-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152, llama-arch, code.  [arXiv:2405.04324; hf]"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    rope_theta=10_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="granite-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
)
