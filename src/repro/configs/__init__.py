"""Assigned architecture configs (--arch <id>) + paper-native agents.

Each module exposes ``CONFIG`` (the exact published configuration),
``SMOKE`` (a reduced same-family config for CPU tests), and shares
``input_specs`` from ``repro.configs.shapes``.
"""

from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCH_IDS = [
    "qwen1_5_32b",
    "granite_8b",
    "internlm2_1_8b",
    "command_r_35b",
    "mixtral_8x7b",
    "llama4_maverick_400b_a17b",
    "hymba_1_5b",
    "whisper_medium",
    "xlstm_125m",
    "phi_3_vision_4_2b",
]

# accepted aliases (the assignment spells them with dashes/dots)
ALIASES = {
    "qwen1.5-32b": "qwen1_5_32b",
    "granite-8b": "granite_8b",
    "internlm2-1.8b": "internlm2_1_8b",
    "command-r-35b": "command_r_35b",
    "mixtral-8x7b": "mixtral_8x7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "hymba-1.5b": "hymba_1_5b",
    "whisper-medium": "whisper_medium",
    "xlstm-125m": "xlstm_125m",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
