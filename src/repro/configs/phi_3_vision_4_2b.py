"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064; phi3-mini backbone + CLIP vision stub (input_specs supplies
576 precomputed patch embeddings prepended to the text).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    frontend="vision",
    num_patch_tokens=576,     # CLIP ViT-L/14 @ 336px → 24×24 patches
    rope_theta=10_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="phi3v-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256, num_patch_tokens=8,
    dtype="float32",
)
