"""Assigned input shapes and ShapeDtypeStruct stand-ins (dry-run inputs).

Shapes (LM family — seq_len × global_batch):
    train_4k      4_096 × 256   → lowers train_step (token-Q learner)
    prefill_32k  32_768 × 32    → lowers prefill (actor episode bootstrap)
    decode_32k   32_768 × 128   → lowers serve_step (1 token, 32k KV cache)
    long_500k   524_288 × 1     → serve_step; sub-quadratic archs only

``input_specs`` returns weak-type-correct ShapeDtypeStructs — no device
allocation — for every model input of the given (arch, shape) cell.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524_288, 1, "decode"),
}


def runnable(cfg: ModelConfig, shape: str) -> bool:
    """long_500k is skipped for pure-full-attention archs (DESIGN.md §5)."""
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def token_specs(cfg: ModelConfig, case: ShapeCase) -> Dict[str, Any]:
    """Model inputs for the given cell (tokens + modality stubs)."""
    b, s = case.global_batch, case.seq_len
    specs: Dict[str, Any] = {}
    if case.kind == "decode":
        specs["tokens"] = _sds((b, 1), jnp.int32)
        return specs
    s_text = s
    if cfg.family == "vlm":
        s_text = s - cfg.num_patch_tokens
        specs["extra_embeds"] = _sds((b, cfg.num_patch_tokens, cfg.d_model),
                                     jnp.bfloat16)
    if cfg.family == "audio":
        specs["extra_embeds"] = _sds((b, cfg.encoder_seq, cfg.d_model),
                                     jnp.bfloat16)
    specs["tokens"] = _sds((b, s_text), jnp.int32)
    return specs


def learner_batch_specs(cfg: ModelConfig, case: ShapeCase) -> Dict[str, Any]:
    """Transition minibatch for the token-Q learner train_step:
    tokens/actions/rewards/dones per position + PER importance weights."""
    b, s = case.global_batch, case.seq_len
    s_text = s
    specs: Dict[str, Any] = {}
    if cfg.family == "vlm":
        s_text = s - cfg.num_patch_tokens
        specs["extra_embeds"] = _sds((b, cfg.num_patch_tokens, cfg.d_model),
                                     jnp.bfloat16)
    if cfg.family == "audio":
        specs["extra_embeds"] = _sds((b, cfg.encoder_seq, cfg.d_model),
                                     jnp.bfloat16)
    specs.update(
        tokens=_sds((b, s_text), jnp.int32),
        actions=_sds((b, s_text), jnp.int32),
        rewards=_sds((b, s_text), jnp.float32),
        dones=_sds((b, s_text), jnp.float32),
        is_weights=_sds((b,), jnp.float32),
    )
    return specs
