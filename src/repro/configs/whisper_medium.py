"""whisper-medium [audio] — enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865; conv frontend is a
STUB (input_specs supplies precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_layers=24,
    encoder_seq=1500,
    frontend="audio",
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="whisper-smoke", num_layers=2, encoder_layers=2,
    d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
    encoder_seq=32, dtype="float32",
)
