"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16, parallel attention + mamba heads; sliding
window attention with 3 global layers (first/middle/last).
[arXiv:2411.13676; hf]"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    attention="sliding",
    window=1024,
    global_layers=(0, 15, 31),   # first / middle / last attend globally
    ssm_state=16,
    hybrid=True,
    ssm_expand=2,
    rope_theta=10_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="hymba-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, window=32, ssm_state=4,
    dtype="float32",
)
