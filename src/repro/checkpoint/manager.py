"""Fault-tolerant checkpointing: atomic-rename npz shards + manifest.

Design for 1000+ nodes (DESIGN.md §4.5):
  * each host writes only its local shards (here: single-host writes all);
  * a checkpoint directory is staged as ``step_<n>.tmp`` and committed by
    a single atomic ``rename`` — a crash mid-save can never corrupt the
    latest valid checkpoint;
  * ``save_async`` snapshots to host memory synchronously (cheap) and
    writes in a background thread so the train loop never blocks on disk;
  * ``restore_latest`` scans for the newest *committed* step, validates
    the manifest, and reconstructs the pytree (optionally resharding onto
    a different mesh — elastic restart, see elastic.py);
  * keep-last-k GC bounds disk usage.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

_SEP = "/"

def _encode(v: np.ndarray) -> np.ndarray:
    return np.asarray(v)       # ml_dtypes (bf16 etc.) save as raw V-kind


def _decode(raw: np.ndarray, dtype) -> np.ndarray:
    """npz loads ml_dtypes arrays back as void — re-view from manifest."""
    if raw.dtype.kind == "V":
        return raw.view(np.dtype(dtype))
    return raw


def _flatten_with_paths(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                        for k in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Pytree,
             extra: Optional[Dict[str, bytes]] = None) -> str:
        """Synchronous atomic save; returns the committed path.
        ``extra`` maps filenames to opaque byte blobs committed inside
        the same atomic rename as the arrays — side-state that must
        stay consistent with the tree (the replay service's seq tables,
        a pickled params blob) rides the same crash guarantee."""
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host, extra)

    def save_async(self, step: int, tree: Pytree) -> None:
        """Snapshot now, write in background (previous write is joined
        first so at most one outstanding save exists — bounded memory)."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(x), tree)  # device→host now
        self._thread = threading.Thread(target=self._write, args=(step, host))
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Pytree,
               extra: Optional[Dict[str, bytes]] = None) -> str:
        flat, _ = _flatten_with_paths(host_tree)
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: _encode(v) for k, v in flat.items()})
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(np.shape(v)) for k, v in flat.items()},
            "dtypes": {k: str(np.asarray(v).dtype) for k, v in flat.items()},
            "extra": sorted(extra.keys()) if extra else [],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        for name, blob in (extra or {}).items():
            if name in ("arrays.npz", "manifest.json") or _SEP in name:
                raise ValueError(f"extra blob name {name!r}: reserved or "
                                 f"contains a path separator")
            with open(os.path.join(tmp, name), "wb") as f:
                f.write(blob)
        if os.path.exists(final):
            # re-saving an existing step (restart at the same point):
            # rename over a non-empty dir is an error on POSIX, so retire
            # the old commit first — the window with neither dir present
            # only loses an already-superseded copy of this same step.
            shutil.rmtree(final)
        os.rename(tmp, final)       # atomic commit
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore -------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def restore(self, step: int, example: Pytree) -> Pytree:
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_ex, treedef = _flatten_with_paths(example)
        if sorted(flat_ex.keys()) != manifest["keys"]:
            missing = set(manifest["keys"]) ^ set(flat_ex.keys())
            raise ValueError(f"manifest/tree mismatch: {sorted(missing)[:5]} ...")
        leaves = []
        flat_struct, _ = jax.tree_util.tree_flatten_with_path(example)
        for (path_k, ex) in flat_struct:
            key = _SEP.join(
                str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                for k in path_k)
            dt = getattr(ex, "dtype", None)
            arr = _decode(data[key], manifest["dtypes"][key])
            leaves.append(jnp.asarray(arr, dt))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, example: Pytree) -> Tuple[Optional[int], Pytree]:
        steps = self.all_steps()
        if not steps:
            return None, example
        return steps[-1], self.restore(steps[-1], example)

    def read_extra(self, step: int, name: str) -> Optional[bytes]:
        """Read one ``extra`` blob from a committed step; None when the
        step carries no blob by that name (restore paths treat missing
        side-state as absent, not corrupt — the rename was atomic)."""
        path = os.path.join(self.dir, f"step_{step}", name)
        if not os.path.exists(path):
            return None
        with open(path, "rb") as f:
            return f.read()
