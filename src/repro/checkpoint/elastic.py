"""Elastic restart: reshard a restored pytree onto a (possibly different)
mesh.  A job checkpointed on a 2-pod 512-chip mesh can restart on a
single 256-chip pod (or vice versa): restore() yields host-resident full
arrays; ``reshard`` device_puts each leaf with the sharding derived from
the *current* mesh + the model's PartitionSpec tree.  Straggler/failure
policy (DESIGN.md §4.5): on node loss, the job restarts from the last
committed step on the surviving slice — actor shards refill the replay
buffer (not checkpointed by default, matching the paper's process-local
buffers), learner state resumes exactly.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any


def _filter_spec(mesh: Mesh, spec: P) -> P:
    """Drop axis names that don't exist in the current mesh (elastic
    shrink: a 'pod' axis from a multi-pod checkpoint vanishes on 1 pod)."""
    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in mesh.axis_names)
            return kept if kept else None
        return entry if entry in mesh.axis_names else None

    return P(*(keep(e) for e in spec))


def reshard(tree: Pytree, specs: Pytree, mesh: Mesh) -> Pytree:
    """device_put every leaf with its (mesh-filtered) NamedSharding.

    ``specs`` mirrors ``tree`` down to ``PartitionSpec`` leaves (``None``
    means replicated); any registered pytree container — dicts, the
    agent-state dataclasses, optax's NamedTuple states — is descended,
    so a whole learner state reshards in one call (the service's
    restart-from-checkpoint path, DESIGN.md §11).
    """
    def put(x, spec):
        s = NamedSharding(mesh, _filter_spec(mesh, spec or P()))
        return jax.device_put(x, s)

    spec_leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: x is None or isinstance(x, P))
    leaves = treedef.flatten_up_to(tree)
    return jax.tree_util.tree_unflatten(
        treedef, [put(x, s) for x, s in zip(leaves, spec_leaves)])
