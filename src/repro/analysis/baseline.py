"""Baseline bookkeeping: the committed ``analysis/baseline.json``.

The baseline is the escape hatch that lets the gate be blocking from
day one: findings recorded in it are known debt, not new breakage.  A
baselined finding is matched by ``(file, rule, snippet)`` — the
*stripped source line text*, not the line number — so unrelated edits
that shift lines don't resurrect old findings, while editing the
flagged line itself (you touched it, you own it) does.  Matching is a
multiset: two identical findings in the baseline absorb at most two
fresh ones.

The file is written sorted and newline-terminated so a fresh
``--write-baseline`` over an unchanged repo is byte-identical to the
committed one (the stale-baseline meta-test asserts exactly that).
"""

from __future__ import annotations

import json
from typing import Dict, List, Tuple

from repro.analysis.common import Finding

BASELINE_VERSION = 1

Key = Tuple[str, str, str]  # (file, rule, stripped snippet)


def finding_key(finding: Finding, snippet: str) -> Key:
    return (finding.file, finding.rule, snippet.strip())


def to_payload(findings: List[Tuple[Finding, str]]) -> dict:
    entries = [
        {"file": f.file, "line": f.line, "rule": f.rule, "name": f.name,
         "snippet": snippet.strip(), "message": f.message}
        for f, snippet in sorted(findings, key=lambda fs: fs[0])]
    return {"version": BASELINE_VERSION, "findings": entries}


def render(payload: dict) -> str:
    return json.dumps(payload, indent=2, sort_keys=True) + "\n"


def load(path: str) -> Dict[Key, int]:
    """key → multiplicity; missing file = empty baseline."""
    try:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    except FileNotFoundError:
        return {}
    if not text.strip():
        return {}
    payload = json.loads(text)
    counts: Dict[Key, int] = {}
    for e in payload.get("findings", ()):
        key = (e["file"], e["rule"], e.get("snippet", ""))
        counts[key] = counts.get(key, 0) + 1
    return counts


def subtract(findings: List[Tuple[Finding, str]],
             baseline: Dict[Key, int]
             ) -> Tuple[List[Tuple[Finding, str]], int]:
    """(fresh findings not absorbed by the baseline, absorbed count)."""
    remaining = dict(baseline)
    fresh: List[Tuple[Finding, str]] = []
    absorbed = 0
    for f, snippet in findings:
        key = finding_key(f, snippet)
        if remaining.get(key, 0) > 0:
            remaining[key] -= 1
            absorbed += 1
        else:
            fresh.append((f, snippet))
    return fresh, absorbed
