"""Pass 1 — donation safety (DESIGN.md §12, rules D101/D102).

The replay-donation contract of DESIGN.md §9: every executor chunk
donates the replay state (tree + storage) at the jit boundary, so the
caller must treat the donated binding as *consumed* — reading it after
the call is a use-after-free that XLA only reports lazily (``Array has
been deleted``) and only on paths that actually materialize the buffer.

  * **D101 use-after-donate** — for every call through a
    ``jax.jit(..., donate_argnums=…)`` value, any read of the expression
    passed at a donated position after the call (before the binding is
    reassigned) is flagged.  Tracked bindings are plain names and dotted
    attribute paths (``state.replay``); reads of a *sub*-path
    (``state.replay.tree``) count too.
  * **D102 argnum-misalignment** — a ``donate_argnums``/``static_argnums``
    index that falls outside the resolved callee's positional signature
    (the silent drift mode: someone adds a leading argument to the
    chunk function and the donation quietly lands on the wrong buffer
    or errors at trace time).  Callees are resolved through lexical
    ``def``s, lambdas, and one level of ``shard_map(fn, …)``;
    ``functools.partial`` shifts positions unpredictably and is skipped.

Both rules also cover ``@functools.partial(jax.jit, donate_argnums=…)``
decorators and immediately-invoked ``jax.jit(f, …)(args)`` forms.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.common import (Finding, SourceFile, ancestors,
                                   const_int_tuple, enclosing_function,
                                   positional_params, register_rules,
                                   resolve_local_def)

register_rules({
    "D101": "donation-use-after-donate",
    "D102": "donation-argnum-mismatch",
})

Path = Tuple[str, ...]


def _is_jit(qn: Optional[str]) -> bool:
    return qn in ("jax.jit", "jax.experimental.pjit.pjit")


def _is_shard_map(qn: Optional[str]) -> bool:
    return qn is not None and qn.split(".")[-1] == "shard_map"


def _is_partial(qn: Optional[str]) -> bool:
    return qn in ("functools.partial", "partial")


def _argnums(call: ast.Call, name: str) -> Optional[Tuple[int, ...]]:
    for kw in call.keywords:
        if kw.arg == name:
            return const_int_tuple(kw.value)
    return None


def _expr_path(node: ast.AST) -> Optional[Path]:
    """("state", "replay") for ``state.replay``; None for anything
    dynamic (calls, subscripts, literals)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _resolve_callee(node: ast.AST, sf: SourceFile) -> Optional[ast.AST]:
    """The function whose signature the jit argnums index: a lexical
    def, a lambda, or (through one shard_map wrapper) either."""
    if isinstance(node, ast.Lambda):
        return node
    if isinstance(node, ast.Name):
        return resolve_local_def(node.id, node)
    if isinstance(node, ast.Call):
        qn = sf.qualname(node.func)
        if _is_shard_map(qn) and node.args:
            return _resolve_callee(node.args[0], sf)
    return None


class _DonatedFn:
    def __init__(self, jit_call: ast.Call, donate: Tuple[int, ...],
                 static: Tuple[int, ...], callee: Optional[ast.AST]):
        self.jit_call = jit_call
        self.donate = donate
        self.static = static
        self.callee = callee


def _check_alignment(sf: SourceFile, fn: _DonatedFn,
                     findings: List[Finding]) -> None:
    overlap = sorted(set(fn.donate) & set(fn.static))
    if overlap:
        findings.append(sf.finding(
            fn.jit_call, "D102",
            f"argnums {overlap} are both donated and static — a static "
            "argument has no buffer to alias"))
    if fn.callee is None:
        return
    params = positional_params(fn.callee)
    if fn.callee.args.vararg is not None:
        return  # *args absorbs any index
    for label, nums in (("donate_argnums", fn.donate),
                        ("static_argnums", fn.static)):
        for i in nums:
            if i >= len(params) or i < -len(params):
                findings.append(sf.finding(
                    fn.jit_call, "D102",
                    f"{label} index {i} is out of range for the callee's "
                    f"{len(params)} positional parameter(s) "
                    f"({', '.join(params) or 'none'}) — the argnums have "
                    "drifted out of alignment with the signature"))


def _contains(outer: ast.AST, inner: ast.AST) -> bool:
    return any(a is outer for a in ancestors(inner)) or outer is inner


def _stores_in(scope: ast.AST) -> List[Tuple[int, Path]]:
    """(line, path) of every rebind: assignment targets, aug-assigns,
    for-targets, with-as names — the events that end a donated
    binding's lifetime."""
    out: List[Tuple[int, Path]] = []

    def targets(node):
        if isinstance(node, (ast.Tuple, ast.List)):
            for el in node.elts:
                yield from targets(el)
        elif isinstance(node, ast.Starred):
            yield from targets(node.value)
        else:
            yield node

    for node in ast.walk(scope):
        tgts: Sequence[ast.AST] = ()
        if isinstance(node, ast.Assign):
            tgts = [t for tgt in node.targets for t in targets(tgt)]
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            tgts = list(targets(node.target))
        elif isinstance(node, ast.For):
            tgts = list(targets(node.target))
        elif isinstance(node, ast.withitem) and node.optional_vars:
            tgts = list(targets(node.optional_vars))
        for t in tgts:
            path = _expr_path(t)
            if path is not None:
                out.append((getattr(t, "lineno", 0), path))
    return out


def _loads_of(scope: ast.AST, path: Path,
              exclude_within: ast.AST) -> List[int]:
    """Lines where ``path`` (or a sub-path of it) is read, outside the
    donating call itself.  Deduped per line."""
    lines = set()
    for node in ast.walk(scope):
        if not isinstance(node, (ast.Name, ast.Attribute)):
            continue
        if not isinstance(getattr(node, "ctx", None), ast.Load):
            continue
        p = _expr_path(node)
        if p is None or p[:len(path)] != path:
            continue
        if _contains(exclude_within, node):
            continue
        lines.add(node.lineno)
    return sorted(lines)


def _check_use_after(sf: SourceFile, call: ast.Call, donated: _DonatedFn,
                     findings: List[Finding]) -> None:
    for pos in donated.donate:
        if pos < 0 or pos >= len(call.args):
            continue
        path = _expr_path(call.args[pos])
        if path is None:
            continue  # dynamic expression: no binding survives to read
        scope = enclosing_function(call) or sf.tree
        stores = _stores_in(scope)
        rebind_lines = sorted(
            line for line, spath in stores
            if spath == path or spath == path[:1])
        first_rebind = min((ln for ln in rebind_lines
                            if ln >= call.lineno), default=None)
        loop = next((a for a in ancestors(call)
                     if isinstance(a, (ast.For, ast.While))), None)
        for line in _loads_of(scope, path, call):
            after_linear = (line > call.lineno
                            and (first_rebind is None or line < first_rebind))
            # a read earlier in a loop body still follows the donation on
            # the next iteration unless the binding is rebound in the loop
            in_loop = (loop is not None
                       and loop.lineno <= line <= (loop.end_lineno or line)
                       and not any(loop.lineno <= ln <= (loop.end_lineno or 0)
                                   for ln in rebind_lines))
            if after_linear or in_loop:
                findings.append(Finding(
                    sf.relpath, line, "D101",
                    f"`{'.'.join(path)}` is read after being donated to "
                    f"the jitted call on line {call.lineno} "
                    "(donate_argnums aliases the buffer — use the "
                    "returned value instead)"))


def run(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    jitted_by_name: Dict[str, _DonatedFn] = {}

    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call) or not _is_jit(sf.qualname(node.func)):
            continue
        donate = _argnums(node, "donate_argnums") or ()
        static = _argnums(node, "static_argnums") or ()
        if not donate and not static:
            continue
        callee = _resolve_callee(node.args[0], sf) if node.args else None
        fn = _DonatedFn(node, donate, static, callee)
        _check_alignment(sf, fn, findings)
        if not donate:
            continue
        parent = getattr(node, "_rl_parent", None)
        if isinstance(parent, ast.Assign) and len(parent.targets) == 1 \
                and isinstance(parent.targets[0], ast.Name):
            jitted_by_name[parent.targets[0].id] = fn
        elif isinstance(parent, ast.Call) and parent.func is node:
            # immediately invoked: jax.jit(f, donate_argnums=…)(x, y)
            _check_use_after(sf, parent, fn, findings)

    # decorator form: @functools.partial(jax.jit, donate_argnums=…)
    for node in ast.walk(sf.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            if not isinstance(dec, ast.Call) or not dec.args:
                continue
            if not (_is_partial(sf.qualname(dec.func))
                    and _is_jit(sf.qualname(dec.args[0]))):
                continue
            donate = _argnums(dec, "donate_argnums") or ()
            static = _argnums(dec, "static_argnums") or ()
            if donate or static:
                fn = _DonatedFn(dec, donate, static, node)
                _check_alignment(sf, fn, findings)
                if donate:
                    jitted_by_name[node.name] = fn

    # call sites of named donated functions
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in jitted_by_name:
            _check_use_after(sf, node, jitted_by_name[node.func.id], findings)
    return findings
