"""Pass 4 — retrace hazards in jitted/scanned functions (rules
R401/R402/R403).

Three ways a traced function goes wrong that the type system cannot
see and unit tests only catch if they hit the exact shape/path:

  * **R401 retrace-traced-branch** — a Python ``if``/``while`` on a
    traced parameter of a jitted/scanned function.  At best this is a
    TracerBoolConversionError at trace time; with ``static_argnums`` it
    silently becomes a retrace per distinct value.  Exemptions cover
    the legitimate trace-time predicates: ``x is None`` /
    ``is not None``, shape/dtype introspection (``.shape``/``.ndim``/
    ``.dtype``/``.size``), ``len()``/``isinstance()``/``hasattr()``,
    and ``jax.tree`` structure queries — those resolve during tracing,
    uniformly.  Parameters named static by ``static_argnums``/
    ``static_argnames`` are exempt by construction.
  * **R402 retrace-mutable-closure** — a traced function that *writes*
    ``self.<attr>`` or a ``global``/``nonlocal`` binding.  The write
    happens once, at trace time; every later call silently skips it
    (or worse, a retrace re-runs it), so the state and the compiled
    computation drift apart.
  * **R403 retrace-unhashable-static** — a call to a jitted function
    passing a ``list``/``dict``/``set`` literal at a position named in
    ``static_argnums``: static args key the compile cache and must be
    hashable — this raises at call time, but only on the call path that
    uses the literal.

Traced functions are collected from ``jax.jit``/``jax.lax.scan``/
``shard_map``/``pmap`` call sites (resolved through names and lambdas)
and ``@jax.jit``/``@partial(jax.jit, …)`` decorators.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.common import (Finding, SourceFile, ancestors,
                                   const_int_tuple, positional_params,
                                   register_rules, resolve_local_def)

register_rules({
    "R401": "retrace-traced-branch",
    "R402": "retrace-mutable-closure",
    "R403": "retrace-unhashable-static",
})

_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}
_TRACE_TIME_CALLS = {
    "len", "isinstance", "hasattr", "getattr", "type", "callable",
}
_TRACE_TIME_CALL_PREFIXES = ("jax.tree.", "jax.tree_util.")


def _is_jit(qn: Optional[str]) -> bool:
    return qn in ("jax.jit", "jax.experimental.pjit.pjit")


def _is_tracer_entry(qn: Optional[str]) -> bool:
    if qn is None:
        return False
    if _is_jit(qn) or qn in ("jax.lax.scan", "jax.lax.while_loop",
                             "jax.lax.fori_loop", "jax.checkpoint",
                             "jax.remat", "jax.vmap", "jax.grad",
                             "jax.value_and_grad"):
        return True
    return qn.split(".")[-1] in ("shard_map", "pmap")


def _static_names(call: ast.Call, fn: ast.AST) -> Set[str]:
    """Parameter names excluded from tracing by static_argnums/names."""
    params = positional_params(fn)
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for i in const_int_tuple(kw.value) or ():
                if -len(params) <= i < len(params):
                    names.add(params[i])
        elif kw.arg == "static_argnames":
            vals = [kw.value] if isinstance(kw.value, ast.Constant) \
                else list(getattr(kw.value, "elts", []))
            for el in vals:
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    names.add(el.value)
    return names


class _TracedFn:
    def __init__(self, fn: ast.AST, static: Set[str], how: str):
        self.fn = fn
        self.static = static
        self.how = how  # "jax.jit", "jax.lax.scan", ... for messages


def _collect_traced(sf: SourceFile) -> List[_TracedFn]:
    out: List[_TracedFn] = []
    seen: Set[int] = set()

    def add(fn_ref: ast.AST, call: Optional[ast.Call], how: str) -> None:
        fn: Optional[ast.AST] = None
        if isinstance(fn_ref, ast.Lambda):
            fn = fn_ref
        elif isinstance(fn_ref, ast.Name):
            fn = resolve_local_def(fn_ref.id, fn_ref)
        elif isinstance(fn_ref, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn = fn_ref
        if fn is None or id(fn) in seen:
            return
        seen.add(id(fn))
        static = _static_names(call, fn) if call is not None else set()
        out.append(_TracedFn(fn, static, how))

    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call):
            qn = sf.qualname(node.func)
            if _is_tracer_entry(qn) and node.args:
                add(node.args[0], node, qn or "jit")
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                qn = sf.qualname(dec)
                if _is_tracer_entry(qn):
                    add(node, None, qn or "jit")
                elif isinstance(dec, ast.Call):
                    qn = sf.qualname(dec.func)
                    if _is_tracer_entry(qn):
                        add(node, dec, qn or "jit")
                    elif dec.args and _is_tracer_entry(sf.qualname(dec.args[0])):
                        add(node, dec, sf.qualname(dec.args[0]) or "jit")
    return out


def _exempted(name_node: ast.Name, test: ast.AST, sf: SourceFile) -> bool:
    """Is this reference to a traced param inside a construct that
    resolves at trace time (is-None check, shape probe, len/isinstance,
    tree-structure query)?"""
    prev: ast.AST = name_node
    for anc in ancestors(name_node):
        if isinstance(anc, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in anc.ops):
            return True
        if isinstance(anc, ast.Attribute) and anc.attr in _SHAPE_ATTRS:
            return True
        if isinstance(anc, ast.Call) and prev is not anc.func:
            qn = sf.qualname(anc.func)
            if qn is not None and (
                    qn in _TRACE_TIME_CALLS
                    or any(qn.startswith(p)
                           for p in _TRACE_TIME_CALL_PREFIXES)):
                return True
        if anc is test:
            return False
        prev = anc
    return False


def _check_traced_branch(sf: SourceFile, traced: _TracedFn,
                         findings: List[Finding]) -> None:
    params = set(positional_params(traced.fn)) | {
        a.arg for a in traced.fn.args.kwonlyargs}
    params -= traced.static
    params.discard("self")
    for node in ast.walk(traced.fn):
        test: Optional[ast.AST] = None
        if isinstance(node, (ast.If, ast.While, ast.IfExp)):
            test = node.test
        elif isinstance(node, ast.Assert):
            test = node.test
        if test is None:
            continue
        for ref in ast.walk(test):
            if isinstance(ref, ast.Name) and ref.id in params \
                    and isinstance(ref.ctx, ast.Load) \
                    and not _exempted(ref, test, sf):
                findings.append(sf.finding(
                    node, "R401",
                    f"Python branch on traced parameter `{ref.id}` inside "
                    f"a function traced by {traced.how} — this is a "
                    "TracerBoolConversionError at best and a per-value "
                    "retrace at worst; use jax.lax.cond/select or mark "
                    "the argument static"))
                break


def _module_mutables(sf: SourceFile) -> Set[str]:
    """Module-level names rebound more than once, or rebound from inside
    a function via ``global`` — the mutable module state a traced
    closure silently freezes."""
    top_assigns: Dict[str, int] = {}
    body = getattr(sf.tree, "body", [])
    for stmt in body:
        targets: List[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        for t in targets:
            if isinstance(t, ast.Name):
                top_assigns[t.id] = top_assigns.get(t.id, 0) + \
                    (2 if isinstance(stmt, ast.AugAssign) else 1)
    from_global: Set[str] = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Global):
            from_global.update(node.names)
    return {n for n, c in top_assigns.items() if c > 1} | from_global


def _check_mutable_closure(sf: SourceFile, traced: _TracedFn,
                           mutables: Set[str],
                           findings: List[Finding]) -> None:
    reported: Set[Tuple[int, str]] = set()

    def report(node: ast.AST, what: str, detail: str) -> None:
        key = (getattr(node, "lineno", 0), what)
        if key in reported:
            return
        reported.add(key)
        findings.append(sf.finding(
            node, "R402",
            f"traced function ({traced.how}) {detail} — the effect "
            "happens once at trace time, then the compiled function and "
            "the Python state silently diverge"))

    for node in ast.walk(traced.fn):
        if isinstance(node, (ast.Global, ast.Nonlocal)):
            report(node, ",".join(node.names),
                   f"rebinds {node.__class__.__name__.lower()} "
                   f"name(s) {', '.join(node.names)}")
        elif isinstance(node, ast.Attribute) \
                and isinstance(getattr(node, "ctx", None), ast.Store) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == "self":
            report(node, node.attr, f"writes self.{node.attr}")
        elif isinstance(node, ast.Name) \
                and isinstance(node.ctx, ast.Load) and node.id in mutables:
            report(node, node.id,
                   f"closes over mutable module-level `{node.id}` "
                   "(rebound elsewhere in this module)")


def _check_unhashable_static(sf: SourceFile, findings: List[Finding]) -> None:
    # named jitted fns with static positions: var = jax.jit(f, static_argnums=…)
    jitted: Dict[str, Tuple[Tuple[int, ...], ast.AST]] = {}
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call) or not _is_jit(sf.qualname(node.func)):
            continue
        static = None
        for kw in node.keywords:
            if kw.arg == "static_argnums":
                static = const_int_tuple(kw.value)
        if not static:
            continue
        parent = getattr(node, "_rl_parent", None)
        if isinstance(parent, ast.Assign) and len(parent.targets) == 1 \
                and isinstance(parent.targets[0], ast.Name):
            jitted[parent.targets[0].id] = (static, node)
        elif isinstance(parent, ast.Call) and parent.func is node:
            _flag_unhashable(sf, parent, static, findings)
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in jitted:
            _flag_unhashable(sf, node, jitted[node.func.id][0], findings)


def _flag_unhashable(sf: SourceFile, call: ast.Call,
                     static: Tuple[int, ...],
                     findings: List[Finding]) -> None:
    for i in static:
        if 0 <= i < len(call.args):
            arg = call.args[i]
            if isinstance(arg, (ast.List, ast.Dict, ast.Set, ast.DictComp,
                                ast.ListComp, ast.SetComp)):
                kind = arg.__class__.__name__.lower().replace("comp", "")
                findings.append(sf.finding(
                    arg, "R403",
                    f"{kind} literal passed at static_argnums position {i} "
                    "— static args key the jit cache and must be hashable "
                    "(use a tuple / frozenset / frozen dataclass)"))


def run(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    mutables = _module_mutables(sf)
    for traced in _collect_traced(sf):
        _check_traced_branch(sf, traced, findings)
        _check_mutable_closure(sf, traced, mutables, findings)
    _check_unhashable_static(sf, findings)
    return findings
