"""Pass 3 — lock discipline in the threaded service layer (rules
L301/L302/L303).

The lock protocol of DESIGN.md §11: ReplayService shard state lives
behind ``self._lock``, the params bus behind ``self._params_cond``, and
the RateLimiter debt window behind ``self._cond``.  The guarded sets
are *inferred*, not declared: any attribute a class assigns under
``with self.<lock>:`` (outside ``__init__``) is treated as
lock-protected everywhere in that class.

  * **L301 lock-unguarded-attr** — a read or write of an inferred
    guarded attribute lexically outside every ``with self.<lock>:``
    block (and outside ``__init__``, which runs before any thread can
    see the object).  Holding *any* of the class's locks satisfies the
    rule — cross-lock confusion is out of scope for a lexical pass.
    Helpers whose callers hold the lock (the RateLimiter predicate
    lambdas) are the intended audience for a def-line
    ``# repro-lint: disable=L301(reason)``.
  * **L302 lock-wait-no-while** — ``self.<cond>.wait(...)`` not inside
    a ``while`` loop: bare waits miss spurious wakeups and notify races;
    ``wait_for`` carries its own predicate loop and is exempt.
  * **L303 lock-notify-unlocked** — ``self.<cond>.notify()`` /
    ``notify_all()`` outside a ``with self.<cond>:`` block for that
    same condition (notify on an unheld Condition raises RuntimeError,
    but only on the code path that actually races).

The pass runs per ``ClassDef``; module-level locks are out of scope
(the repo has none).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.common import (Finding, SourceFile, ancestors,
                                   register_rules)

register_rules({
    "L301": "lock-unguarded-attr",
    "L302": "lock-wait-no-while",
    "L303": "lock-notify-unlocked",
})

_LOCK_TYPES = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


def _lock_attrs(cls: ast.ClassDef, sf: SourceFile) -> Dict[str, str]:
    """attr name → lock type for every ``self.x = threading.Lock()``."""
    out: Dict[str, str] = {}
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        attr = _self_attr(node.targets[0])
        if attr is None or not isinstance(node.value, ast.Call):
            continue
        qn = sf.qualname(node.value.func)
        if qn is None:
            continue
        parts = qn.split(".")
        if parts[-1] in _LOCK_TYPES and (len(parts) == 1
                                         or parts[0] == "threading"):
            out[attr] = parts[-1]
    return out


def _held_locks(node: ast.AST, locks: Dict[str, str],
                stop_at: ast.AST) -> Set[str]:
    """Lock attrs held at ``node``: with-statements on self.<lock>
    between the node and its enclosing method."""
    held: Set[str] = set()
    for anc in ancestors(node):
        if isinstance(anc, ast.With):
            for item in anc.items:
                attr = _self_attr(item.context_expr)
                if attr in locks:
                    held.add(attr)
        if anc is stop_at:
            break
    return held


def _methods(cls: ast.ClassDef) -> List[ast.FunctionDef]:
    return [n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _check_class(sf: SourceFile, cls: ast.ClassDef,
                 findings: List[Finding]) -> None:
    locks = _lock_attrs(cls, sf)
    if not locks:
        return
    conds = {a for a, t in locks.items() if t == "Condition"}
    methods = _methods(cls)

    # infer the guarded set: attrs assigned under a lock outside __init__
    guarded: Set[str] = set()
    for meth in methods:
        if meth.name == "__init__":
            continue
        for node in ast.walk(meth):
            attr = _self_attr(node)
            if attr is None or attr in locks:
                continue
            if isinstance(getattr(node, "ctx", None), (ast.Store, ast.Del)) \
                    and _held_locks(node, locks, meth):
                guarded.add(attr)

    for meth in methods:
        init = meth.name == "__init__"
        for node in ast.walk(meth):
            # L302 / L303: condition-variable protocol
            if isinstance(node, ast.Call):
                cond_attr = None
                if isinstance(node.func, ast.Attribute):
                    cond_attr = _self_attr(node.func.value)
                if cond_attr in conds:
                    op = node.func.attr
                    if op == "wait":
                        in_while = any(isinstance(a, ast.While)
                                       for a in ancestors(node))
                        if not in_while:
                            findings.append(sf.finding(
                                node, "L302",
                                f"self.{cond_attr}.wait() outside a "
                                "predicate `while` loop — spurious "
                                "wakeups and notify races slip through a "
                                "bare wait (or use wait_for)"))
                    elif op in ("notify", "notify_all"):
                        if cond_attr not in _held_locks(node, locks, meth):
                            findings.append(sf.finding(
                                node, "L303",
                                f"self.{cond_attr}.{op}() without holding "
                                f"self.{cond_attr} — notify on an unheld "
                                "Condition raises RuntimeError on the "
                                "racing path"))
            # L301: guarded attr touched lock-free
            attr = _self_attr(node)
            if attr in guarded and not init \
                    and not _held_locks(node, locks, meth):
                verb = ("write" if isinstance(node.ctx, (ast.Store, ast.Del))
                        else "read")
                findings.append(sf.finding(
                    node, "L301",
                    f"{verb} of self.{attr} outside any lock, but the "
                    f"class assigns it under "
                    f"{'/'.join('self.' + a for a in sorted(locks))} — "
                    "either take the lock or suppress on the enclosing "
                    "def with the reason the caller holds it"))


def run(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ClassDef):
            _check_class(sf, node, findings)
    return findings
