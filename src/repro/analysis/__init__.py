"""repro-lint: repo-specific static analysis (DESIGN.md §12).

Four stdlib-``ast`` passes over ``src/``, ``benchmarks/``,
``examples/`` — donation safety (D1xx), collective uniformity (C2xx),
lock discipline (L3xx), retrace hazards (R4xx) — run by
``python -m repro.analysis`` and blocking in CI.

Nothing in this package may import jax, numpy, or anything beyond the
standard library: the CI lint stage runs it without the ML deps, and
tests/test_analysis.py asserts the import list.
"""

from repro.analysis import collectives, donation, locks, retrace
from repro.analysis.common import RULES, Finding, SourceFile

# the pass registry the CLI runs, in report order
PASSES = (donation.run, collectives.run, locks.run, retrace.run)

__all__ = ["PASSES", "RULES", "Finding", "SourceFile"]
