"""Shared infrastructure of the repro-lint passes (DESIGN.md §12).

Everything here is stdlib-only (``ast`` + ``tokenize``): the passes run
in CI before jax is even installed, so no module in ``repro.analysis``
may import jax, numpy, or anything outside the standard library (a
meta-test in tests/test_analysis.py asserts this by scanning our own
imports).

The pieces:

  * ``Finding`` — one structured diagnostic (file:line, rule id, rule
    name, message), the unit every pass emits and the baseline stores;
  * ``SourceFile`` — a parsed module with parent-annotated AST, the
    import alias map (``qualname`` resolves ``jnp.foo`` →
    ``jax.numpy.foo``), and the suppression table parsed from
    ``# repro-lint: disable=RULE(reason)`` comments;
  * scope helpers — ``enclosing_function``, ``resolve_local_def`` (the
    lexical def a ``Name`` refers to, for resolving ``jax.jit(chunk,
    …)`` to ``chunk``'s signature).

Suppression semantics: a disable comment applies to findings on its own
line; a *standalone* comment line applies to the next statement line;
a comment on a ``def``/``class`` line applies to the whole body (how
lock-discipline findings in caller-holds-the-lock helpers are waived).
A disable with an empty reason is itself reported (rule X001) — every
waiver must say why.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Set, Tuple

# rule id → human name; every pass registers its rules here so the CLI
# and the docs enumerate one table
RULES: Dict[str, str] = {
    "X000": "parse-error",
    "X001": "bad-suppression",
}


def register_rules(rules: Dict[str, str]) -> None:
    RULES.update(rules)


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    file: str          # repo-relative posix path
    line: int
    rule: str          # e.g. "D101"
    message: str

    @property
    def name(self) -> str:
        return RULES.get(self.rule, "unknown-rule")

    def render(self) -> str:
        return f"{self.file}:{self.line}: {self.rule} [{self.name}] {self.message}"

    def to_json(self) -> dict:
        return {"file": self.file, "line": self.line, "rule": self.rule,
                "name": self.name, "message": self.message}


_DISABLE_RE = re.compile(
    r"repro-lint:\s*disable=((?:[A-Z]\d{3}\([^()]*\)(?:\s*,\s*)?)+)")
_RULE_RE = re.compile(r"([A-Z]\d{3})\(([^()]*)\)")


def attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._rl_parent = node  # type: ignore[attr-defined]


def ancestors(node: ast.AST) -> Iterable[ast.AST]:
    node = getattr(node, "_rl_parent", None)
    while node is not None:
        yield node
        node = getattr(node, "_rl_parent", None)


FunctionNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def enclosing_function(node: ast.AST) -> Optional[ast.AST]:
    for anc in ancestors(node):
        if isinstance(anc, FunctionNode):
            return anc
    return None


def collect_aliases(tree: ast.AST) -> Dict[str, str]:
    """Imported-name → fully dotted target, so ``qualname`` can resolve
    ``jnp.where`` → ``jax.numpy.where`` and ``shard_map`` →
    ``jax.experimental.shard_map.shard_map``."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    root = a.name.split(".")[0]
                    aliases[root] = root
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def qualname(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Dotted name of a Name/Attribute chain with the import alias map
    applied to the root; None for anything dynamic (calls, subscripts)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(aliases.get(node.id, node.id))
        return ".".join(reversed(parts))
    return None


def resolve_local_def(name: str, at: ast.AST) -> Optional[ast.AST]:
    """The lexically visible ``def name`` for a reference at ``at`` —
    walk enclosing scopes innermost-out and take the first match."""
    scopes = [a for a in ancestors(at)
              if isinstance(a, FunctionNode + (ast.Module, ast.ClassDef))]
    for scope in scopes:
        body = getattr(scope, "body", [])
        for stmt in body if isinstance(body, list) else []:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and stmt.name == name:
                return stmt
    return None


def positional_params(fn: ast.AST) -> List[str]:
    a = fn.args
    return [p.arg for p in list(a.posonlyargs) + list(a.args)]


def const_int_tuple(node: ast.AST) -> Optional[Tuple[int, ...]]:
    """Literal int / tuple-or-list of ints, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if not (isinstance(el, ast.Constant) and isinstance(el.value, int)):
                return None
            out.append(el.value)
        return tuple(out)
    return None


class SourceFile:
    """One parsed module plus everything the passes share: alias map,
    parent links, suppression table."""

    def __init__(self, path: str, relpath: str, text: Optional[str] = None):
        self.path = path
        self.relpath = relpath
        if text is None:
            with open(path, encoding="utf-8") as f:
                text = f.read()
        self.text = text
        self.parse_error: Optional[Finding] = None
        self.bad_suppressions: List[Finding] = []
        try:
            self.tree: ast.AST = ast.parse(text, filename=relpath)
        except SyntaxError as e:
            self.tree = ast.Module(body=[], type_ignores=[])
            self.parse_error = Finding(relpath, e.lineno or 1, "X000",
                                       f"cannot parse: {e.msg}")
        attach_parents(self.tree)
        self.aliases = collect_aliases(self.tree)
        self._suppressions = self._parse_suppressions()
        self._func_lines = sorted(
            (node.lineno, max(getattr(node, "end_lineno", node.lineno),
                              node.lineno))
            for node in ast.walk(self.tree)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)))

    # -- suppressions -------------------------------------------------------

    def _parse_suppressions(self) -> Dict[int, Set[str]]:
        supp: Dict[int, Set[str]] = {}
        standalone: List[Tuple[int, Set[str]]] = []
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(self.text).readline))
        except (tokenize.TokenError, IndentationError, SyntaxError):
            return supp
        code_lines = {t.start[0] for t in tokens
                      if t.type not in (tokenize.COMMENT, tokenize.NL,
                                        tokenize.NEWLINE, tokenize.INDENT,
                                        tokenize.DEDENT, tokenize.ENDMARKER)}
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _DISABLE_RE.search(tok.string)
            if m is None:
                if "repro-lint:" in tok.string:
                    self.bad_suppressions.append(Finding(
                        self.relpath, tok.start[0], "X001",
                        "malformed repro-lint comment: expected "
                        "'# repro-lint: disable=RULE(reason)'"))
                continue
            rules = set()
            for rule, reason in _RULE_RE.findall(m.group(1)):
                if not reason.strip():
                    self.bad_suppressions.append(Finding(
                        self.relpath, tok.start[0], "X001",
                        f"suppression of {rule} has no reason — every "
                        "waiver must say why"))
                    continue
                rules.add(rule)
            if not rules:
                continue
            line = tok.start[0]
            if line in code_lines:
                supp.setdefault(line, set()).update(rules)
            else:
                standalone.append((line, rules))
        # a standalone comment applies to the next code line
        for line, rules in standalone:
            nxt = min((c for c in code_lines if c > line), default=None)
            if nxt is not None:
                supp.setdefault(nxt, set()).update(rules)
        return supp

    def is_suppressed(self, finding: Finding) -> bool:
        rules = self._suppressions.get(finding.line)
        if rules and finding.rule in rules:
            return True
        # def-line suppressions cover the whole function body
        for start, end in self._func_lines:
            if start <= finding.line <= end:
                rules = self._suppressions.get(start)
                if rules and finding.rule in rules:
                    return True
        return False

    # -- helpers shared by passes ------------------------------------------

    def qualname(self, node: ast.AST) -> Optional[str]:
        return qualname(node, self.aliases)

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        return Finding(self.relpath, getattr(node, "lineno", 1), rule, message)
