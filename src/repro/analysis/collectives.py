"""Pass 2 — collective uniformity inside mapped functions (rules
C201/C202).

The gang-launch argument of DESIGN.md §10: under gloo, every process
must issue the *same sequence* of collectives or the gang deadlocks at
the first mismatched rendezvous.  A function traced once per process is
uniform by construction — closures and config branches resolve
identically everywhere — so the only way to diverge is to branch on
something that genuinely differs per host:

  * **C201 collective-divergent-control** — a collective
    (``psum``/``pmean``/``pmax``/``all_gather``/… plus the repo's
    ``compressed_pmean``/``fused_tree_reduce``) lexically under an
    ``if``/``while`` test or ``for`` iterable that reads a *nonuniform
    host source*: ``jax.process_index``, ``time.*``, ``random.*``,
    ``os.environ``/``os.getenv``, ``socket.gethostname``.  Uniform
    closure branches (``for ax in self._axes: pmean(...)``) are
    deliberately not flagged — they trace the same everywhere.
  * **C202 collective-unknown-axis** — an axis-name string literal in a
    collective call outside the known mesh axis set {``pod``, ``data``,
    ``model``}: a typo'd axis name fails only at run time, on the mesh
    that actually binds axes, i.e. the multi-host job and not the unit
    test.

C201 only looks inside functions demonstrably passed to
``shard_map``/``pmap`` (resolved through names, lambdas, and decorator
forms); C202 applies to every collective call site — an axis literal is
wrong no matter where it is spelled.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.common import (Finding, SourceFile, ancestors,
                                   register_rules, resolve_local_def)

register_rules({
    "C201": "collective-divergent-control",
    "C202": "collective-unknown-axis",
})

KNOWN_MESH_AXES = {"pod", "data", "model"}

# last path segment of a collective call target
COLLECTIVE_NAMES = {
    "psum", "pmean", "pmax", "pmin", "all_gather", "all_to_all",
    "ppermute", "pshuffle", "psum_scatter",
    "compressed_pmean", "fused_tree_reduce",
}

# dotted prefixes whose reads differ between processes of one gang
_NONUNIFORM_PREFIXES = (
    "jax.process_index",
    "time.", "random.", "numpy.random.",
    "os.environ", "os.getenv", "os.urandom", "os.getpid",
    "socket.gethostname", "uuid.",
)


def _is_collective(sf: SourceFile, call: ast.Call) -> bool:
    qn = sf.qualname(call.func)
    return qn is not None and qn.split(".")[-1] in COLLECTIVE_NAMES


def _is_mapper(qn: Optional[str]) -> bool:
    if qn is None:
        return False
    tail = qn.split(".")[-1]
    return tail in ("shard_map", "pmap", "xmap")


def _nonuniform_source(sf: SourceFile, expr: ast.AST) -> Optional[str]:
    for node in ast.walk(expr):
        qn = sf.qualname(node)
        if qn is None:
            continue
        qn_dotted = qn + "."
        for prefix in _NONUNIFORM_PREFIXES:
            if qn == prefix.rstrip(".") or qn_dotted.startswith(prefix):
                return qn
    return None


def _mapped_functions(sf: SourceFile) -> Set[ast.AST]:
    """Function nodes demonstrably handed to shard_map/pmap."""
    mapped: Set[ast.AST] = set()

    def resolve(node: ast.AST) -> None:
        if isinstance(node, ast.Lambda):
            mapped.add(node)
        elif isinstance(node, ast.Name):
            target = resolve_local_def(node.id, node)
            if target is not None:
                mapped.add(target)

    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) and _is_mapper(sf.qualname(node.func)):
            if node.args:
                resolve(node.args[0])
            for kw in node.keywords:
                if kw.arg in ("f", "fun"):
                    resolve(kw.value)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                # @shard_map(...)/@pmap and @partial(shard_map, ...)
                if _is_mapper(sf.qualname(dec)):
                    mapped.add(node)
                elif isinstance(dec, ast.Call):
                    if _is_mapper(sf.qualname(dec.func)):
                        mapped.add(node)
                    elif dec.args and _is_mapper(sf.qualname(dec.args[0])):
                        mapped.add(node)
    return mapped


def _check_divergence(sf: SourceFile, call: ast.Call, mapped_fn: ast.AST,
                      findings: List[Finding]) -> None:
    for anc in ancestors(call):
        if anc is mapped_fn:
            break
        cond: Optional[ast.AST] = None
        if isinstance(anc, (ast.If, ast.While)):
            cond = anc.test
        elif isinstance(anc, ast.For):
            cond = anc.iter
        elif isinstance(anc, ast.IfExp):
            cond = anc.test
        if cond is None:
            continue
        src = _nonuniform_source(sf, cond)
        if src is not None:
            findings.append(sf.finding(
                call, "C201",
                f"collective under control flow conditioned on `{src}` — "
                "processes of the gang can disagree on whether this "
                "collective launches, which deadlocks the gloo rendezvous "
                "(hoist the branch out of the mapped function)"))


_AXIS_KEYWORDS = {"axis", "axes", "axis_name", "axis_names", "compress_axis"}


def _check_axes(sf: SourceFile, call: ast.Call,
                findings: List[Finding]) -> None:
    """Axis-position arguments only: positional args after the operand
    that are string literals (or tuples/lists of them), plus keywords
    with axis-ish names — dtype strings and the like stay out."""
    candidates: List[ast.AST] = list(call.args[1:])
    candidates += [kw.value for kw in call.keywords
                   if kw.arg in _AXIS_KEYWORDS]
    strings: List[str] = []
    for arg in candidates:
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            strings.append(arg.value)
        elif isinstance(arg, (ast.Tuple, ast.List)):
            strings.extend(el.value for el in arg.elts
                           if isinstance(el, ast.Constant)
                           and isinstance(el.value, str))
    for s in strings:
        if s not in KNOWN_MESH_AXES:
            findings.append(sf.finding(
                call, "C202",
                f"axis name '{s}' is not in the known mesh axis set "
                f"{sorted(KNOWN_MESH_AXES)} — a typo'd axis only fails on "
                "the real multi-host mesh, not in unit tests"))


def run(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    mapped = _mapped_functions(sf)
    for fn in mapped:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and _is_collective(sf, node):
                _check_divergence(sf, node, fn, findings)
    seen_lines = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) and _is_collective(sf, node) \
                and node.lineno not in seen_lines:
            seen_lines.add(node.lineno)
            _check_axes(sf, node, findings)
    return findings
