"""repro-lint CLI — run the four passes, apply suppressions and the
baseline, report.

    python -m repro.analysis [paths…] [--check] [--write-baseline]
                             [--baseline FILE] [--report FILE]

Default paths are ``src/``, ``benchmarks/``, ``examples/`` under the
repo root (found by walking up to ``pyproject.toml``), matching the CI
invocation.  Exit codes: 0 clean, 1 findings survive suppression +
baseline (only with ``--check``; the bare run always reports and exits
0 so local exploration never trips a shell ``set -e``), 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Iterable, List, Optional, Tuple

from repro.analysis import PASSES
from repro.analysis import baseline as baseline_mod
from repro.analysis.common import RULES, Finding, SourceFile

DEFAULT_ROOTS = ("src", "benchmarks", "examples")


def find_repo_root(start: Optional[str] = None) -> Optional[str]:
    d = os.path.abspath(start or os.getcwd())
    while True:
        if os.path.exists(os.path.join(d, "pyproject.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return None
        d = parent


def iter_py_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        elif os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                out.extend(os.path.join(dirpath, f)
                           for f in filenames if f.endswith(".py"))
    return sorted(set(out))


def analyze_file(path: str, relpath: str
                 ) -> Tuple[List[Tuple[Finding, str]], SourceFile]:
    """All unsuppressed findings for one file, paired with the stripped
    source line they sit on (the baseline snippet key)."""
    sf = SourceFile(path, relpath)
    findings: List[Finding] = []
    if sf.parse_error is not None:
        findings.append(sf.parse_error)
    findings.extend(sf.bad_suppressions)
    for pass_run in PASSES:
        findings.extend(pass_run(sf))
    lines = sf.text.splitlines()
    kept: List[Tuple[Finding, str]] = []
    for f in sorted(set(findings)):
        if sf.is_suppressed(f):
            continue
        snippet = lines[f.line - 1] if 0 < f.line <= len(lines) else ""
        kept.append((f, snippet))
    return kept, sf


def run_paths(paths: Iterable[str], root: str
              ) -> List[Tuple[Finding, str]]:
    findings: List[Tuple[Finding, str]] = []
    for path in iter_py_files(paths):
        rel = os.path.relpath(os.path.abspath(path), root)
        rel = rel.replace(os.sep, "/")
        findings.extend(analyze_file(path, rel)[0])
    findings.sort(key=lambda fs: fs[0])
    return findings


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="repo-specific static analysis: donation safety, "
                    "collective uniformity, lock discipline, retrace "
                    "hazards (DESIGN.md §12)")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to analyze (default: src/ benchmarks/ "
                         "examples/ under the repo root)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any finding survives suppressions and "
                         "the baseline (the CI gate mode)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current findings to the baseline file "
                         "and exit 0")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="baseline file (default: <repo>/analysis/"
                         "baseline.json)")
    ap.add_argument("--report", default=None, metavar="FILE",
                    help="also write findings as JSON to FILE")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(RULES):
            print(f"{rule}  {RULES[rule]}")
        return 0

    root = find_repo_root()
    if root is None:
        root = os.getcwd()
    paths = args.paths or [os.path.join(root, d) for d in DEFAULT_ROOTS
                           if os.path.isdir(os.path.join(root, d))]
    missing = [p for p in paths if not os.path.exists(p)]
    if missing or not paths:
        print(f"repro-lint: no such path(s): {', '.join(missing) or '(none)'}",
              file=sys.stderr)
        return 2

    findings = run_paths(paths, root)

    baseline_path = args.baseline or os.path.join(root, "analysis",
                                                  "baseline.json")
    if args.write_baseline:
        payload = baseline_mod.to_payload(findings)
        os.makedirs(os.path.dirname(baseline_path) or ".", exist_ok=True)
        with open(baseline_path, "w", encoding="utf-8") as f:
            f.write(baseline_mod.render(payload))
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    fresh, absorbed = baseline_mod.subtract(
        findings, baseline_mod.load(baseline_path))

    if args.report:
        with open(args.report, "w", encoding="utf-8") as f:
            json.dump({"findings": [fi.to_json() for fi, _ in fresh],
                       "baselined": absorbed}, f, indent=2)
            f.write("\n")

    for fi, _ in fresh:
        print(fi.render())
    tail = f"{len(fresh)} finding(s)"
    if absorbed:
        tail += f" ({absorbed} baselined)"
    print(f"repro-lint: {tail}")
    if fresh and args.check:
        return 1
    return 0
