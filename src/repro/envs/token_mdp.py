"""Token MDP — the LM-scale environment for the assigned architectures.

State = current token; action = predicted next token; the environment
advances by sampling from a fixed random Markov chain over the vocab;
reward = 1 if the agent's action equals the sampled next token.  The
optimal policy is argmax of the transition matrix — learnable by the
token-Q learner, with known optimal expected reward (tests assert the
learner approaches it)."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenMDPSpec:
    vocab: int
    concentration: float = 0.3   # lower → peakier transitions (easier)


class TokenMDPState(NamedTuple):
    token: jax.Array   # (n,) int32 current tokens
    table: jax.Array   # (V, V) transition logits (fixed per MDP instance)


def make(spec: TokenMDPSpec, key: jax.Array, n_envs: int):
    table = jax.random.gumbel(key, (spec.vocab, spec.vocab)) / spec.concentration

    def reset(key):
        tok = jax.random.randint(key, (n_envs,), 0, spec.vocab)
        return TokenMDPState(tok, table), tok

    def step(state: TokenMDPState, actions: jax.Array, key: jax.Array):
        logits = state.table[state.token]                     # (n, V)
        nxt = jax.random.categorical(key, logits, axis=-1)
        reward = (actions == nxt).astype(jnp.float32)
        return TokenMDPState(nxt, state.table), nxt, reward, jnp.zeros_like(reward, bool)

    def optimal_reward(n_samples: int = 4096) -> float:
        # E[max_a P(a|s)] under the stationary token distribution ≈ uniform
        probs = jax.nn.softmax(table, axis=-1)
        return float(jnp.mean(jnp.max(probs, axis=-1)))

    return reset, step, optimal_reward
