"""Pure-JAX vectorized control environments (the paper's OpenAI-gym
analogue — LunarLander is swapped for CartPole/Pendulum so the physics
runs vmapped/jitted on-device; same discrete/continuous split the paper
tests: DQN on discrete, DDPG/SAC on continuous).

API mirrors the paper §II-A: reset() → s, step(a) → (s', r, done)."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EnvSpec:
    name: str
    obs_dim: int
    action_dim: int           # discrete: number of actions; continuous: dim
    discrete: bool
    max_steps: int
    action_low: float = -1.0
    action_high: float = 1.0


class EnvState(NamedTuple):
    x: jax.Array        # physics state
    t: jax.Array        # step counter


# ---------------------------------------------------------------- CartPole

CARTPOLE = EnvSpec("cartpole", 4, 2, True, 500)

_G, _MC, _MP, _L, _F, _DT = 9.8, 1.0, 0.1, 0.5, 10.0, 0.02


def cartpole_reset(key) -> Tuple[EnvState, jax.Array]:
    x = jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)
    return EnvState(x, jnp.zeros((), jnp.int32)), x


def cartpole_step(state: EnvState, action: jax.Array, key
                  ) -> Tuple[EnvState, jax.Array, jax.Array, jax.Array]:
    x, x_dot, th, th_dot = state.x
    force = jnp.where(action == 1, _F, -_F)
    cos, sin = jnp.cos(th), jnp.sin(th)
    tot_m = _MC + _MP
    tmp = (force + _MP * _L * th_dot**2 * sin) / tot_m
    th_acc = (_G * sin - cos * tmp) / (_L * (4.0 / 3.0 - _MP * cos**2 / tot_m))
    x_acc = tmp - _MP * _L * th_acc * cos / tot_m
    nx = jnp.stack([x + _DT * x_dot, x_dot + _DT * x_acc,
                    th + _DT * th_dot, th_dot + _DT * th_acc])
    t = state.t + 1
    done = (
        (jnp.abs(nx[0]) > 2.4) | (jnp.abs(nx[2]) > 0.2095) | (t >= CARTPOLE.max_steps)
    )
    return EnvState(nx, t), nx, jnp.ones(()), done


# ---------------------------------------------------------------- Pendulum

PENDULUM = EnvSpec("pendulum", 3, 1, False, 200, -2.0, 2.0)


def _pend_obs(x):
    th, th_dot = x
    return jnp.stack([jnp.cos(th), jnp.sin(th), th_dot])


def pendulum_reset(key) -> Tuple[EnvState, jax.Array]:
    k1, k2 = jax.random.split(key)
    th = jax.random.uniform(k1, (), minval=-jnp.pi, maxval=jnp.pi)
    thd = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0)
    x = jnp.stack([th, thd])
    return EnvState(x, jnp.zeros((), jnp.int32)), _pend_obs(x)


def pendulum_step(state: EnvState, action: jax.Array, key
                  ) -> Tuple[EnvState, jax.Array, jax.Array, jax.Array]:
    th, th_dot = state.x
    u = jnp.clip(action.reshape(()), -2.0, 2.0)
    norm_th = ((th + jnp.pi) % (2 * jnp.pi)) - jnp.pi
    cost = norm_th**2 + 0.1 * th_dot**2 + 0.001 * u**2
    new_thd = th_dot + (3 * 9.81 / (2 * 1.0) * jnp.sin(th) + 3.0 / 1.0 * u) * 0.05
    new_thd = jnp.clip(new_thd, -8.0, 8.0)
    new_th = th + new_thd * 0.05
    x = jnp.stack([new_th, new_thd])
    t = state.t + 1
    done = t >= PENDULUM.max_steps
    return EnvState(x, t), _pend_obs(x), -cost, done


# ---------------------------------------------------------- registry / vector

ENVS = {
    "cartpole": (CARTPOLE, cartpole_reset, cartpole_step),
    "pendulum": (PENDULUM, pendulum_reset, pendulum_step),
}


def make_vec(name: str, n_envs: int):
    """Vectorized auto-resetting environment (paper §V-A parallel actors:
    each actor owns an independent env instance)."""
    spec, reset, step = ENVS[name]

    def v_reset(key):
        ks = jax.random.split(key, n_envs)
        return jax.vmap(reset)(ks)

    def v_step(states, actions, key):
        ks = jax.random.split(key, n_envs)
        nstates, obs, rew, done = jax.vmap(step)(states, actions, ks)
        # auto-reset finished episodes
        rks = jax.random.split(jax.random.fold_in(key, 1), n_envs)
        rstates, robs = jax.vmap(reset)(rks)
        nstates = jax.tree.map(
            lambda a, b: jnp.where(
                done.reshape((n_envs,) + (1,) * (a.ndim - 1)), b, a), nstates, rstates)
        obs_out = jnp.where(done[:, None], robs, obs)
        return nstates, obs_out, rew, done, obs  # obs = true next obs pre-reset

    return spec, v_reset, v_step
