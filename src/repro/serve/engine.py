"""Device math of the continuous-batching actor server (DESIGN.md §13).

The backbone's ``decode_step`` keeps ONE scalar ``cache["pos"]`` for the
whole batch — correct for the training actor (lockstep episodes), wrong
for serving, where every sequence in the batch sits at a different
depth.  Rather than rewriting five model families, the engine vmaps a
batch-of-1 ``token_dqn.serve_step`` over the slot axis: each slot's
cache slice carries its *own* ``pos``, so RoPE phases, cache writes and
causal masks are all per-slot — bit-exact against the plain batched
decode when positions happen to agree (pinned in tests/test_serve.py).

Three jitted entry points, three bounded compile sets:

* ``_prime``   — bucket-padded prefill of one request into a fresh slot
                 cache, ``pos`` rewound to the true prompt length.  One
                 retrace per *bucket edge* (shapes are the bucket set —
                 repro-lint R401-clean by construction, asserted via the
                 compile-counter spy in tests).
* ``_insert``/``_release`` — slot-table edits at a dynamic slot index
                 (one compile each).
* ``_step``    — the vmapped decode over all slots, free slots frozen by
                 the ``slot_mask`` (one compile).  The batched KV cache
                 is donated: serving holds exactly one live cache buffer.

Families: dense | moe only.  The pad-then-rewind trick needs state that
is purely position-indexed — recurrent families (ssm, hybrid) fold pad
tokens into their state irreversibly, and vlm/audio prompts carry extra
embeddings the request queue doesn't model.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.agents import token_dqn
from repro.models import backbone
from repro.models.config import NO_SHARDING, ModelConfig
from repro.serve.buckets import BucketSpec

Pytree = Any

SUPPORTED_FAMILIES = ("dense", "moe")


class DecodeState(NamedTuple):
    """Per-slot serving state: the stacked slot caches (leaf axis 0 =
    slot), each slot's next input token, and the busy mask."""

    cache: Pytree                 # leaves: (slots, ...per-slot cache...)
    tokens: jax.Array             # (slots, 1, 1) int32
    active: jax.Array             # (slots,) bool


def _cache_size(fn) -> int:
    """Retrace counter: how many signatures this jit has compiled."""
    try:
        return int(fn._cache_size())
    except AttributeError:  # pragma: no cover — older/newer jax fallback
        return -1


class DecodeEngine:
    def __init__(self, cfg: ModelConfig, shd=NO_SHARDING, *, slots: int,
                 max_len: int, buckets: BucketSpec):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise ValueError(
                f"DecodeEngine serves {SUPPORTED_FAMILIES} families only, "
                f"got {cfg.family!r} ({cfg.name}): pad-then-rewind needs a "
                "purely position-indexed cache (DESIGN.md §13)")
        if slots < 1:
            raise ValueError(f"slots={slots}: must be >= 1")
        if buckets.max_prompt_len > max_len:
            raise ValueError(
                f"largest bucket edge {buckets.max_prompt_len} exceeds "
                f"max_len={max_len}: prefill could not fit in the cache")
        self.cfg = cfg
        self.shd = shd
        self.slots = int(slots)
        self.max_len = int(max_len)
        self.buckets = buckets

        def prime(params, padded, true_len):
            # bucket-padded prefill; first greedy action comes from the
            # last REAL position, and pos rewinds to the true length so
            # every pad key is overwritten before the mask can see it
            logits, cache = backbone.prefill(
                cfg, shd, params, padded, max_len=self.max_len)
            off = logits.shape[1] - padded.shape[1]
            last = jax.lax.dynamic_index_in_dim(
                logits[0], off + true_len - 1, axis=0, keepdims=False)
            tok = jnp.argmax(last, axis=-1).astype(jnp.int32)
            return tok, dict(cache, pos=true_len.astype(jnp.int32))

        def insert(state: DecodeState, slot_cache, tok, slot) -> DecodeState:
            cache = jax.tree.map(
                lambda b, s: jax.lax.dynamic_update_index_in_dim(
                    b, s.astype(b.dtype), slot, 0),
                state.cache, slot_cache)
            tokens = jax.lax.dynamic_update_index_in_dim(
                state.tokens, tok.reshape(1, 1), slot, 0)
            active = jax.lax.dynamic_update_index_in_dim(
                state.active, jnp.asarray(True), slot, 0)
            return DecodeState(cache, tokens, active)

        def release(state: DecodeState, slot) -> DecodeState:
            active = jax.lax.dynamic_update_index_in_dim(
                state.active, jnp.asarray(False), slot, 0)
            return DecodeState(state.cache, state.tokens, active)

        self._prime = jax.jit(prime)
        self._insert = jax.jit(insert)
        self._release = jax.jit(release)
        # one decode program for the whole slot table; per-slot pos lives
        # in the vmapped cache slice, free slots frozen by the slot mask.
        # The old cache buffer is donated — exactly one live KV cache.
        self._step = jax.jit(
            jax.vmap(functools.partial(token_dqn.serve_step, cfg, shd),
                     in_axes=(None, 0, 0, 0)),
            donate_argnums=(1,))

    # -- state ---------------------------------------------------------------

    def init_state(self) -> DecodeState:
        slot = backbone.init_cache(self.cfg, self.shd, 1, self.max_len)
        cache = jax.tree.map(
            lambda x: jnp.stack([x] * self.slots), slot)
        return DecodeState(
            cache=cache,
            tokens=jnp.zeros((self.slots, 1, 1), jnp.int32),
            active=jnp.zeros((self.slots,), bool),
        )

    def fits(self, prompt_len: int, max_new_tokens: int) -> None:
        """Admission-time capacity check (raises on violation): the
        prompt must land in a bucket and the last decode write at
        ``prompt_len + max_new_tokens - 2`` must stay inside the cache."""
        self.buckets.bucket_for(prompt_len)   # raises past the last edge
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens={max_new_tokens}: must be >= 1")
        if prompt_len + max_new_tokens - 1 > self.max_len:
            raise ValueError(
                f"prompt_len={prompt_len} + max_new_tokens={max_new_tokens} "
                f"- 1 exceeds max_len={self.max_len}: the generation would "
                "overrun the KV cache")

    # -- ops -----------------------------------------------------------------

    def prime(self, params, prompt: np.ndarray) -> Tuple[jax.Array, Pytree]:
        """Bucket-padded prefill of one prompt → (first greedy token,
        slot cache with pos = true length)."""
        prompt = np.asarray(prompt, np.int32)
        padded = self.buckets.pad(prompt)
        return self._prime(params, jnp.asarray(padded),
                           jnp.asarray(prompt.shape[0], jnp.int32))

    def insert(self, state: DecodeState, slot: int, slot_cache,
               tok) -> DecodeState:
        return self._insert(state, slot_cache, jnp.asarray(tok, jnp.int32),
                            jnp.asarray(slot, jnp.int32))

    def release(self, state: DecodeState, slot: int) -> DecodeState:
        return self._release(state, jnp.asarray(slot, jnp.int32))

    def step(self, params, state: DecodeState) -> Tuple[jax.Array, DecodeState]:
        """One continuous-batching decode step over every slot; free
        slots are frozen in place by the slot mask."""
        actions, cache = self._step(params, state.cache, state.tokens,
                                    state.active)
        actions = actions.reshape(self.slots)
        state = DecodeState(
            cache=cache,
            tokens=actions.astype(jnp.int32).reshape(self.slots, 1, 1),
            active=state.active)
        return actions, state

    # -- retrace accounting ---------------------------------------------------

    @property
    def prime_compiles(self) -> int:
        """Bounded by ``len(buckets.edges)`` — the §13 retrace invariant."""
        return _cache_size(self._prime)

    @property
    def decode_compiles(self) -> int:
        return _cache_size(self._step)
