"""Double-buffered parameter publication for the serve path (DESIGN.md §13).

The serving analogue of the training runtime's ``params_for_acting``
double buffer (agents/base.py, AsyncExecutor): the learner updates
fresh params on its own clock, the actor acts on a stable copy, and the
handoff happens at a controlled boundary.  Here the boundary is the
``serve_step``: ``ParamDoubleBuffer.stage`` may be called from any
thread at any time (it only touches the *staged* half), and the serve
loop calls ``swap_if_staged`` exactly once per step, so one batch step
can never mix two parameter versions — and the swap itself is a pointer
flip, not a copy, so live traffic sees no latency spike.

``ServiceParamChannel`` plugs the replay service's versioned params
channel (service/server.py ``put_params``/``get_params``) in as the
publisher: a training learner pushes ``params_for_acting``-shaped trees
to the replay server it already talks to, and the actor frontend polls
the same channel — no second wire protocol.  Works against both the
in-process ``ReplayService`` (blob bytes) and the TCP ``ReplayClient``
(pre-unpickled ``params``).
"""

from __future__ import annotations

import pickle
import threading
from typing import Any, Optional, Tuple

Pytree = Any


class ParamDoubleBuffer:
    """live/staged versioned parameter pair with boundary-only swaps."""

    def __init__(self, params: Pytree, version: int = 0):
        self._lock = threading.Lock()
        self._live = params
        self._live_version = int(version)
        self._staged: Optional[Tuple[int, Pytree]] = None
        self._swaps = 0

    def stage(self, params: Pytree, version: Optional[int] = None) -> int:
        """Publish a new tree (any thread).  Does NOT touch the live
        half — the serve loop picks it up at its next step boundary.
        Monotonic versions only; a stale publish is dropped."""
        with self._lock:
            if version is None:
                staged_v = self._staged[0] if self._staged else self._live_version
                version = staged_v + 1
            version = int(version)
            if version <= self._live_version or (
                    self._staged is not None and version <= self._staged[0]):
                return self._live_version  # stale publish — keep what we have
            self._staged = (version, params)
            return version

    def swap_if_staged(self) -> Tuple[Pytree, int, bool]:
        """Serve-loop boundary: promote the staged tree if any.  Returns
        ``(live params, live version, swapped)``."""
        with self._lock:
            if self._staged is not None:
                self._live_version, self._live = self._staged
                self._staged = None
                self._swaps += 1
                return self._live, self._live_version, True
            return self._live, self._live_version, False

    @property
    def version(self) -> int:
        with self._lock:
            return self._live_version

    @property
    def staged_version(self) -> Optional[int]:
        with self._lock:
            return self._staged[0] if self._staged else None

    @property
    def swaps(self) -> int:
        with self._lock:
            return self._swaps


class ServiceParamChannel:
    """Poll the replay service's versioned param channel into a
    ``ParamDoubleBuffer``.  ``source`` is duck-typed: anything with
    ``get_params(min_version=..., timeout=...)`` — the in-process
    ``ReplayService`` or the TCP ``ReplayClient``.

    Degradation contract (DESIGN.md §14): a channel outage — the
    service unreachable, the connection torn mid-poll, the retry budget
    exhausted — must never take the serve loop down with it.  ``poll``
    swallows connection-level failures, leaves the double buffer on the
    last-good params, and counts the outage: ``stale_polls`` is the
    consecutive-failure staleness signal (reset on the next successful
    round trip), ``outages`` the lifetime total, ``last_error`` the
    most recent failure rendered for operators."""

    def __init__(self, source: Any, buffer: ParamDoubleBuffer):
        self.source = source
        self.buffer = buffer
        self._seen = buffer.version
        self.outages = 0          # lifetime connection-level poll failures
        self.stale_polls = 0      # consecutive failures — staleness signal
        self.last_error: Optional[str] = None

    def poll(self) -> bool:
        """Non-blocking pull: stage the channel's tree iff it carries a
        version newer than anything we've seen.  Returns True on a new
        stage; False on no-news *and* on outage (see class docstring —
        check ``stale_polls`` to tell them apart)."""
        floor = self._seen
        staged = self.buffer.staged_version
        if staged is not None:
            floor = max(floor, staged)
        try:
            reply = self.source.get_params(min_version=floor + 1, timeout=0.0)
        except TimeoutError:
            # in-process source: no newer version yet — contact was fine
            self.stale_polls = 0
            return False
        except (ConnectionError, EOFError, OSError) as e:
            self.outages += 1
            self.stale_polls += 1
            self.last_error = f"{type(e).__name__}: {e}"
            return False
        except RuntimeError as e:
            # the TCP client surfaces server-side errors as RuntimeError
            # replies; a server-side TimeoutError is the quiet-channel
            # case, anything else is a real outage of the channel
            if "TimeoutError" in str(e):
                self.stale_polls = 0
                return False
            self.outages += 1
            self.stale_polls += 1
            self.last_error = f"{type(e).__name__}: {e}"
            return False
        self.stale_polls = 0
        if reply.get("stopped") and reply.get("version", 0) <= floor:
            return False
        version = int(reply["version"])
        if version <= floor:
            return False
        params = reply.get("params")
        if params is None:
            params = pickle.loads(reply["blob"])
        self._seen = version
        self.buffer.stage(params, version)
        return True

    def stats(self) -> dict:
        return {"seen_version": self._seen, "outages": self.outages,
                "stale_polls": self.stale_polls,
                "last_error": self.last_error}
