"""Host-side continuous-batching scheduler (DESIGN.md §13).

A FIFO request queue feeds a fixed table of decode slots.  Each
``serve_step`` call is one admission window + one batched decode step:

1. **admit** — every free slot pops the queue head, prefills it through
   the engine's bucket-padded ``prime`` and lands in the slot table
   (the request's first generated token comes from prefill);
2. **decode** — one vmapped decode step over the whole table (free
   slots frozen by the slot mask), one token appended per busy slot;
3. **evict** — slots that reached their generation budget emit a
   ``Completion`` and are released, so the *next* ``serve_step`` admits
   into them — continuous batching over the KV cache, no global drain.

The scheduler takes ``(params, params_version)`` **per call** and uses
that one pair for every prime and the decode step inside the window —
the single-version-per-batch-step half of the §13 param-publication
contract (the other half, swap-at-the-boundary, lives in
``serve/params.py``).  ``step_log`` records ``(step, version,
n_active)`` so tests can assert no step ever saw two versions.

Token accounting is exact by construction and asserted in tests:
``admissions + decoded_tokens == sum(len(c.tokens))`` over completions
plus in-flight slots — prefill contributes exactly one token per
admission, decode exactly one per busy slot per step.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np

from repro.serve.engine import DecodeEngine

Pytree = Any


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    enqueued_at: float


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: List[int]             # generated tokens, len == max_new_tokens
    slot: int
    params_version: int           # the version of the step that finished it
    enqueued_at: float
    admitted_at: float
    finished_at: float

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.enqueued_at


@dataclasses.dataclass
class _Active:
    req: Request
    tokens: List[int]
    admitted_at: float


class Scheduler:
    def __init__(self, engine: DecodeEngine, *, log_len: int = 4096):
        self.engine = engine
        self.state = engine.init_state()
        self.queue: deque = deque()
        self._slots: List[Optional[_Active]] = [None] * engine.slots
        self._next_rid = 0
        # exact token/phase accounting (examples/serve_actor.py reports
        # these; tests assert the closed-form invariant)
        self.step_count = 0
        self.admissions = 0
        self.decoded_tokens = 0
        self.timings: Dict[str, float] = {"prefill_s": 0.0, "decode_s": 0.0}
        self.step_log: deque = deque(maxlen=log_len)      # (step, version, n_active)
        self.admission_log: deque = deque(maxlen=log_len)  # (rid, slot, step)

    # -- queue ----------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               enqueued_at: Optional[float] = None) -> int:
        """Admission-checked enqueue; returns the request id."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        self.engine.fits(prompt.shape[0], max_new_tokens)
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(
            rid=rid, prompt=prompt, max_new_tokens=int(max_new_tokens),
            enqueued_at=(time.perf_counter() if enqueued_at is None
                         else enqueued_at)))
        return rid

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(a is not None for a in self._slots)

    @property
    def n_active(self) -> int:
        return sum(a is not None for a in self._slots)

    @property
    def generated_tokens(self) -> int:
        """Exact total: one per admission (prefill) + one per busy slot
        per decode step."""
        return self.admissions + self.decoded_tokens

    # -- the serve step -------------------------------------------------------

    def serve_step(self, params: Pytree,
                   params_version: int = 0) -> List[Completion]:
        """One admission window + one batched decode step under ONE
        (params, version) pair.  Returns the completions it evicted."""
        completions: List[Completion] = []

        t0 = time.perf_counter()
        for slot, occupant in enumerate(self._slots):
            if occupant is not None or not self.queue:
                continue
            req = self.queue.popleft()
            tok, slot_cache = self.engine.prime(params, req.prompt)
            first = int(tok)                       # host sync: prefill done
            self.state = self.engine.insert(self.state, slot, slot_cache, tok)
            now = time.perf_counter()
            self._slots[slot] = _Active(req, [first], admitted_at=now)
            self.admissions += 1
            self.admission_log.append((req.rid, slot, self.step_count))
        self.timings["prefill_s"] += time.perf_counter() - t0

        # a budget-1 request is already complete at admission
        for slot, a in enumerate(self._slots):
            if a is not None and len(a.tokens) >= a.req.max_new_tokens:
                completions.append(self._evict(slot, params_version))

        if not any(a is not None for a in self._slots):
            return completions

        t0 = time.perf_counter()
        actions, self.state = self.engine.step(params, self.state)
        acts = np.asarray(actions)                 # host sync: decode done
        self.timings["decode_s"] += time.perf_counter() - t0
        self.step_count += 1
        self.step_log.append((self.step_count, params_version, self.n_active))

        for slot, a in enumerate(self._slots):
            if a is None:
                continue
            a.tokens.append(int(acts[slot]))
            self.decoded_tokens += 1
            if len(a.tokens) >= a.req.max_new_tokens:
                completions.append(self._evict(slot, params_version))
        return completions

    def _evict(self, slot: int, params_version: int) -> Completion:
        a = self._slots[slot]
        assert a is not None
        self.state = self.engine.release(self.state, slot)
        self._slots[slot] = None
        return Completion(
            rid=a.req.rid,
            prompt_len=int(a.req.prompt.shape[0]),
            tokens=a.tokens,
            slot=slot,
            params_version=params_version,
            enqueued_at=a.req.enqueued_at,
            admitted_at=a.admitted_at,
            finished_at=time.perf_counter(),
        )
