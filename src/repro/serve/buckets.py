"""Prompt-length padding buckets (DESIGN.md §13).

The serve frontend admits prompts of arbitrary length but jit-compiles
``prefill`` per *shape* — an unbounded set of prompt lengths would mean
an unbounded set of retraces (exactly the repro-lint R401 hazard class).
``BucketSpec`` is the static contract that bounds them: every prompt is
right-padded to the smallest bucket edge that holds it, so the prefill
jit cache can never grow past ``len(edges)`` entries.  Padding is safe
for position-indexed (KV-cache) families because the engine rewinds the
slot's ``pos`` to the true prompt length after prefill — every pad key
sits at a position ``>= pos`` and is overwritten by a real decode key
before the causal mask can ever see it (the §13 pad-shadowing
invariant).

Assignment is a pure function of (edges, length): deterministic, no
clocks, no state — the retrace-count test pins ``compiles == buckets
touched``.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Sorted, strictly increasing prompt-length bucket edges."""

    edges: Tuple[int, ...]

    def __post_init__(self):
        if not self.edges:
            raise ValueError("BucketSpec needs at least one edge")
        edges = tuple(int(e) for e in self.edges)
        if any(e < 1 for e in edges):
            raise ValueError(f"bucket edges must be >= 1, got {edges}")
        if list(edges) != sorted(set(edges)):
            raise ValueError(
                f"bucket edges must be strictly increasing, got {edges}")
        object.__setattr__(self, "edges", edges)

    @property
    def max_prompt_len(self) -> int:
        return self.edges[-1]

    def bucket_for(self, length: int) -> int:
        """Smallest edge that holds ``length`` (the padded prefill shape)."""
        if length < 1:
            raise ValueError(f"prompt length {length} must be >= 1")
        i = bisect.bisect_left(self.edges, length)
        if i == len(self.edges):
            raise ValueError(
                f"prompt length {length} exceeds the largest bucket edge "
                f"{self.edges[-1]} — grow BucketSpec.edges or reject the "
                "request at admission")
        return self.edges[i]

    def pad(self, prompt: np.ndarray, pad_id: int = 0) -> np.ndarray:
        """Right-pad a 1-D token array to its bucket edge (shape (1, edge))."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1:
            raise ValueError(f"prompt must be 1-D, got shape {prompt.shape}")
        edge = self.bucket_for(prompt.shape[0])
        out = np.full((1, edge), pad_id, np.int32)
        out[0, : prompt.shape[0]] = prompt
        return out
