"""The continuous-batching actor-inference frontend (DESIGN.md §13).

``ActorServer`` is the user-scale surface of the reproduction: clients
``submit`` token prompts from any thread and get back a ``ServeHandle``
(a future); a single serve loop — background thread via ``start()`` or
foreground via ``drain()``/``serve_step()`` — runs the continuous-
batching scheduler over the vmapped decode engine.  Parameter hot-swap
rides the §13 double buffer: ``publish()`` (or a replay-service param
channel attached at construction) stages a new tree from any thread,
and the loop promotes it exactly once per step boundary, so a training
learner can retarget the policy under live traffic without a latency
spike and without ever mixing versions inside one batch step.

Threading contract: the scheduler and engine are touched by the serve
loop ONLY.  Cross-thread state (the submit inbox, the handle table, the
completion log) lives behind ``self._cond``; the loop drains the inbox
at each step boundary and resolves handles after eviction.  Run either
the background thread or inline stepping — not both at once.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.models.config import NO_SHARDING, ModelConfig
from repro.serve.buckets import BucketSpec
from repro.serve.engine import DecodeEngine
from repro.serve.params import ParamDoubleBuffer, ServiceParamChannel
from repro.serve.scheduler import Completion, Scheduler

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ActorServeConfig:
    slots: int = 4                      # decode batch width
    max_len: int = 64                   # KV-cache length per slot
    buckets: Tuple[int, ...] = (16, 32)  # prompt-length padding buckets
    max_new_tokens: int = 16            # default generation budget
    idle_wait_s: float = 0.02           # loop sleep when queue+slots empty

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens={self.max_new_tokens}: must be >= 1")


class ServeHandle:
    """Client-side future for one submitted request."""

    def __init__(self, rid_hint: Optional[int] = None):
        self._event = threading.Event()
        self._completion: Optional[Completion] = None
        self.rid = rid_hint

    def _resolve(self, completion: Completion) -> None:
        self._completion = completion
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Completion:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request did not complete within {timeout}s")
        assert self._completion is not None
        return self._completion


class ActorServer:
    def __init__(self, cfg: ModelConfig, params: Pytree,
                 serve_cfg: ActorServeConfig = ActorServeConfig(),
                 shd=NO_SHARDING, *, params_version: int = 1,
                 param_source: Any = None):
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self.engine = DecodeEngine(
            cfg, shd, slots=serve_cfg.slots, max_len=serve_cfg.max_len,
            buckets=BucketSpec(serve_cfg.buckets))
        self.scheduler = Scheduler(self.engine)
        self.params = ParamDoubleBuffer(params, version=params_version)
        self.channel = (ServiceParamChannel(param_source, self.params)
                        if param_source is not None else None)
        self._cond = threading.Condition()
        self._inbox: deque = deque()      # (prompt, max_new, handle, t)
        self._handles: Dict[int, ServeHandle] = {}
        self._latencies: deque = deque(maxlen=65536)  # (t_done, s, version)
        self._swap_log: deque = deque(maxlen=1024)    # (step, new version)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- client side ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: Optional[int] = None
               ) -> ServeHandle:
        """Enqueue one prompt (any thread); admission capacity is
        checked here so the caller gets the ValueError, not the loop."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        budget = (self.serve_cfg.max_new_tokens if max_new_tokens is None
                  else int(max_new_tokens))
        self.engine.fits(prompt.shape[0], budget)
        handle = ServeHandle()
        with self._cond:
            self._inbox.append(
                (prompt, budget, handle, time.perf_counter()))
            self._cond.notify_all()
        return handle

    def publish(self, params: Pytree, version: Optional[int] = None) -> int:
        """Stage new policy weights (any thread — typically the training
        learner); the loop swaps them in at its next step boundary."""
        v = self.params.stage(params, version)
        with self._cond:
            self._cond.notify_all()
        return v

    # -- serve loop -----------------------------------------------------------

    def serve_step(self) -> List[Completion]:
        """One step boundary: drain the inbox, poll the param channel,
        promote any staged params, then run one scheduler window."""
        with self._cond:
            while self._inbox:
                prompt, budget, handle, t = self._inbox.popleft()
                rid = self.scheduler.submit(prompt, budget, enqueued_at=t)
                handle.rid = rid
                self._handles[rid] = handle
        if self.channel is not None:
            self.channel.poll()
        params, version, swapped = self.params.swap_if_staged()
        if swapped:
            self._swap_log.append((self.scheduler.step_count + 1, version))
        completions = self.scheduler.serve_step(params, version)
        if completions:
            with self._cond:
                for c in completions:
                    self._latencies.append(
                        (c.finished_at, c.latency_s, c.params_version))
                    handle = self._handles.pop(c.rid, None)
                    if handle is not None:
                        handle._resolve(c)
        return completions

    def drain(self, timeout: Optional[float] = None) -> int:
        """Foreground mode: step until queue and slots are empty.
        Returns the number of completions resolved."""
        deadline = None if timeout is None else time.monotonic() + timeout
        n = 0
        while True:
            with self._cond:
                pending = bool(self._inbox)
            if not pending and not self.scheduler.busy:
                return n
            n += len(self.serve_step())
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(f"drain exceeded {timeout}s "
                                   f"({n} completions so far)")

    def _loop(self) -> None:
        while not self._stop.is_set():
            self.serve_step()
            with self._cond:
                idle = not self._inbox and not self.scheduler.busy
                if idle and not self._stop.is_set():
                    # periodic wake even when idle: the param channel
                    # only advances when polled
                    self._cond.wait(self.serve_cfg.idle_wait_s)

    def start(self) -> "ActorServer":
        if self._thread is not None:
            raise RuntimeError("ActorServer already started")
        self._thread = threading.Thread(
            target=self._loop, name="actor-serve", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    # -- stats ----------------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        sched = self.scheduler
        with self._cond:
            lat = [s for _, s, _ in self._latencies]
            swaps = list(self._swap_log)
        out = {
            "completed": len(lat),
            "steps": sched.step_count,
            "admissions": sched.admissions,
            "decoded_tokens": sched.decoded_tokens,
            "generated_tokens": sched.generated_tokens,
            "queued": len(sched.queue),
            "active_slots": sched.n_active,
            "params_version": self.params.version,
            "param_swaps": self.params.swaps,
            "swap_log": swaps,
            "prime_compiles": self.engine.prime_compiles,
            "decode_compiles": self.engine.decode_compiles,
            "prefill_s": sched.timings["prefill_s"],
            "decode_s": sched.timings["decode_s"],
        }
        if lat:
            out["latency_p50_ms"] = float(np.percentile(lat, 50) * 1e3)
            out["latency_p99_ms"] = float(np.percentile(lat, 99) * 1e3)
        return out
