"""Continuous-batching actor-inference frontend (DESIGN.md §13).

The act() path at user scale: a request queue feeding dynamic batches
with prompt-length padding buckets (retraces bounded to the bucket
set), a scheduler that admits new requests into free decode slots each
serve step (continuous batching over per-slot KV caches, finished
sequences evicted in place), and double-buffered parameter publication
reusing the ``params_for_acting`` contract — the replay service's
versioned params channel (service/server.py) is the publisher, so a
training learner hot-swaps policy weights under live traffic.
"""

from repro.serve.buckets import BucketSpec
from repro.serve.engine import DecodeEngine, DecodeState, SUPPORTED_FAMILIES
from repro.serve.params import ParamDoubleBuffer, ServiceParamChannel
from repro.serve.scheduler import Completion, Request, Scheduler
from repro.serve.server import ActorServeConfig, ActorServer, ServeHandle

__all__ = [
    "ActorServeConfig",
    "ActorServer",
    "BucketSpec",
    "Completion",
    "DecodeEngine",
    "DecodeState",
    "ParamDoubleBuffer",
    "Request",
    "Scheduler",
    "ServeHandle",
    "ServiceParamChannel",
    "SUPPORTED_FAMILIES",
]
