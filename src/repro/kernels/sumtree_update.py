"""Pallas TPU kernel: batched priority update with upward delta propagation.

TPU adaptation of paper Alg. 2 UPDATEVALUE + Alg. 3 synchronization:

  * scatter of per-update deltas into each ancestor level is a **one-hot
    MXU matmul**: ``one_hot(group).T @ (delta ⊙ one_hot(child))`` produces
    a dense (groups, K) delta matrix accumulated into the VMEM-resident
    level — the systolic replacement for lock-protected scatter;
  * duplicate leaf indices are resolved to last-writer-wins *before* the
    kernel launches: the wrapper (ops.py) computes the sort-based
    last-writer mask over the whole batch (core/sumtree.py) and passes
    it in, so at most one entry per leaf carries a non-zero delta.  The
    old in-kernel O(UB²) triangular dedup and the delta-neutral padding
    dance are gone — padded entries simply arrive with mask 0;
  * levels are aliased input↔output (in-place tree update).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

UPDATE_BLOCK = 128  # UB — updates per grid step


def _kernel(fanout: int, idx_ref, val_ref, mask_ref, *refs):
    """refs = (root_out, level_1_out, ..., level_H_out), aliased to inputs."""
    root_ref = refs[0]
    level_refs = refs[1:]
    k = fanout
    ub = idx_ref.shape[0]

    idx = idx_ref[...]
    val = val_ref[...].astype(jnp.float32)
    # Full-batch last-writer mask (precomputed sort-based merge in the
    # wrapper): 1.0 on the single surviving write per leaf, 0.0 on
    # superseded duplicates and padding.
    mask = mask_ref[...].astype(jnp.float32)

    lane = jax.lax.broadcasted_iota(jnp.int32, (ub, k), 1)

    # Leaf level: read old values (MXU gather), compute masked deltas, set.
    leaf_ref = level_refs[-1]
    leaf = leaf_ref[...].astype(jnp.float32)       # (G_H, K)
    g_h = leaf.shape[0]
    g = idx // k
    c = idx % k
    giota = jax.lax.broadcasted_iota(jnp.int32, (ub, g_h), 1)
    oh_g = (g[:, None] == giota).astype(jnp.float32)       # (UB, G_H)
    oh_c = (c[:, None] == lane).astype(jnp.float32)        # (UB, K)
    rows = jax.lax.dot(oh_g, leaf, precision=jax.lax.Precision.HIGHEST)
    old = jnp.sum(rows * oh_c, axis=-1)
    delta = (val - old) * mask
    scat = jax.lax.dot(                                     # (G_H, K) scatter
        oh_g.T, delta[:, None] * oh_c, precision=jax.lax.Precision.HIGHEST
    )
    leaf_ref[...] = (leaf + scat).astype(leaf_ref.dtype)

    # Intermediate levels: pure scatter-add of deltas (duplicates sum).
    node = g
    for ref in level_refs[-2::-1]:
        lv = ref[...].astype(jnp.float32)
        g_l = lv.shape[0]
        g2 = node // k
        c2 = node % k
        giota2 = jax.lax.broadcasted_iota(jnp.int32, (ub, g_l), 1)
        oh_g2 = (g2[:, None] == giota2).astype(jnp.float32)
        oh_c2 = (c2[:, None] == lane).astype(jnp.float32)
        scat2 = jax.lax.dot(
            oh_g2.T, delta[:, None] * oh_c2, precision=jax.lax.Precision.HIGHEST
        )
        ref[...] = (lv + scat2).astype(ref.dtype)
        node = g2

    # Padded root group: root value at (0, 0).
    root = root_ref[...].astype(jnp.float32)                # (1, K)
    zero_lane = (jax.lax.broadcasted_iota(jnp.int32, (1, k), 1) == 0)
    root_ref[...] = (
        root + jnp.where(zero_lane, jnp.sum(delta), 0.0)
    ).astype(root_ref.dtype)


def sumtree_update_levels(
    root: jax.Array,
    levels: Sequence[jax.Array],
    idx: jax.Array,
    values: jax.Array,
    mask: jax.Array,
    *,
    fanout: int,
    interpret: bool = False,
) -> tuple[jax.Array, ...]:
    """SET priorities at ``idx`` and propagate deltas to every level + root.

    ``root``: (1, K) padded root group.  ``levels[l]``: (groups_l, K),
    leaf level last.  ``mask``: int32 0/1, the full-batch last-writer
    mask (padding entries 0).  Returns updated (root, *levels).  B must
    be a multiple of UPDATE_BLOCK (ops.py pads with masked-out entries).
    """
    b = idx.shape[0]
    assert b % UPDATE_BLOCK == 0, b
    grid = (b // UPDATE_BLOCK,)

    tree_in = [root, *levels]
    tree_specs = [pl.BlockSpec(t.shape, lambda i: (0, 0)) for t in tree_in]
    return pl.pallas_call(
        functools.partial(_kernel, fanout),
        grid=grid,
        in_specs=[
            pl.BlockSpec((UPDATE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((UPDATE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((UPDATE_BLOCK,), lambda i: (i,)),
        ] + tree_specs,
        out_specs=tree_specs,
        out_shape=[jax.ShapeDtypeStruct(t.shape, t.dtype) for t in tree_in],
        input_output_aliases={3 + j: j for j in range(len(tree_in))},
        interpret=interpret,
    )(idx, values, mask, *tree_in)
