"""Pallas TPU kernel: fused inverse-CDF sample + prioritized gather.

The paper's Sampling step is two irregular-memory phases — descend the
sum tree, then fetch the sampled transitions from storage (Table I).
The split kernels (sumtree_sample.py + gather.py) round-trip the sampled
indices through HBM between two kernel launches; this kernel fuses both
phases, so the indices are produced and consumed inside one grid:

  * grid = (B / SB sample blocks, N / NB storage steps), storage steps
    innermost;
  * at storage step 0 the block runs the shared descent
    (``sumtree_sample.descend`` — the same code path as the split
    kernel, so the two cannot drift) over the VMEM-resident levels and
    writes ``out_idx``/``out_pri``;
  * every storage step (including step 0) then re-reads ``out_idx``
    from its pinned output block — never from HBM — and accumulates
    ``one_hot(idx ∈ block) @ storage_block`` into each storage leaf's
    pinned output block (the gather.py accumulator pattern, one shared
    one-hot for *all* leaves instead of one per gather call).

Storage leaves are streamed as f32 (N, F) matrices; integer payloads are
exact below 2^24 (one-hot matmul sums in f32 — same contract as
gather.py).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sumtree_sample import descend

SAMPLE_BLOCK = 128   # SB — draws per sample block
STORAGE_BLOCK = 512  # NB — storage rows per streaming step


def _kernel(capacity: int, fanout: int, n_levels: int,
            u_ref, *refs):
    """refs = (level_1..level_H, storage_0..storage_L,
               out_idx, out_pri, gathered_0..gathered_L)."""
    level_refs = refs[:n_levels]
    n_storage = (len(refs) - n_levels - 2) // 2
    storage_refs = refs[n_levels:n_levels + n_storage]
    out_idx_ref = refs[n_levels + n_storage]
    out_pri_ref = refs[n_levels + n_storage + 1]
    gathered_refs = refs[n_levels + n_storage + 2:]

    n_step = pl.program_id(1)
    nb = storage_refs[0].shape[0]
    sb = u_ref.shape[0]

    @pl.when(n_step == 0)
    def _descend_and_init():
        level_vals = [ref[...].astype(jnp.float32) for ref in level_refs]
        u = u_ref[...].astype(jnp.float32)
        leaf, pri = descend(level_vals, u, capacity=capacity, fanout=fanout)
        out_idx_ref[...] = leaf
        out_pri_ref[...] = pri
        for g_ref in gathered_refs:
            g_ref[...] = jnp.zeros_like(g_ref)

    # idx comes from the pinned output block (same block ∀ storage steps)
    # — written above at step 0, persistent across the inner grid axis.
    idx = out_idx_ref[...]
    local = idx - n_step * nb
    niota = jax.lax.broadcasted_iota(jnp.int32, (sb, nb), 1)
    onehot = (local[:, None] == niota).astype(jnp.float32)  # 0 out of block
    for s_ref, g_ref in zip(storage_refs, gathered_refs):
        block = s_ref[...].astype(jnp.float32)              # (NB, F)
        acc = jax.lax.dot(onehot, block,
                          precision=jax.lax.Precision.HIGHEST)
        g_ref[...] = g_ref[...] + acc.astype(g_ref.dtype)


def sample_gather_levels(
    levels: Sequence[jax.Array],
    u: jax.Array,
    storage_mats: Sequence[jax.Array],
    *,
    capacity: int,
    fanout: int,
    interpret: bool = False,
):
    """Sample ``u.shape[0]`` leaves and gather their storage rows.

    ``levels[l]``: (groups_l, K), top-down below the root, leaf level
    last (sumtree_sample layout).  ``storage_mats[j]``: f32 (N, F_j)
    with one shared padded row count N (a multiple of STORAGE_BLOCK).
    B must be a multiple of SAMPLE_BLOCK (ops.py pads).  Returns
    (idx, pri, [gathered_j]).
    """
    b = u.shape[0]
    assert b % SAMPLE_BLOCK == 0, b
    n = storage_mats[0].shape[0]
    assert n % STORAGE_BLOCK == 0, n
    assert all(m.shape[0] == n for m in storage_mats)
    grid = (b // SAMPLE_BLOCK, n // STORAGE_BLOCK)

    level_specs = [pl.BlockSpec(lv.shape, lambda i, j: (0, 0))
                   for lv in levels]
    storage_specs = [
        pl.BlockSpec((STORAGE_BLOCK, m.shape[1]), lambda i, j: (j, 0))
        for m in storage_mats
    ]
    gathered_specs = [
        pl.BlockSpec((SAMPLE_BLOCK, m.shape[1]), lambda i, j: (i, 0))
        for m in storage_mats
    ]
    out_shapes = (
        [jax.ShapeDtypeStruct((b,), jnp.int32),
         jax.ShapeDtypeStruct((b,), jnp.float32)]
        + [jax.ShapeDtypeStruct((b, m.shape[1]), jnp.float32)
           for m in storage_mats]
    )
    out = pl.pallas_call(
        functools.partial(_kernel, capacity, fanout, len(levels)),
        grid=grid,
        in_specs=([pl.BlockSpec((SAMPLE_BLOCK,), lambda i, j: (i,))]
                  + level_specs + storage_specs),
        out_specs=[
            pl.BlockSpec((SAMPLE_BLOCK,), lambda i, j: (i,)),
            pl.BlockSpec((SAMPLE_BLOCK,), lambda i, j: (i,)),
        ] + gathered_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(u, *levels, *storage_mats)
    idx, pri, *gathered = out
    return idx, pri, gathered
