"""Public jit'd wrappers for the Pallas kernels.

Responsibilities:
  * flat-tree ↔ level-matrix conversion (the kernels see each level as a
    (groups, K) matrix; the rest of the system uses the paper's flat
    implicit-array layout);
  * batch padding to kernel block multiples.  Updates carry the
    full-batch sort-based last-writer mask (core/sumtree.py) computed
    *outside* the kernel, so padded entries are simply masked out and
    sequential last-writer-wins semantics hold across grid blocks
    without any in-kernel dedup;
  * VMEM-budget dispatch: trees whose working set exceeds the kernel's
    VMEM budget fall back to the ``core.sumtree`` XLA path (documented in
    DESIGN.md §4.2);
  * ``interpret`` switching: on CPU (this container) kernels run in
    Pallas interpret mode; on TPU they compile to Mosaic.
"""

from __future__ import annotations

import functools
from typing import Any, List

import jax
import jax.numpy as jnp

from repro.core import sumtree as _st
from repro.core.sumtree import SumTreeSpec
from repro.kernels import gather as _gather
from repro.kernels import sample_gather as _ksg
from repro.kernels import sumtree_sample as _ks
from repro.kernels import sumtree_update as _ku

Pytree = Any

# VMEM working-set cap for the kernel path (bytes); beyond this the ops
# fall back to XLA.  ~8 MB leaves headroom for one-hots + transients in
# a 16 MB v5e VMEM.
KERNEL_TREE_BYTE_BUDGET = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def _ceil_to(x: int, k: int) -> int:
    return ((x + k - 1) // k) * k


def tree_to_levels(spec: SumTreeSpec, tree: jax.Array) -> List[jax.Array]:
    """Split the flat array into (groups, K) level matrices, root first."""
    out = []
    for level in range(len(spec.level_sizes)):
        off, size = spec.offsets[level], spec.level_sizes[level]
        lv = jax.lax.dynamic_slice(tree, (off,), (size,))
        out.append(lv.reshape(size // spec.fanout, spec.fanout))
    return out


def levels_to_tree(spec: SumTreeSpec, levels) -> jax.Array:
    flat = jnp.concatenate([lv.reshape(-1) for lv in levels])
    return jnp.concatenate([flat, jnp.zeros((1,), flat.dtype)])  # scratch


def kernel_path_ok(spec: SumTreeSpec) -> bool:
    return spec.total_size * 4 <= KERNEL_TREE_BYTE_BUDGET


# -- sampling ---------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,))
def sumtree_sample(spec: SumTreeSpec, tree: jax.Array, u: jax.Array):
    """Kernel-backed batched sample; XLA fallback above VMEM budget."""
    if not kernel_path_ok(spec):
        return _st.sample(spec, tree, u)
    b = u.shape[0]
    bp = _ceil_to(b, _ks.SAMPLE_BLOCK)
    u_pad = jnp.pad(u, (0, bp - b), constant_values=0.5)
    levels = tree_to_levels(spec, tree)[1:]  # descent starts below the root
    idx, pri = _ks.sumtree_sample_levels(
        levels, u_pad,
        capacity=spec.capacity, fanout=spec.fanout,
        interpret=_interpret(),
    )
    return idx[:b], pri[:b]


# -- update -------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0, 4))
def sumtree_update(spec: SumTreeSpec, tree: jax.Array, idx: jax.Array,
                   values: jax.Array, unique: bool = False) -> jax.Array:
    """Kernel-backed batched SET; XLA fallback above VMEM budget.

    Duplicate resolution happens here, not in the kernel: the sort-based
    last-writer merge (``core.sumtree.last_writer_mask``) runs once over
    the whole batch and the kernel receives the mask — padding entries
    are masked-out writes to leaf 0 (no delta-neutral value dance), and
    cross-grid-block duplicates need no sequential-ordering argument
    because at most one entry per leaf survives the merge.
    ``unique=True`` skips the merge for caller-guaranteed distinct
    indices (FIFO insert slots).
    """
    if not kernel_path_ok(spec):
        return _st.update(spec, tree, idx, values, unique=unique)
    b = idx.shape[0]
    idx = idx.astype(jnp.int32)
    mask = (jnp.ones((b,), jnp.int32) if unique
            else _st.last_writer_mask(idx, spec.num_leaves).astype(jnp.int32))
    bp = _ceil_to(b, _ku.UPDATE_BLOCK)
    if bp != b:
        idx = jnp.pad(idx, (0, bp - b))
        values = jnp.pad(values, (0, bp - b))
        mask = jnp.pad(mask, (0, bp - b))
    root, *levels = tree_to_levels(spec, tree)
    out = _ku.sumtree_update_levels(
        root, levels, idx, values, mask,
        fanout=spec.fanout, interpret=_interpret(),
    )
    return levels_to_tree(spec, out)


# -- fused sample + gather ----------------------------------------------------


def _flatten_storage_leaf(buf: jax.Array):
    """(capacity, ...) leaf → f32 (capacity, F) matrix + restorer."""
    shape = buf.shape
    feat = 1
    for s in shape[1:]:
        feat *= s
    flat = buf.reshape(shape[0], feat).astype(jnp.float32)

    def restore(g: jax.Array, b: int) -> jax.Array:
        out = g[:b].reshape((b,) + shape[1:])
        if jnp.issubdtype(buf.dtype, jnp.inexact):
            return out.astype(buf.dtype)
        return jnp.round(out).astype(buf.dtype)

    return flat, restore


@functools.partial(jax.jit, static_argnums=(0,))
def sumtree_sample_gather(spec: SumTreeSpec, tree: jax.Array, u: jax.Array,
                          storage: Pytree):
    """Fused descent + storage fetch: one kernel produces (idx, pri,
    items) — the sampled indices never leave VMEM between the tree walk
    and the row gather (the paper's irregular-memory-access fix).

    Falls back to the split sample + per-leaf gather path above the
    VMEM budget or for zero-feature leaves.  Integer payloads are exact
    below 2^24 (one-hot matmul accumulates in f32 — the gather.py
    contract).
    """
    leaves, treedef = jax.tree.flatten(storage)

    def split_path():
        idx, pri = sumtree_sample(spec, tree, u)
        items = jax.tree.unflatten(
            treedef, [prioritized_gather(leaf, idx) for leaf in leaves])
        return idx, pri, items

    if not kernel_path_ok(spec) or not leaves or any(
            leaf.size == 0 for leaf in leaves):
        return split_path()
    b = u.shape[0]
    bp = _ceil_to(b, _ksg.SAMPLE_BLOCK)
    u_pad = jnp.pad(u, (0, bp - b), constant_values=0.5)
    n = leaves[0].shape[0]
    np_ = _ceil_to(n, _ksg.STORAGE_BLOCK)
    mats, restores = zip(*[_flatten_storage_leaf(leaf) for leaf in leaves])
    mats = [jnp.pad(m, ((0, np_ - n), (0, 0))) for m in mats]
    levels = tree_to_levels(spec, tree)[1:]  # descent starts below the root
    idx, pri, gathered = _ksg.sample_gather_levels(
        levels, u_pad, mats,
        capacity=spec.capacity, fanout=spec.fanout,
        interpret=_interpret(),
    )
    items = jax.tree.unflatten(
        treedef, [res(g, b) for res, g in zip(restores, gathered)])
    return idx[:b], pri[:b], items


# -- storage gather -----------------------------------------------------------

@jax.jit
def prioritized_gather(storage: jax.Array, idx: jax.Array) -> jax.Array:
    """out[i] = storage[idx[i]], any-rank storage (leading index dim)."""
    shape = storage.shape
    n = shape[0]
    feat = 1
    for s in shape[1:]:
        feat *= s
    if feat == 0:
        return storage[idx]
    flat = storage.reshape(n, feat)
    b = idx.shape[0]
    bp = _ceil_to(b, _gather.BATCH_BLOCK)
    np_ = _ceil_to(n, _gather.STORAGE_BLOCK)
    idx_pad = jnp.pad(idx.astype(jnp.int32), (0, bp - b))
    flat_pad = jnp.pad(flat, ((0, np_ - n), (0, 0)))
    out = _gather.gather_rows(flat_pad, idx_pad, interpret=_interpret())
    return out[:b].reshape((b,) + shape[1:])
