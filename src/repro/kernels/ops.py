"""Public jit'd wrappers for the Pallas kernels.

Responsibilities:
  * flat-tree ↔ level-matrix conversion (the kernels see each level as a
    (groups, K) matrix; the rest of the system uses the paper's flat
    implicit-array layout);
  * batch padding to kernel block multiples, with delta-neutral padding
    for updates (a padded update targets the same leaf as the *last* real
    update of that leaf — or the leaf's current value — so sequential
    last-writer-wins semantics are preserved);
  * VMEM-budget dispatch: trees whose working set exceeds the kernel's
    VMEM budget fall back to the ``core.sumtree`` XLA path (documented in
    DESIGN.md §4.2);
  * ``interpret`` switching: on CPU (this container) kernels run in
    Pallas interpret mode; on TPU they compile to Mosaic.
"""

from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp

from repro.core import sumtree as _st
from repro.core.sumtree import SumTreeSpec
from repro.kernels import gather as _gather
from repro.kernels import sumtree_sample as _ks
from repro.kernels import sumtree_update as _ku

# VMEM working-set cap for the kernel path (bytes); beyond this the ops
# fall back to XLA.  ~8 MB leaves headroom for one-hots + transients in
# a 16 MB v5e VMEM.
KERNEL_TREE_BYTE_BUDGET = 8 * 1024 * 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not _on_tpu()


def _ceil_to(x: int, k: int) -> int:
    return ((x + k - 1) // k) * k


def tree_to_levels(spec: SumTreeSpec, tree: jax.Array) -> List[jax.Array]:
    """Split the flat array into (groups, K) level matrices, root first."""
    out = []
    for level in range(len(spec.level_sizes)):
        off, size = spec.offsets[level], spec.level_sizes[level]
        lv = jax.lax.dynamic_slice(tree, (off,), (size,))
        out.append(lv.reshape(size // spec.fanout, spec.fanout))
    return out


def levels_to_tree(spec: SumTreeSpec, levels) -> jax.Array:
    flat = jnp.concatenate([lv.reshape(-1) for lv in levels])
    return jnp.concatenate([flat, jnp.zeros((1,), flat.dtype)])  # scratch


def kernel_path_ok(spec: SumTreeSpec) -> bool:
    return spec.total_size * 4 <= KERNEL_TREE_BYTE_BUDGET


# -- sampling ---------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,))
def sumtree_sample(spec: SumTreeSpec, tree: jax.Array, u: jax.Array):
    """Kernel-backed batched sample; XLA fallback above VMEM budget."""
    if not kernel_path_ok(spec):
        return _st.sample(spec, tree, u)
    b = u.shape[0]
    bp = _ceil_to(b, _ks.SAMPLE_BLOCK)
    u_pad = jnp.pad(u, (0, bp - b), constant_values=0.5)
    levels = tree_to_levels(spec, tree)[1:]  # descent starts below the root
    idx, pri = _ks.sumtree_sample_levels(
        levels, u_pad,
        capacity=spec.capacity, fanout=spec.fanout,
        interpret=_interpret(),
    )
    return idx[:b], pri[:b]


# -- update -------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,))
def sumtree_update(spec: SumTreeSpec, tree: jax.Array, idx: jax.Array,
                   values: jax.Array) -> jax.Array:
    """Kernel-backed batched SET; XLA fallback above VMEM budget."""
    if not kernel_path_ok(spec):
        return _st.update(spec, tree, idx, values)
    b = idx.shape[0]
    bp = _ceil_to(b, _ku.UPDATE_BLOCK)
    if bp != b:
        # Delta-neutral padding: pad entries re-write the final value of
        # leaf `t` (the last real write to `t`, else its current value),
        # so the extra last-writers change nothing.
        t = spec.capacity - 1
        match = idx == t
        has = jnp.any(match)
        last_pos = jnp.max(jnp.where(match, jnp.arange(b), -1))
        cur = tree[spec.leaf_offset + t]
        pad_val = jnp.where(has, values[jnp.maximum(last_pos, 0)], cur)
        idx = jnp.pad(idx, (0, bp - b), constant_values=t)
        values = jnp.concatenate(
            [values, jnp.broadcast_to(pad_val, (bp - b,)).astype(values.dtype)]
        )
    root, *levels = tree_to_levels(spec, tree)
    out = _ku.sumtree_update_levels(
        root, levels, idx.astype(jnp.int32), values,
        fanout=spec.fanout, interpret=_interpret(),
    )
    return levels_to_tree(spec, out)


# -- storage gather -----------------------------------------------------------

@jax.jit
def prioritized_gather(storage: jax.Array, idx: jax.Array) -> jax.Array:
    """out[i] = storage[idx[i]], any-rank storage (leading index dim)."""
    shape = storage.shape
    n = shape[0]
    feat = 1
    for s in shape[1:]:
        feat *= s
    if feat == 0:
        return storage[idx]
    flat = storage.reshape(n, feat)
    b = idx.shape[0]
    bp = _ceil_to(b, _gather.BATCH_BLOCK)
    np_ = _ceil_to(n, _gather.STORAGE_BLOCK)
    idx_pad = jnp.pad(idx.astype(jnp.int32), (0, bp - b))
    flat_pad = jnp.pad(flat, ((0, np_ - n), (0, 0)))
    out = _gather.gather_rows(flat_pad, idx_pad, interpret=_interpret())
    return out[:b].reshape((b,) + shape[1:])
