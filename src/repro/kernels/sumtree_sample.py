"""Pallas TPU kernel: batched prefix-sum descent over the K-ary sum tree.

TPU adaptation of the paper's cache-aligned sibling scan (§IV-C3/C4):

  * every level is a ``(groups, K)`` matrix — one sibling group per row;
    with K = 128 a row is exactly one lane-aligned VREG row (the paper's
    cache line);
  * per-sample row gather is a **one-hot MXU matmul**
    ``one_hot(group_idx, G) @ level`` — TPUs have no efficient scalar
    gather, so the "minimise cache misses" goal becomes "turn the
    irregular access into a dense systolic op";
  * the linear child scan becomes a lane-parallel ``cumsum`` + first-hit
    ``argmax`` over the 128-lane row (VPU);
  * all levels are VMEM-resident (BlockSpec index_map pinned to block 0);
    the grid streams sample blocks of ``SB`` draws.

VMEM budget: tree bytes + SB·G_leaf·4 (one-hot) + transient rows.  The
``ops.py`` wrapper falls back to the XLA path when the leaf level exceeds
the VMEM budget (documented limit; at that size HBM gathers dominate and
XLA's native gather is the right tool).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SAMPLE_BLOCK = 128  # SB — samples per grid step


def descend(level_vals, u, *, capacity: int, fanout: int):
    """Shared in-kernel inverse-CDF descent over loaded level matrices.

    ``level_vals[l]``: (groups_l, K) f32, top-down below the root (leaf
    level last).  Returns (leaf, pri) for ``u.shape[0]`` draws — also
    used by the fused sample+gather kernel (sample_gather.py), so the
    two kernels cannot drift apart numerically.
    """
    k = fanout
    sb = u.shape[0]
    total = jnp.sum(level_vals[0])                 # (1, K) — children of root
    residual = jnp.clip(u, 1e-12, 1.0 - 1e-7) * total
    group = jnp.zeros((sb,), jnp.int32)

    lane = jax.lax.broadcasted_iota(jnp.int32, (sb, k), 1)
    row_val = jnp.zeros((sb,), jnp.float32)
    for lv in level_vals:                          # (G, K) per level
        g = lv.shape[0]
        giota = jax.lax.broadcasted_iota(jnp.int32, (sb, g), 1)
        onehot = (group[:, None] == giota).astype(jnp.float32)
        rows = jax.lax.dot(                        # MXU gather of sibling rows
            onehot, lv, precision=jax.lax.Precision.HIGHEST
        )                                          # (SB, K)
        csum = jnp.cumsum(rows, axis=-1)
        hit = csum >= residual[:, None]
        cutoff = jnp.argmax(hit, axis=-1).astype(jnp.int32)
        cutoff = jnp.where(jnp.any(hit, axis=-1), cutoff, k - 1)
        sel = (lane == cutoff[:, None]).astype(jnp.float32)
        picked = jnp.sum(csum * sel, axis=-1)
        row_val = jnp.sum(rows * sel, axis=-1)
        residual = residual - (picked - row_val)   # drop prefix before cutoff
        group = group * k + cutoff

    leaf = jnp.minimum(group, capacity - 1)
    # Parity with the XLA path (core/sumtree.py), which re-reads the
    # priority AFTER clamping: an fp-tail draw whose no-hit clamps cascade
    # into the leaf-level padding has row_val = 0 (the padding lane), but
    # the clamped leaf is `capacity - 1`, whose priority is a static
    # (group, lane) read of the leaf level — `lv` still holds the loop's
    # last (leaf-level) load, so no second VMEM read of the largest level.
    clamp_val = lv[(capacity - 1) // k, (capacity - 1) % k]
    pri = jnp.where(group > capacity - 1, clamp_val, row_val)
    return leaf, pri


def _kernel(capacity: int, fanout: int, u_ref, *refs):
    """refs = (level_1, ..., level_H, out_idx, out_pri)."""
    level_refs = refs[:-2]
    out_idx_ref, out_pri_ref = refs[-2:]
    level_vals = [ref[...].astype(jnp.float32) for ref in level_refs]
    u = u_ref[...].astype(jnp.float32)
    leaf, pri = descend(level_vals, u, capacity=capacity, fanout=fanout)
    out_idx_ref[...] = leaf
    out_pri_ref[...] = pri


def sumtree_sample_levels(
    levels: Sequence[jax.Array],
    u: jax.Array,
    *,
    capacity: int,
    fanout: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Sample ``u.shape[0]`` leaves from level matrices (top-down, no root).

    ``levels[l]`` has shape (groups_l, K); ``levels[-1]`` is the leaf level.
    B must be a multiple of SAMPLE_BLOCK (ops.py pads).
    """
    b = u.shape[0]
    assert b % SAMPLE_BLOCK == 0, b
    grid = (b // SAMPLE_BLOCK,)

    level_specs = [
        pl.BlockSpec(lv.shape, lambda i: (0, 0)) for lv in levels
    ]
    return pl.pallas_call(
        functools.partial(_kernel, capacity, fanout),
        grid=grid,
        in_specs=[pl.BlockSpec((SAMPLE_BLOCK,), lambda i: (i,))] + level_specs,
        out_specs=[
            pl.BlockSpec((SAMPLE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((SAMPLE_BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )(u, *levels)
