"""Pallas TPU kernel: prioritized minibatch assembly (storage gather).

The paper's "access the storage" step of Sampling (Table I).  Random
HBM reads of sampled transitions are the irregular-access hot spot; on
TPU we stream the storage through VMEM in blocks and assemble the batch
with one-hot MXU matmuls:

    out[b_block] = Σ_n  one_hot(idx_block ∈ n_block) @ storage[n_block]

Grid = (N / NB) storage steps × (B / BB) batch blocks; the output block
is revisited across the N dimension (accumulator pattern).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BATCH_BLOCK = 128   # BB
STORAGE_BLOCK = 512  # NB


def _kernel(idx_ref, storage_ref, out_ref):
    n_step = pl.program_id(1)
    nb = storage_ref.shape[0]

    @pl.when(n_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...]                               # (BB,) global indices
    local = idx - n_step * nb                        # position inside block
    niota = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], nb), 1)
    onehot = (local[:, None] == niota).astype(jnp.float32)  # 0 if out of block
    block = storage_ref[...].astype(jnp.float32)     # (NB, F)
    acc = jax.lax.dot(onehot, block, precision=jax.lax.Precision.HIGHEST)
    out_ref[...] = out_ref[...] + acc.astype(out_ref.dtype)


def gather_rows(
    storage: jax.Array,
    idx: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """out[i] = storage[idx[i]] for 2D storage (N, F).

    Exact for f32/bf16 payloads and for integer payloads with values
    < 2^24 (one-hot matmul sums are exact in f32).  B and N must be
    multiples of the block sizes (ops.py pads).
    """
    n, f = storage.shape
    b = idx.shape[0]
    assert b % BATCH_BLOCK == 0 and n % STORAGE_BLOCK == 0, (b, n)
    grid = (b // BATCH_BLOCK, n // STORAGE_BLOCK)

    out_dtype = storage.dtype
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BATCH_BLOCK,), lambda i, j: (i,)),
            pl.BlockSpec((STORAGE_BLOCK, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BATCH_BLOCK, f), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f), out_dtype),
        interpret=interpret,
    )(idx, storage)
