"""Pallas TPU kernels: FlashAttention-style fused attention, fwd + bwd (§Perf).

Beyond-paper optimization for the learner's dominant memory term: the
paper-faithful baseline materializes (…, S, S) f32 scores in HBM; these
kernels stream K/V blocks through VMEM with an online-softmax
accumulator, so attention's HBM traffic collapses to Q/K/V/O (+ the
(N, S) logsumexp saved for the backward).

Three kernels (classic FlashAttention-2 decomposition):
  * fwd  — grid (N, S/BQ, S/BK), output block revisited over K; scratch
           m/l/acc in VMEM; emits O and LSE.
  * dq   — grid (N, S/BQ, S/BK), accumulates dQ over K blocks.
  * dkv  — grid (N, S/BK, S/BQ), accumulates dK/dV over Q blocks.

Causal, sliding-window and chunked-local (Llama-4) masks are computed
from global block offsets; fully-masked blocks are skipped.  Tied
together with ``jax.custom_vjp``; validated in interpret mode against
``ref.flash_attention_ref`` (values AND gradients).
"""

from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Dry-run cost modeling (launch/dryrun.py sets this): on CPU the kernels
# run in interpret mode, which lowers to an XLA grid loop whose HBM
# accounting bears no relation to the real TPU custom call.  The stub is
# a shape/dataflow-exact stand-in (reads Q/K/V, writes O; AD reads dO,
# writes dQ/dK/dV) — never *executed*, only lowered; FLOPs are added
# analytically (hlo_analysis.flash_attention_flops).
_STUB = os.environ.get("REPRO_FLASH_STUB") == "1"

BQ = 512
BK = 512
NEG = -1e30


def _block_mask(attention, window, causal, glob, q_pos, k_pos):
    """glob may be a traced scalar (per-layer global-attention flag)."""
    mask = jnp.ones(jnp.broadcast_shapes(q_pos.shape, k_pos.shape), bool)
    if causal:
        mask &= k_pos <= q_pos
    if attention == "sliding":
        mask &= glob | (k_pos > q_pos - window)
    if attention == "chunked":
        mask &= glob | ((k_pos // window) == (q_pos // window))
    return mask


def _block_reachable(attention, window, causal, glob,
                     q_start, bq, k_start, bk):
    q_last = q_start + bq - 1
    k_last = k_start + bk - 1
    reach = jnp.asarray(True)
    if causal:
        reach &= k_start <= q_last
    if attention == "sliding":
        reach &= glob | (k_last > q_start - window)
    if attention == "chunked":
        reach &= glob | (((k_start // window) <= (q_last // window)) & (
            (k_last // window) >= (q_start // window)))
    return reach


# ------------------------------------------------------------------ fwd ----

def _fwd_kernel(attention, window, causal, scale,
                g_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    bq, bk = q_ref.shape[1], k_ref.shape[1]
    q_start = pl.program_id(1) * bq
    k_start = ki * bk

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    glob = g_ref[0] != 0
    @pl.when(_block_reachable(attention, window, causal, glob,
                              q_start, bq, k_start, bk))
    def _block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot(q, k.T) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(_block_mask(attention, window, causal, glob,
                                  q_pos, k_pos), s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        lsum = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / lsum).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(lsum))[:, 0]


def _fwd(q, k, v, glob, attention, window, causal, bq, bk, interpret):
    n, s, hd = q.shape
    sk = k.shape[1]
    bq_, bk_ = min(bq, s), min(bk, sk)
    assert s % bq_ == 0 and sk % bk_ == 0, (s, sk, bq_, bk_)
    scale = 1.0 / math.sqrt(hd)
    grid = (n, s // bq_, sk // bk_)
    o, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, attention, window, causal, scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, i, j: (0,)),
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq_), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, s, hd), q.dtype),
            jax.ShapeDtypeStruct((n, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, hd), jnp.float32),
        ],
        interpret=interpret,
    )(glob, q, k, v)
    return o, lse


# ------------------------------------------------------------------- dq ----

def _dq_kernel(attention, window, causal, scale,
               g_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
               dq_ref, acc_scr):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    bq, bk = q_ref.shape[1], k_ref.shape[1]
    q_start = pl.program_id(1) * bq
    k_start = ki * bk

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    glob = g_ref[0] != 0
    @pl.when(_block_reachable(attention, window, causal, glob,
                              q_start, bq, k_start, bk))
    def _block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0][:, None]
        delta = delta_ref[0][:, None]
        s = jax.lax.dot(q, k.T) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = _block_mask(attention, window, causal, glob, q_pos, k_pos)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        ds = p * (jax.lax.dot(do, v.T) - delta)
        acc_scr[...] = acc_scr[...] + jax.lax.dot(ds, k) * scale

    @pl.when(ki == nk - 1)
    def _finalize():
        dq_ref[0] = acc_scr[...].astype(dq_ref.dtype)


# ------------------------------------------------------------------ dkv ----

def _dkv_kernel(attention, window, causal, scale,
                g_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr):
    qi = pl.program_id(2)
    nq = pl.num_programs(2)
    bk, bq = k_ref.shape[1], q_ref.shape[1]
    k_start = pl.program_id(1) * bk
    q_start = qi * bq

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    glob = g_ref[0] != 0
    @pl.when(_block_reachable(attention, window, causal, glob,
                              q_start, bq, k_start, bk))
    def _block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0][:, None]
        delta = delta_ref[0][:, None]
        s = jax.lax.dot(q, k.T) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = _block_mask(attention, window, causal, glob, q_pos, k_pos)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        dv_scr[...] = dv_scr[...] + jax.lax.dot(p.T, do)
        ds = p * (jax.lax.dot(do, v.T) - delta)
        dk_scr[...] = dk_scr[...] + jax.lax.dot(ds.T, q) * scale

    @pl.when(qi == nq - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd(attention, window, causal, bq, bk, interpret, res, do):
    q, k, v, o, lse, glob = res
    n, s, hd = q.shape
    sk = k.shape[1]
    bq_, bk_ = min(bq, s), min(bk, sk)
    scale = 1.0 / math.sqrt(hd)
    delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), -1)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, attention, window, causal, scale),
        grid=(n, s // bq_, sk // bk_),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i, j: (0,)),
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq_), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq_), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq_, hd), jnp.float32)],
        interpret=interpret,
    )(glob, q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, attention, window, causal, scale),
        grid=(n, sk // bk_, s // bq_),
        in_specs=[
            pl.BlockSpec((1,), lambda b, i, j: (0,)),
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq_), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, bq_), lambda b, i, j: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, hd), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk_, hd), jnp.float32),
            pltpu.VMEM((bk_, hd), jnp.float32),
        ],
        interpret=interpret,
    )(glob, q, k, v, do, lse, delta)
    return dq, dk, dv


# ------------------------------------------------------------- public ----

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _flash(q, k, v, glob, attention, window, causal, bq, bk, interpret):
    o, _ = _fwd(q, k, v, glob, attention, window, causal, bq, bk, interpret)
    return o


def _vjp_fwd(q, k, v, glob, attention, window, causal, bq, bk, interpret):
    o, lse = _fwd(q, k, v, glob, attention, window, causal, bq, bk, interpret)
    return o, (q, k, v, o, lse, glob)


def _vjp_bwd(attention, window, causal, bq, bk, interpret, res, do):
    dq, dk, dv = _bwd(attention, window, causal, bq, bk, interpret, res, do)
    return dq, dk, dv, None


_flash.defvjp(_vjp_fwd, _vjp_bwd)


def flash_attention_nhsd(q, k, v, attention="full", window=0, causal=True,
                         is_global=True, bq=BQ, bk=BK, interpret=False):
    """Fused attention on (N, S, hd) tensors (N = batch·heads).

    ``is_global`` may be a python bool or a traced scalar (per-layer
    global-attention flag from a scanned layer stack)."""
    if _STUB:
        eps = jnp.asarray(1e-12, q.dtype)
        return q + eps * k + eps * v   # dataflow-exact dry-run stand-in
    glob = jnp.asarray([is_global], jnp.int32) if not isinstance(
        is_global, jax.Array) else is_global.reshape(1).astype(jnp.int32)
    return _flash(q, k, v, glob, attention, window, causal, bq, bk, interpret)
