"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

These are the *semantic* references: ``core.sumtree`` is itself the
reference implementation of the tree algorithms, so the tree oracles
delegate to it on the flat-array layout; the gather oracle is a plain
take.  Kernels must match these bit-for-bit up to f32 accumulation
ordering (tests assert allclose with tight tolerances, and exact
index equality for sampling away from fp cutoff ties).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sumtree
from repro.core.sumtree import SumTreeSpec


def sumtree_sample_ref(spec: SumTreeSpec, tree: jax.Array, u: jax.Array):
    return sumtree.sample(spec, tree, u)


def sumtree_update_ref(spec: SumTreeSpec, tree: jax.Array, idx, values):
    return sumtree.update(spec, tree, idx, values)


def gather_rows_ref(storage: jax.Array, idx: jax.Array) -> jax.Array:
    return storage[idx]


def flash_attention_ref(q, k, v, attention="full", window=0, causal=True,
                        is_global=True):
    """Naive (N, S, hd) attention oracle for the flash kernels."""
    import math

    n, s, hd = q.shape
    sk = k.shape[1]
    scores = jnp.einsum("nqd,nkd->nqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((s, sk), bool)
    if causal:
        mask &= kp <= qp
    if attention == "sliding" and not is_global:
        mask &= kp > qp - window
    if attention == "chunked" and not is_global:
        mask &= (kp // window) == (qp // window)
    scores = jnp.where(mask[None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("nqk,nkd->nqd", w, v.astype(jnp.float32)).astype(q.dtype)
