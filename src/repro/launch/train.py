"""Distributed RL training driver (deliverable b/e): the paper's full
pipeline — parallel actors (token MDP), sharded prioritized replay,
parallel learners with the token-Q update — on an arbitrary mesh, with
checkpoint/restart.

On this host it runs real steps with a reduced config:
    PYTHONPATH=src python -m repro.launch.train --arch granite_8b --smoke \
        --steps 50
On a pod, drop --smoke and point --mesh at the production topology
(16x16 or 2x16x16); the same code path lowers — the dry-run proves it
compiles for every assigned arch.

``--plan BENCH_plan.json`` applies a DSE-planner config
(runtime/planner.py, DESIGN.md §8): the planned actor-lane count
becomes ``--n-envs``, the planned device count is forced before jax
initializes, and the planned (pod×)data mesh is installed as the
ambient mesh (``launch.mesh.mesh_from_plan``).  The RL-executor-level
instantiation of a plan lives in ``runtime.executors.
executor_from_plan`` (see examples/quickstart.py --plan).
"""

import argparse
import contextlib
import functools
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-envs", type=int, default=16)
    ap.add_argument("--mesh", default="host",
                    help="'host' | '16x16' | '2x16x16' (pods need the "
                         "512-device dry-run env)")
    ap.add_argument("--plan", default=None, metavar="BENCH_plan.json",
                    help="apply a runtime.planner plan: planned n_envs, "
                         "forced device count and ambient (pod×)data "
                         "mesh (overrides --n-envs; --mesh must stay "
                         "'host')")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    plan = None
    if args.plan:
        if args.mesh != "host":
            ap.error("--plan carries its own mesh — drop --mesh")
        # jax-free load: the forced device count must precede jax init
        from repro.runtime.planner import load_plan

        plan = load_plan(args.plan)
        args.n_envs = plan.n_envs
        print(f"plan: {plan.describe()}")
        if plan.n_devices > 1:
            import os
            os.environ["XLA_FLAGS"] = (
                f"{os.environ.get('XLA_FLAGS', '')} "
                "--xla_force_host_platform_device_count="
                f"{plan.n_devices}").strip()

    if args.mesh != "host":
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

    import jax
    import jax.numpy as jnp

    from repro.agents import token_dqn
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config
    from repro.core.replay import PrioritizedReplay, ReplayConfig
    from repro.envs.token_mdp import TokenMDPSpec, make
    from repro.launch.mesh import (make_production_mesh, mesh_from_plan,
                                   sharding_config, use_mesh)
    from repro.models import backbone
    from repro.models.config import NO_SHARDING
    from repro.optim import adam

    cfg = get_config(args.arch, smoke=args.smoke)
    if plan is not None:
        # the planned (pod×)data mesh becomes the ambient mesh; the
        # token model itself stays unsharded (NO_SHARDING) — the plan's
        # mesh carries the actor/learner data axes, not tensor parallel
        shd = NO_SHARDING
        mesh = mesh_from_plan(plan)
    elif args.mesh == "host":
        shd = NO_SHARDING
        mesh = None
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "2x16x16")
        shd = sharding_config(args.mesh == "2x16x16")

    tcfg = token_dqn.TokenDQNConfig(gamma=0.9, accum=1,
                                    opt=adam.AdamConfig(lr=1e-4))
    key = jax.random.PRNGKey(0)
    state = token_dqn.init_train_state(cfg, tcfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    mesh_desc = (f"plan:{plan.n_pods}x{plan.n_data}" if plan is not None
                 else args.mesh)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={mesh_desc}")

    mdp = TokenMDPSpec(vocab=cfg.vocab_size)
    reset, step_env, optimal = make(mdp, jax.random.fold_in(key, 1), args.n_envs)
    env_state, obs = reset(jax.random.fold_in(key, 2))

    example = {
        "tokens": jnp.zeros((args.seq,), jnp.int32),
        "actions": jnp.zeros((args.seq,), jnp.int32),
        "rewards": jnp.zeros((args.seq,), jnp.float32),
        "dones": jnp.zeros((args.seq,), jnp.float32),
    }
    replay = PrioritizedReplay(ReplayConfig(capacity=8192, fanout=128), example)
    rst = replay.init()

    @jax.jit
    def collect(params, env_state, obs, key):
        def one(carry, i):
            env_state, obs, ctx = carry
            k = jax.random.fold_in(key, i)
            logits = backbone.forward(cfg, shd, params, ctx)[:, -1]
            greedy = jnp.argmax(logits, -1)
            rand = jax.random.randint(k, greedy.shape, 0, cfg.vocab_size)
            act = jnp.where(jax.random.uniform(k, greedy.shape) < 0.1,
                            rand, greedy)
            env_state2, obs2, rew, done = step_env(env_state, act, k)
            ctx2 = jnp.concatenate([ctx[:, 1:], obs2[:, None]], axis=1)
            return (env_state2, obs2, ctx2), (obs, act, rew, done)

        ctx0 = jnp.tile(obs[:, None], (1, 8))
        (env_state, obs, _), (toks, acts, rews, dones) = jax.lax.scan(
            one, (env_state, obs, ctx0), jnp.arange(args.seq))
        return env_state, obs, {
            "tokens": toks.T, "actions": acts.T,
            "rewards": rews.T, "dones": dones.T.astype(jnp.float32)}

    train_step = jax.jit(functools.partial(token_dqn.train_step, cfg, shd, tcfg),
                         donate_argnums=(0,))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start, state = mgr.restore_latest(state)
    if start is not None:
        print(f"resumed from step {start} (fault-tolerant restart)")

    stack = contextlib.ExitStack()
    if plan is not None and mesh is not None:
        # planned data mesh as the ambient mesh for the training steps
        stack.enter_context(use_mesh(mesh))

    ctx = None
    t0 = time.time()
    for it in range(int(state.step), args.steps):
        key, kc, ks = jax.random.split(key, 3)
        env_state, obs, seg = collect(state.params, env_state, obs, kc)
        rst = replay.insert(rst, seg)
        idx, items, w = replay.sample(rst, ks, args.batch)
        state, metrics, tds = train_step(state, dict(items, is_weights=w))
        rst = replay.update_priorities(rst, idx, tds)
        if it % 10 == 0:
            print(f"step {it:4d} loss {float(metrics['loss']):.4f} "
                  f"reward {float(jnp.mean(seg['rewards'])):.3f} "
                  f"(optimal {optimal():.3f})")
        if args.ckpt_every and it and it % args.ckpt_every == 0:
            mgr.save_async(it, state)
    mgr.wait()
    mgr.save(args.steps, state)
    stack.close()
    print(f"trained {args.steps - (start or 0)} steps in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
