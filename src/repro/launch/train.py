"""Distributed RL training driver (deliverable b/e): the paper's full
pipeline — parallel actors (token MDP), sharded prioritized replay,
parallel learners with the token-Q update — on an arbitrary mesh, with
checkpoint/restart.

On this host it runs real steps with a reduced config:
    PYTHONPATH=src python -m repro.launch.train --arch granite_8b --smoke \
        --steps 50
On a pod, drop --smoke and point --mesh at the production topology
(16x16 or 2x16x16); the same code path lowers — the dry-run proves it
compiles for every assigned arch.

``--plan BENCH_plan.json`` applies a DSE-planner config
(runtime/planner.py, DESIGN.md §8): the planned actor-lane count
becomes ``--n-envs``, the planned device count is forced before jax
initializes, and the planned (pod×)data mesh is installed as the
ambient mesh (``launch.mesh.mesh_from_plan``).  The RL-executor-level
instantiation of a plan lives in ``runtime.executors.
executor_from_plan`` (see examples/quickstart.py --plan).

``--wall-clock N`` (DESIGN.md §10) re-launches this driver as N real
worker processes through ``launch.multiprocess``: the parent spawns the
gang (fresh XLA client per worker, gloo collectives) and each worker
joins the multi-controller runtime via
``core.distributed.initialize_distributed`` before its first jax call.
Workers split ``--n-envs`` evenly, run the same training body on their
own actor streams, and data-parallel-average the parameters across the
gang after every train step — a real device→host→wire→device round
trip, not an in-program copy.  Process 0 owns printing and checkpoints.
Incompatible with ``--plan``/``--mesh`` (those emulate topology inside
one process — the opposite of this mode).
"""

import argparse
import contextlib
import functools
import os
import sys
import time


def _make_param_averager(n_procs: int):
    """Cross-process parameter mean for the wall-clock gang: each worker
    contributes its local params as one slot of a leading-proc-axis
    global array, a shard_map pmean over the ``("proc",)`` mesh reduces
    them over the wire, and the replicated result is pulled back to the
    worker's local device — so the published params really crossed
    device→host→gloo→device, not an XLA alias."""
    import jax
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    mesh = Mesh(np.asarray(jax.devices()).reshape(n_procs), ("proc",))
    local_dev = jax.local_devices()[0]

    def pmean(tree):
        # local view of each stacked leaf is this worker's (1, …) slot;
        # drop it so the replicated output has the original leaf shape
        # repro-lint: disable=C202(local one-axis gang mesh, not the pod/data/model training mesh)
        return jax.tree.map(lambda x: jax.lax.pmean(x[0], "proc"), tree)

    reduce_fn = jax.jit(shard_map(
        pmean, mesh=mesh, in_specs=PartitionSpec("proc"),
        out_specs=PartitionSpec(), check_rep=False))

    def to_global(leaf):
        shape = (n_procs,) + leaf.shape
        sharding = NamedSharding(mesh, PartitionSpec("proc"))
        local = jax.device_put(leaf[None], local_dev)
        return jax.make_array_from_single_device_arrays(
            shape, sharding, [local])

    def sync(params):
        stacked = jax.tree.map(to_global, params)
        mean = reduce_fn(stacked)
        host = jax.device_get(mean)   # fully replicated → addressable
        return jax.tree.map(lambda x: jax.device_put(x, local_dev), host)

    return sync


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-envs", type=int, default=16)
    ap.add_argument("--mesh", default="host",
                    help="'host' | '16x16' | '2x16x16' (pods need the "
                         "512-device dry-run env)")
    ap.add_argument("--plan", default=None, metavar="BENCH_plan.json",
                    help="apply a runtime.planner plan: planned n_envs, "
                         "forced device count and ambient (pod×)data "
                         "mesh (overrides --n-envs; --mesh must stay "
                         "'host')")
    ap.add_argument("--wall-clock", type=int, default=0, metavar="N",
                    help="launch N real worker processes (multi-"
                         "controller SPMD over gloo) instead of the "
                         "in-process run; params are data-parallel-"
                         "averaged across the gang every step")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    wc_coord = os.environ.get("REPRO_WC_COORD")
    if args.wall_clock and args.wall_clock > 1 and wc_coord is None:
        # parent: spawn the gang re-running this driver, worker env
        # (XLA_FLAGS / PYTHONPATH / coordinator) set per child
        if args.plan or args.mesh != "host":
            ap.error("--wall-clock spawns real processes — drop "
                     "--plan/--mesh (those emulate topology in-process)")
        from repro.launch import multiprocess as mp

        n = args.wall_clock
        coordinator = f"127.0.0.1:{mp.free_port()}"
        argv = list(sys.argv[1:])
        i = argv.index("--wall-clock")
        del argv[i:i + 2]
        env = mp.worker_env(devices_per_proc=1)
        env["REPRO_WC_COORD"] = coordinator
        env["REPRO_WC_NPROCS"] = str(n)
        import subprocess
        procs = []
        for pid in range(n):
            cenv = dict(env, REPRO_WC_PID=str(pid))
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.launch.train", *argv],
                env=cenv, stdout=subprocess.PIPE,
                stderr=subprocess.STDOUT, text=True))
        rc = 0
        for pid, p in enumerate(procs):
            out, _ = p.communicate()
            for line in out.splitlines():
                print(f"[worker {pid}] {line}")
            rc = rc or p.returncode
        if rc:
            raise SystemExit(rc)
        return

    if wc_coord is not None:
        # worker: join the gang before the first jax call
        from repro.core.distributed import initialize_distributed

        wc_nprocs = int(os.environ["REPRO_WC_NPROCS"])
        wc_pid = int(os.environ["REPRO_WC_PID"])
        initialize_distributed(wc_coord, wc_nprocs, wc_pid)
        if args.n_envs % wc_nprocs:
            ap.error(f"--n-envs {args.n_envs} not divisible by the "
                     f"{wc_nprocs}-process gang")
        args.n_envs //= wc_nprocs
    else:
        wc_nprocs, wc_pid = 1, 0

    plan = None
    if args.plan:
        if args.mesh != "host":
            ap.error("--plan carries its own mesh — drop --mesh")
        # jax-free load: the forced device count must precede jax init
        from repro.runtime.planner import load_plan

        plan = load_plan(args.plan)
        args.n_envs = plan.n_envs
        print(f"plan: {plan.describe()}")
        if plan.n_devices > 1:
            os.environ["XLA_FLAGS"] = (
                f"{os.environ.get('XLA_FLAGS', '')} "
                "--xla_force_host_platform_device_count="
                f"{plan.n_devices}").strip()

    if args.mesh != "host":
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

    import jax
    import jax.numpy as jnp

    from repro.agents import token_dqn
    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config
    from repro.core.replay import PrioritizedReplay, ReplayConfig
    from repro.envs.token_mdp import TokenMDPSpec, make
    from repro.launch.mesh import (make_production_mesh, mesh_from_plan,
                                   sharding_config, use_mesh)
    from repro.models import backbone
    from repro.models.config import NO_SHARDING
    from repro.optim import adam

    cfg = get_config(args.arch, smoke=args.smoke)
    if plan is not None:
        # the planned (pod×)data mesh becomes the ambient mesh; the
        # token model itself stays unsharded (NO_SHARDING) — the plan's
        # mesh carries the actor/learner data axes, not tensor parallel
        shd = NO_SHARDING
        mesh = mesh_from_plan(plan)
    elif args.mesh == "host":
        shd = NO_SHARDING
        mesh = None
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "2x16x16")
        shd = sharding_config(args.mesh == "2x16x16")

    tcfg = token_dqn.TokenDQNConfig(gamma=0.9, accum=1,
                                    opt=adam.AdamConfig(lr=1e-4))
    key = jax.random.PRNGKey(0)
    state = token_dqn.init_train_state(cfg, tcfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    mesh_desc = (f"plan:{plan.n_pods}x{plan.n_data}" if plan is not None
                 else args.mesh)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mesh={mesh_desc}")

    mdp = TokenMDPSpec(vocab=cfg.vocab_size)
    reset, step_env, optimal = make(mdp, jax.random.fold_in(key, 1), args.n_envs)
    # per-worker actor streams: decorrelated resets, replicated params
    env_state, obs = reset(jax.random.fold_in(jax.random.fold_in(key, 2),
                                              wc_pid))

    sync_params = None
    if wc_nprocs > 1:
        sync_params = _make_param_averager(wc_nprocs)

    example = {
        "tokens": jnp.zeros((args.seq,), jnp.int32),
        "actions": jnp.zeros((args.seq,), jnp.int32),
        "rewards": jnp.zeros((args.seq,), jnp.float32),
        "dones": jnp.zeros((args.seq,), jnp.float32),
    }
    replay = PrioritizedReplay(ReplayConfig(capacity=8192, fanout=128), example)
    rst = replay.init()

    @jax.jit
    def collect(params, env_state, obs, key):
        def one(carry, i):
            env_state, obs, ctx = carry
            k = jax.random.fold_in(key, i)
            logits = backbone.forward(cfg, shd, params, ctx)[:, -1]
            greedy = jnp.argmax(logits, -1)
            rand = jax.random.randint(k, greedy.shape, 0, cfg.vocab_size)
            act = jnp.where(jax.random.uniform(k, greedy.shape) < 0.1,
                            rand, greedy)
            env_state2, obs2, rew, done = step_env(env_state, act, k)
            ctx2 = jnp.concatenate([ctx[:, 1:], obs2[:, None]], axis=1)
            return (env_state2, obs2, ctx2), (obs, act, rew, done)

        ctx0 = jnp.tile(obs[:, None], (1, 8))
        (env_state, obs, _), (toks, acts, rews, dones) = jax.lax.scan(
            one, (env_state, obs, ctx0), jnp.arange(args.seq))
        return env_state, obs, {
            "tokens": toks.T, "actions": acts.T,
            "rewards": rews.T, "dones": dones.T.astype(jnp.float32)}

    train_step = jax.jit(functools.partial(token_dqn.train_step, cfg, shd, tcfg),
                         donate_argnums=(0,))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start, state = mgr.restore_latest(state)
    if start is not None:
        print(f"resumed from step {start} (fault-tolerant restart)")

    stack = contextlib.ExitStack()
    if plan is not None and mesh is not None:
        # planned data mesh as the ambient mesh for the training steps
        stack.enter_context(use_mesh(mesh))

    ctx = None
    t0 = time.time()
    for it in range(int(state.step), args.steps):
        key, kc, ks = jax.random.split(key, 3)
        env_state, obs, seg = collect(state.params, env_state, obs, kc)
        rst = replay.insert(rst, seg)
        idx, items, w = replay.sample(rst, ks, args.batch)
        state, metrics, tds = train_step(state, dict(items, is_weights=w))
        rst = replay.update_priorities(rst, idx, tds)
        if sync_params is not None:
            # wall-clock gang: data-parallel parameter average across
            # processes — a real D2H → gloo → H2D round trip per step
            state = state._replace(params=sync_params(state.params))
        if wc_pid == 0 and it % 10 == 0:
            print(f"step {it:4d} loss {float(metrics['loss']):.4f} "
                  f"reward {float(jnp.mean(seg['rewards'])):.3f} "
                  f"(optimal {optimal():.3f})")
        if (args.ckpt_every and it and it % args.ckpt_every == 0
                and wc_pid == 0):
            mgr.save_async(it, state)
    mgr.wait()
    if wc_pid == 0:
        mgr.save(args.steps, state)
    stack.close()
    if wc_pid == 0:
        print(f"trained {args.steps - (start or 0)} steps in "
              f"{time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
