import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_FLASH_STUB"] = "1"

"""Per-op profile of a dry-run cell: top HBM-bytes ops and top collectives
(trip-count multiplied) from the compiled partitioned HLO — the CPU-only
stand-in for a TPU profile (§Perf methodology).

    PYTHONPATH=src python -m repro.launch.analyze --arch qwen1_5_32b --shape train_4k
"""

import argparse
import collections
import re
from typing import Dict, List, Tuple

from repro.launch import hlo_analysis as HA


def op_profile(hlo: str, top: int = 25):
    blocks, entry = HA._split_computations(hlo)
    mult_exec, mult_all = HA._multipliers(blocks, entry)

    byte_rows: List[Tuple[float, str, str]] = []
    coll_rows: List[Tuple[float, str, str]] = []
    for name, text in blocks.items():
        me = mult_exec.get(name, 0.0)
        ma = mult_all.get(name, 0.0)
        symbols: Dict[str, float] = {}
        for line in text.splitlines():
            lm = HA._OPLINE_RE.match(line)
            if not lm:
                continue
            out_name, rhs = lm.group(1), lm.group(2)
            out_bytes, opcode, operands = HA._parse_rhs(rhs)
            symbols[out_name] = out_bytes
            meta = re.search(r'op_name="([^"]+)"', line)
            label = (meta.group(1)[-90:] if meta else out_name)
            base = opcode.replace("-start", "").replace("-done", "")
            if ma > 0:
                got = HA._line_collective(line)
                if got is not None:
                    op, b, n, w = got
                    coll_rows.append((w * ma, f"{op}(g={n})", label))
            if me <= 0 or base in HA._SKIP_OPS or not opcode:
                continue
            op_bytes = sum(symbols.get(o, 0.0) for o in operands) + out_bytes
            if base == "fusion":
                cm = HA._CALLS_NAME_RE.search(rhs)
                if cm:
                    ft = HA._fusion_traffic(blocks.get(cm.group(1).lstrip("%"), ""))
                    if ft is not None:
                        op_bytes = ft
            elif base == "dynamic-update-slice" and len(operands) >= 2:
                op_bytes = 2.0 * symbols.get(operands[1], 0.0)
            elif base in ("dynamic-slice", "gather"):
                op_bytes = 2.0 * out_bytes
            byte_rows.append((op_bytes * me, f"{base}×{me:g}", label))

    byte_rows.sort(reverse=True)
    coll_rows.sort(reverse=True)
    total_b = sum(r[0] for r in byte_rows)
    total_c = sum(r[0] for r in coll_rows)
    print(f"\n== HBM bytes/device: {total_b/1e9:.1f} GB "
          f"(t_mem={total_b/HA.HBM_BW:.2f}s) — top {top} ops ==")
    for b, op, label in byte_rows[:top]:
        print(f"  {b/1e9:9.2f} GB  {op:<28} {label}")
    print(f"\n== collective wire bytes/device: {total_c/1e9:.1f} GB "
          f"(t_coll={total_c/HA.ICI_BW:.2f}s) — top {top} ==")
    for b, op, label in coll_rows[:top]:
        print(f"  {b/1e9:9.2f} GB  {op:<24} {label}")

    # aggregate by op kind
    agg = collections.Counter()
    for b, op, _ in byte_rows:
        agg[op.split("×")[0]] += b
    print("\n== bytes by op kind ==")
    for k, v in agg.most_common(12):
        print(f"  {v/1e9:9.2f} GB  {k}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()
    from repro.launch.dryrun import build_cell
    lower_fn, info = build_cell(args.arch, args.shape, args.multi_pod)
    print("cell info:", {k: v for k, v in info.items() if k != "skipped"})
    compiled = lower_fn().compile()
    op_profile(compiled.as_text(), args.top)


if __name__ == "__main__":
    main()
