"""Production mesh construction (never touches jax device state at import).

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the pod axis
is the slow inter-pod interconnect; gradients crossing it may use the
int8 error-feedback compressed reduce (optim/compress.py)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from repro.models.config import ShardingConfig


def use_mesh(mesh):
    """Version-compatible "make this the ambient mesh" context manager.

    JAX has renamed this three times: ``jax.sharding.use_mesh`` (0.5.x),
    ``jax.set_mesh`` (0.6+), and on older releases the ``Mesh`` object is
    itself the context manager.  Callers write ``with use_mesh(m):``
    regardless of the installed version.
    """
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def data_mesh(n_shards: Optional[int] = None, axis: str = "data"):
    """1-D mesh over ``n_shards`` devices for the sharded replay/learner
    data path (defaults to all visible devices)."""
    devices = jax.devices()
    n = n_shards or len(devices)
    if len(devices) < n:
        raise RuntimeError(
            f"data mesh needs {n} devices, found {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before any "
            "jax import to force host-platform shards.")
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(n), (axis,))


def pod_data_mesh(n_pods: int, n_data: int, axes: Tuple[str, str] = ("pod", "data")):
    """2-D ``(pod, data)`` mesh for the two-axis sharded executor.

    The first (outer) axis is the slow inter-pod interconnect — the one
    the int8 error-feedback compressed reduce crosses
    (``ShardedExecutor(compress_pod_reduce=True)``); the second is the
    fast intra-pod data axis where gradients reduce in f32.  Device
    order is row-major pod-major, matching the executor's flattened
    shard ids, so a ``pod_data_mesh(P, 1)`` run reproduces a
    ``data_mesh(P)`` run exactly from the same seed.
    """
    if n_pods < 1 or n_data < 1:
        raise ValueError(f"pod_data_mesh({n_pods}, {n_data}): both axis "
                         "extents must be ≥ 1")
    n = n_pods * n_data
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"pod×data mesh ({n_pods}, {n_data}) needs {n} devices, found "
            f"{len(devices)} — set XLA_FLAGS="
            "--xla_force_host_platform_device_count before any jax import "
            "to force host-platform shards.")
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(n_pods, n_data), axes)


def mesh_from_plan(plan):
    """Mesh for a planner-selected runtime config
    (``runtime.planner.PlannedConfig``, duck-typed on
    ``n_pods``/``n_data``): ``None`` for the fused program, a 1-D data
    mesh for single-pod sharding, the two-axis (pod, data) mesh when the
    plan crosses pods.  The caller must have forced
    ``plan.n_devices`` host devices before the first jax call —
    quickstart's ``--plan`` path does."""
    if not plan.n_data:
        return None
    if plan.n_pods > 1:
        return pod_data_mesh(plan.n_pods, plan.n_data)
    return data_mesh(plan.n_data)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import (launch/dryrun.py does)."
        )
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def sharding_config(multi_pod: bool = False) -> ShardingConfig:
    return ShardingConfig(
        fsdp=("pod", "data") if multi_pod else ("data",),
        tp="model",
        tp_extent=16,
        dp_extent=32 if multi_pod else 16,
    )


def small_mesh(n_data: Optional[int] = None, n_model: int = 1):
    """Host-size mesh for tests/examples (uses however many devices exist)."""
    devs = jax.devices()
    n_data = n_data or (len(devs) // n_model)
    dev_array = np.asarray(devs[: n_data * n_model]).reshape(n_data, n_model)
    return jax.sharding.Mesh(dev_array, ("data", "model"))
