"""Roofline-term extraction from compiled dry-run artifacts (§Roofline).

compute  = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
memory   = HLO_bytes / (chips × 819 GB/s HBM)
collective = wire_bytes / (chips × 50 GB/s/link ICI)

``cost_analysis()`` on the *partitioned* module reports per-device FLOPs
and bytes; collective wire bytes are parsed from the compiled HLO text:
per-device ring-algorithm traffic factors

    all-gather       (n-1)/n × out_bytes
    reduce-scatter   (n-1)   × out_bytes        (= (n-1)/n × in)
    all-reduce       2(n-1)/n × bytes
    all-to-all       (n-1)/n × bytes
    collective-permute  1 × bytes

with n = collective group size parsed from replica_groups (both the
explicit {{...}} and the iota [a,b]<=[N] formats).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link; per-axis-hop budget (documented)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<dtype>\w+)\[(?P<shape>[\d,]*)\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_TUPLE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, float]
    wire_bytes: float                 # per device, ring-factored, ×trip counts
    raw_bytes: Dict[str, float]       # per op kind, unfactored output bytes
    details: List[Tuple[str, float, int]]  # (op, bytes, group size)


def _shape_bytes(dtype: str, shape: str) -> float:
    n = 1
    if shape.strip():
        for d in shape.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_collective(line: str):
    m = _COLL_RE.search(line)
    if not m:
        return None
    op = m.group("op")
    # shapes strictly between '=' and the opcode occurrence that matched
    eq = line.find("=")
    lhs = line[eq: m.start("op")] if eq >= 0 else line[: m.start("op")]
    bytes_out = sum(_shape_bytes(d, s) for d, s in _TUPLE_RE.findall(lhs))
    if bytes_out == 0:
        return None
    gm = _GROUPS_BRACE_RE.search(line)
    if gm:
        n = len([t for t in gm.group(1).split(",") if t.strip() != ""])
    else:
        gi = _GROUPS_IOTA_RE.search(line)
        n = int(gi.group(2)) if gi else 2
    n = max(n, 2)
    if op == "all-gather":
        w = bytes_out * (n - 1) / n
    elif op == "reduce-scatter":
        w = bytes_out * (n - 1)
    elif op == "all-reduce":
        w = 2 * bytes_out * (n - 1) / n
    elif op == "all-to-all":
        w = bytes_out * (n - 1) / n
    else:  # collective-permute
        w = bytes_out
    return op, bytes_out, n, w


# --- computation-graph walk: multiply collectives inside while bodies by
# their static trip counts (XLA cost/ text views count loop bodies ONCE;
# see EXPERIMENTS.md §Methodology) ---------------------------------------

_BLOCK_HDR_RE = re.compile(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*\(.*?\)\s*->", re.M)
_WHILE_CALL_RE = re.compile(
    r"while\((?:[^)]*)\), condition=([%\w.\-]+), body=([%\w.\-]+)")
_SUBCALL_RE = re.compile(r"(?:calls=|to_apply=)(%?[\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r"s(?:32|64)\[\] constant\((\d+)\)")


def _split_computations(hlo: str):
    headers = [(m.start(), m.group(2).lstrip("%"), bool(m.group(1)))
               for m in _BLOCK_HDR_RE.finditer(hlo)]
    blocks, entry = {}, None
    for i, (pos, name, is_entry) in enumerate(headers):
        end = headers[i + 1][0] if i + 1 < len(headers) else len(hlo)
        blocks[name] = hlo[pos:end]
        if is_entry:
            entry = name
    return blocks, entry


def _trip_count(cond_text: str) -> int:
    vals = [int(v) for v in _TRIP_RE.findall(cond_text)]
    return max(vals) if vals else 1     # dynamic bound → conservative ×1


def _multipliers(blocks, entry):
    """Execution multipliers per computation.

    Returns (mult_exec, mult_all): exec counts only while-body/branch/entry
    reachability (HBM-visible computations — fusion bodies excluded);
    'all' additionally descends calls=/to_apply= (for collectives)."""
    mult_all: Dict[str, float] = {entry: 1.0}
    mult_exec: Dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = set()
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        text = blocks.get(name, "")
        ma = mult_all.get(name, 0.0)
        me = mult_exec.get(name, 0.0)

        def add(child, factor, execu):
            key = (name, child, factor, execu)
            if key in seen or child not in blocks:
                return
            seen.add(key)
            mult_all[child] = mult_all.get(child, 0.0) + ma * factor
            if execu:
                mult_exec[child] = mult_exec.get(child, 0.0) + me * factor
            if child not in order:
                order.append(child)

        for cm in _WHILE_CALL_RE.finditer(text):
            cond = cm.group(1).lstrip("%").rstrip(",")
            body = cm.group(2).lstrip("%").rstrip(",")
            trip = float(_trip_count(blocks.get(cond, "")))
            add(cond, 1.0, True)
            add(body, trip, True)
        for cm in _BRANCHES_RE.finditer(text):
            for child in cm.group(1).split(","):
                add(child.strip().lstrip("%"), 1.0, True)
        for cm in _SUBCALL_RE.finditer(text):
            add(cm.group(1).lstrip("%"), 1.0, False)
    return mult_exec, mult_all


def parse_collectives(hlo: str) -> CollectiveStats:
    blocks, entry = _split_computations(hlo)
    if entry is None:                   # fallback: flat scan, no multipliers
        blocks, entry = {"__all__": hlo}, "__all__"
    _, mult_all = _multipliers(blocks, entry)

    counts: Dict[str, float] = {}
    raw: Dict[str, float] = {}
    wire = 0.0
    details: List[Tuple[str, float, int]] = []
    for name, text in blocks.items():
        m = mult_all.get(name, 0.0)
        if m <= 0:
            continue
        for line in text.splitlines():
            got = _line_collective(line)
            if got is None:
                continue
            op, bytes_out, n, w = got
            counts[op] = counts.get(op, 0.0) + m
            raw[op] = raw.get(op, 0.0) + bytes_out * m
            wire += w * m
            details.append((op, bytes_out * m, n))
    return CollectiveStats(counts, wire, raw, details)


# --- HBM-traffic estimate from the fused, partitioned HLO ------------------

_OPLINE_RE = re.compile(r"^\s+(?:ROOT\s+)?(%[\w.\-]+) = (.*)$")
_SKIP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier",
}


def _parse_rhs(rhs: str):
    """rhs = 'TYPE opcode(args), attrs' → (out_bytes, opcode, operand names)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, c in enumerate(rhs):
            depth += c == "("
            depth -= c == ")"
            if depth == 0:
                break
        typ, rest = rhs[: i + 1], rhs[i + 1:].strip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return 0.0, "", []
        typ, rest = rhs[:sp], rhs[sp + 1:].strip()
    m = re.match(r"([\w\-]+)\((.*)$", rest)
    if not m:
        return 0.0, "", []
    opcode = m.group(1)
    args = m.group(2).split(")")[0]
    operands = [a.strip() for a in args.split(",") if a.strip().startswith("%")]
    out_bytes = sum(_shape_bytes(d, s) for d, s in _TUPLE_RE.findall(typ))
    return out_bytes, opcode, operands


_PARAM_RE = re.compile(r"parameter\((\d+)\)")
_CALLS_NAME_RE = re.compile(r"calls=(%?[\w.\-]+)")


def _fusion_traffic(comp_text: str) -> Optional[float]:
    """HBM traffic of one fusion from its fused computation body.

    A fusion reads each *parameter* and writes its root — EXCEPT:
      * a parameter consumed only by dynamic-slice ops is read at slice
        granularity (the scan-over-layers stacked-weights pattern);
      * a dynamic-update-slice root writes (and reads) only the update
        region; the big aliased buffer costs nothing.
    Returns None if the body can't be parsed.
    """
    sym: Dict[str, float] = {}
    params: Dict[str, float] = {}
    uses: Dict[str, List[Tuple[str, float]]] = {}
    root = None
    for line in comp_text.splitlines():
        lm = _OPLINE_RE.match(line)
        if not lm:
            continue
        out_name, rhs = lm.group(1), lm.group(2)
        out_bytes, opcode, operands = _parse_rhs(rhs)
        sym[out_name] = out_bytes
        if _PARAM_RE.search(rhs):
            params[out_name] = out_bytes
        for o in operands:
            uses.setdefault(o, []).append((opcode, out_bytes))
        if " ROOT " in line or line.lstrip().startswith("ROOT"):
            root = (opcode, operands, out_bytes)
    if root is None:
        return None
    total = 0.0
    root_opcode, root_operands, root_bytes = root
    inplace_target = (root_operands[0] if root_opcode == "dynamic-update-slice"
                      and root_operands else None)
    for pname, pbytes in params.items():
        u = uses.get(pname, [])
        if pname == inplace_target:
            continue                       # aliased in-place buffer
        if u and all(op == "dynamic-slice" for op, _ in u):
            total += sum(b for _, b in u)  # sliced reads only
        else:
            total += pbytes
    if root_opcode == "dynamic-update-slice" and len(root_operands) >= 2:
        total += 2.0 * sym.get(root_operands[1], root_bytes)
    else:
        total += root_bytes
    return total


def hbm_bytes_per_device(hlo: str) -> float:
    """Σ over HBM-visible ops of (operand + output bytes) × trip multiplier.

    Post-fusion accounting: only ops at computation top level touch HBM.
    Fusions are analysed through their fused computation (slice-granular
    parameter reads, in-place dus roots — see _fusion_traffic); top-level
    in-place/gather ops are special-cased the same way.
    """
    blocks, entry = _split_computations(hlo)
    if entry is None:
        return 0.0
    mult_exec, _ = _multipliers(blocks, entry)

    total = 0.0
    for name, text in blocks.items():
        m = mult_exec.get(name, 0.0)
        if m <= 0:
            continue
        symbols: Dict[str, float] = {}
        comp_bytes = 0.0
        for line in text.splitlines():
            lm = _OPLINE_RE.match(line)
            if not lm:
                continue
            out_name, rhs = lm.group(1), lm.group(2)
            out_bytes, opcode, operands = _parse_rhs(rhs)
            symbols[out_name] = out_bytes
            base = opcode.replace("-start", "").replace("-done", "")
            if base in _SKIP_OPS or not opcode:
                continue
            op_bytes = sum(symbols.get(o, 0.0) for o in operands) + out_bytes
            if base == "fusion":
                cm = _CALLS_NAME_RE.search(rhs)
                if cm:
                    ft = _fusion_traffic(blocks.get(cm.group(1).lstrip("%"), ""))
                    if ft is not None:
                        op_bytes = ft
            elif base == "dynamic-update-slice" and len(operands) >= 2:
                op_bytes = 2.0 * symbols.get(operands[1], 0.0)
            elif base in ("dynamic-slice", "gather"):
                op_bytes = 2.0 * out_bytes
            elif base == "scatter" and len(operands) >= 3:
                op_bytes = 2.0 * symbols.get(operands[2], 0.0)
            comp_bytes += op_bytes
        total += comp_bytes * m
    return total


def cost_terms(global_flops: float, global_bytes: float, chips: int,
               coll: CollectiveStats) -> Dict[str, float]:
    """Three roofline terms in seconds.

    compute = HLO_FLOPs/(chips·peak); memory = HLO_bytes/(chips·HBM_bw);
    collective = wire_bytes/(chip·link_bw) — wire bytes are already
    per-device (ring-factored per-partition shapes × trip counts)."""
    return {
        "flops_global": global_flops,
        "bytes_global": global_bytes,
        "collective_bytes_per_device": coll.wire_bytes,
        "t_compute": global_flops / (chips * PEAK_FLOPS),
        "t_memory": global_bytes / (chips * HBM_BW),
        "t_collective": coll.wire_bytes / ICI_BW,
    }


def flash_attention_flops(cfg, case, train: bool) -> float:
    """Analytic FLOPs of the Pallas flash-attention custom calls (invisible
    to HLO cost analysis).  Per layer forward: 4·B·H·hd·Σ_q S_eff(q)
    (QKᵀ + PV, 2 FLOPs per MAC each).  Train factor 5.5 ≈ fwd + target fwd
    + remat fwd + bwd (dq/dkv recompute P and run 5 block dots ≈ 2.5×fwd).
    Only reachable blocks execute, so S_eff honors causal/window/chunked.
    """
    if cfg.attn_impl != "flash" or cfg.family in ("ssm",):
        return 0.0
    s = case.seq_len if case.kind != "decode" else 1
    if case.kind == "decode":
        return 0.0   # decode keeps the cached (naive) path
    b = case.global_batch
    h, hd = cfg.num_heads, cfg.hd
    total = 0.0
    layers = cfg.num_layers
    for i in range(layers):
        if cfg.layer_is_global_attn(i) or cfg.attention == "full":
            s_eff_sum = s * (s + 1) / 2                     # causal triangle
        elif cfg.attention == "sliding":
            w = min(cfg.window, s)
            s_eff_sum = w * (w + 1) / 2 + max(s - w, 0) * w
        else:  # chunked-local
            w = min(cfg.window, s)
            s_eff_sum = max(1, s // w) * w * (w + 1) / 2
        total += 4.0 * b * h * hd * s_eff_sum
    # whisper: encoder self-attn + cross-attn keep the naive path (short
    # encoder length, not flash-eligible) — counted by the probe already.
    factor = 5.5 if train else 1.0
    return total * factor


def recurrence_flops_correction(cfg, case, train: bool) -> float:
    """Analytic FLOPs for ops inside *sequence* scans (mLSTM/sLSTM bodies),
    which the HLO cost probe counts once instead of ×S.  Per token:
      mLSTM ≈ 12·h·hd² (C/n update + decay + readout)
      sLSTM ≈ 8·h·hd² (4 recurrent head-local matmuls) + O(h·hd)
    Scaled ×5 for training (online fwd + remat fwd + bwd 2× + target fwd).
    Mamba's chunk-scan body is O(h·n·p) per *chunk* — negligible, skipped.
    """
    if cfg.family != "ssm":
        return 0.0
    h = cfg.num_heads
    hd = cfg.d_model // h
    toks = case.global_batch * (case.seq_len if case.kind != "decode" else 1)
    per_tok = 0.0
    for i in range(cfg.num_layers):
        per_tok += (8.0 if i in cfg.slstm_at else 12.0) * h * hd * hd
    scale = 5.0 if train else 1.0
    return per_tok * toks * scale


def dominant(terms: Dict[str, float]) -> str:
    keys = ["t_compute", "t_memory", "t_collective"]
    return max(keys, key=lambda k: terms.get(k, 0.0)).replace("t_", "")


# ----------------------------------------------------------- model flops ----

def param_count(cfg) -> Tuple[float, float]:
    """(total, active) parameter counts from the config (analytic)."""
    d, hd = cfg.d_model, cfg.hd
    h, kv, f, v = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size
    attn = d * h * hd + 2 * d * kv * hd + h * hd * d
    dense_mlp = 3 * d * f
    total = active = 0.0
    layers = cfg.num_layers
    if cfg.family == "ssm":
        for i in range(layers):
            if i in cfg.slstm_at:
                blk = 4 * d * d + 4 * cfg.num_heads * (d // cfg.num_heads) ** 2 \
                    + d * d + 3 * d * ((d * 4) // 3)
            else:
                blk = 4 * d * d + d * d + 3 * d * (d * 2)
            total += blk
        active = total
    else:
        for i in range(layers):
            lt = attn
            if cfg.family == "hybrid":
                di = cfg.ssm_expand * d
                lt += 2 * d * di + 2 * d * h * cfg.ssm_state + d * h + di * d
            if cfg.layer_is_moe(i):
                e_params = 3 * d * f
                lt_moe = cfg.num_experts * e_params + d * cfg.num_experts
                lt_active = cfg.experts_per_token * e_params
                if cfg.num_shared_experts:
                    shared = 3 * d * f * cfg.num_shared_experts
                    lt_moe += shared
                    lt_active += shared
                total += lt + lt_moe
                active += lt + lt_active
            else:
                total += lt + dense_mlp
                active += lt + dense_mlp
        if cfg.family == "audio":
            enc = cfg.encoder_layers * (attn + dense_mlp)
            cross = cfg.num_layers * attn
            total += enc + cross
            active += enc + cross
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    return total + emb, active + emb


def model_flops(cfg, case) -> float:
    """6·N_active·D train; 2·N_active·tokens for prefill; 2·N_active·B decode."""
    total, active = param_count(cfg)
    toks = case.global_batch * case.seq_len
    if case.kind == "train":
        return 6.0 * active * toks
    if case.kind == "prefill":
        return 2.0 * active * toks
    return 2.0 * active * case.global_batch   # decode: one token per seq
