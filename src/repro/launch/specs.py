"""Sharding-spec assembly for dry-run inputs, with divisibility filtering.

jit in/out shardings require every sharded dimension to divide evenly by
its mesh-axis extent; this module mirrors shape trees with PartitionSpec
trees and drops axis names where the dimension doesn't divide (e.g. 8
experts over a 16-way model axis, batch=1 over data for long_500k).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import backbone
from repro.models.config import ModelConfig, ShardingConfig

Pytree = Any


def axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def valid_spec(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop axis names on dimensions they don't divide."""
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
        elif dim % axis_size(mesh, entry) == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def shardings_for(tree_shapes: Pytree, spec_tree: Pytree, mesh: Mesh) -> Pytree:
    """NamedSharding tree from (shape tree, spec tree), filtered valid."""
    def one(shp, spec):
        return NamedSharding(mesh, valid_spec(shp.shape, spec, mesh))

    return jax.tree.map(one, tree_shapes, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch_shapes: Dict[str, Any], shd: ShardingConfig) -> Dict[str, Any]:
    """Learner/actor batch: leading batch dim over the data axes."""
    dp = shd.fsdp

    def one(s):
        return P(dp, *(None,) * (len(s.shape) - 1))

    return jax.tree.map(one, batch_shapes)


def cache_specs(cfg: ModelConfig, shd: ShardingConfig, cache_shapes) -> Pytree:
    """Spec tree mirroring init_cache output."""
    dp = shd.fsdp
    kv_spec = backbone._cache_kv_spec(cfg, shd)

    def rule(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                 for k in path]
        nd = len(leaf.shape)
        if not names:
            return P()
        if names[0] in ("k", "v", "cross_k", "cross_v"):
            return kv_spec
        if names[0] == "ssm":          # (U, B, H, N, P)
            return P(None, dp, None, None, None)
        if names[0] == "blocks":       # xlstm states: (B, H, ...)
            return P(*((dp,) + (None,) * (nd - 1)))
        return P()                     # pos etc.

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)
