import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_FLASH_STUB"] = "1"  # opaque-cost flash stand-in (see kernels/flash_attention.py)

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape × mesh) cell and record roofline inputs.

MUST be the very first lines above: jax locks the device count on first
init, and the production meshes need 512 host-platform placeholder
devices.  Do NOT set this flag anywhere global (tests/benches see 1).

Usage:
    python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]

Each cell writes a JSON artifact with memory_analysis, cost_analysis,
parsed collective stats and the three roofline terms; EXPERIMENTS.md
§Dry-run/§Roofline are generated from these artifacts.
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.agents import token_dqn
from repro.agents.token_dqn import TokenDQNConfig
from repro.configs import ARCH_IDS, get_config
from repro.configs import shapes as shp
from repro.launch import hlo_analysis as HA
from repro.launch.mesh import make_production_mesh, sharding_config
from repro.launch.specs import batch_specs, cache_specs, shardings_for, valid_spec
from repro.models import backbone
from repro.models.config import ModelConfig, NO_SHARDING
from repro.optim import adam


def choose_tcfg(cfg: ModelConfig, case: shp.ShapeCase, fsdp_size: int) -> TokenDQNConfig:
    """Accum so each device sees ~1 sequence per microbatch at ≥4B scale,
    and bf16 optimizer state for the biggest archs (HBM budget)."""
    big = cfg.d_model >= 4096 or cfg.num_experts >= 64
    per_dev = max(1, case.global_batch // fsdp_size)
    accum = per_dev if big else max(1, per_dev // 4)
    # accum must divide global_batch and keep microbatch divisible by fsdp
    while case.global_batch % accum or (case.global_batch // accum) % fsdp_size:
        accum -= 1
    state_dtype = "bfloat16" if big else None
    return TokenDQNConfig(accum=accum,
                          opt=adam.AdamConfig(lr=3e-5, state_dtype=state_dtype))


def sds_batch(cfg: ModelConfig, case: shp.ShapeCase):
    return shp.learner_batch_specs(cfg, case)


def per_device_bytes(shapes, shardings) -> float:
    total = 0.0
    for s, sh in zip(jax.tree.leaves(shapes), jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))):
        n = 1
        for d in s.shape:
            n *= d
        total += n * jnp.dtype(s.dtype).itemsize / sh.num_devices_sharded_over()
    return total


def _num_shards(sharding: NamedSharding, shape) -> int:
    n = 1
    spec = tuple(sharding.spec) + (None,) * (len(shape) - len(tuple(sharding.spec)))
    for entry in spec:
        if entry is None:
            continue
        axes = entry if isinstance(entry, (tuple, list)) else (entry,)
        for a in axes:
            n *= dict(sharding.mesh.shape)[a]
    return n


def tree_device_bytes(shapes, shardings) -> float:
    leaves_s = jax.tree.leaves(shapes)
    leaves_h = jax.tree.leaves(shardings,
                               is_leaf=lambda x: isinstance(x, NamedSharding))
    total = 0.0
    for s, h in zip(leaves_s, leaves_h):
        n = 1
        for d in s.shape:
            n *= d
        total += n * jnp.dtype(s.dtype).itemsize / _num_shards(h, s.shape)
    return total


def build_probe(arch: str, shape: str, opt: bool = False):
    """Cost-probe lowering: layers unrolled, accum=1, no sharding/mesh.

    ``lowered.cost_analysis()`` on this module gives *global* FLOPs/bytes
    with nothing hidden inside layer/microbatch scan bodies (XLA counts
    while bodies once — EXPERIMENTS.md §Methodology).  True train cost =
    accum × probe (optimizer/EMA outside the microbatch loop double-counts
    <1%, documented), plus analytic corrections for sequence-recurrence
    bodies (xLSTM).
    """
    cfg = dataclasses.replace(get_config(arch), scan_layers=False)
    if opt:
        cfg = optimized(cfg)
    case = shp.SHAPES[shape]
    key = jax.random.PRNGKey(0)

    if case.kind == "train":
        tcfg = TokenDQNConfig(accum=1)
        state_shapes = jax.eval_shape(
            functools.partial(token_dqn.init_train_state, cfg, tcfg), key)
        b_shapes = sds_batch(cfg, case)
        fn = functools.partial(token_dqn.train_step, cfg, NO_SHARDING, tcfg)
        return jax.jit(fn).lower(state_shapes, b_shapes)
    params_shapes = jax.eval_shape(
        functools.partial(backbone.init_params, cfg), key)
    if case.kind == "prefill":
        t_shapes = shp.token_specs(cfg, case)
        tokens_s = t_shapes.pop("tokens")
        extra_s = t_shapes.pop("extra_embeds", None)

        def fn(params, tokens, extra_embeds=None):
            logits, cache = backbone.prefill(cfg, NO_SHARDING, params, tokens,
                                             case.seq_len, extra_embeds)
            return logits[:, -1, :], cache["pos"]

        if extra_s is not None:
            return jax.jit(fn).lower(params_shapes, tokens_s, extra_s)
        return jax.jit(fn).lower(params_shapes, tokens_s)
    cache_shapes = jax.eval_shape(
        functools.partial(backbone.init_cache, cfg, NO_SHARDING,
                          case.global_batch, case.seq_len))
    tok_sds = jax.ShapeDtypeStruct((case.global_batch, 1), jnp.int32)
    fn = functools.partial(token_dqn.serve_step, cfg, NO_SHARDING)
    return jax.jit(fn).lower(params_shapes, cache_shapes, tok_sds)


OPT_OVERRIDES = dict(attn_impl="flash", moe_ff_tp_fallback=True,
                     mlstm_chunked=True, moe_local_dispatch=True)


def optimized(cfg: ModelConfig) -> ModelConfig:
    """Beyond-paper §Perf configuration (baseline stays 'naive')."""
    return dataclasses.replace(cfg, **OPT_OVERRIDES)


def build_cell(arch: str, shape: str, multi_pod: bool, opt: bool = False):
    """Returns (lower_fn, static info) for the cell."""
    cfg = get_config(arch)
    if opt:
        cfg = optimized(cfg)
    case = shp.SHAPES[shape]
    if not shp.runnable(cfg, shape):
        return None, {"skipped": True,
                      "reason": "long_500k requires sub-quadratic attention "
                                "(DESIGN.md §5)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    shd = sharding_config(multi_pod)
    fsdp_size = 1
    for a in shd.fsdp:
        fsdp_size *= mesh.shape[a]

    key = jax.random.PRNGKey(0)

    if case.kind == "train":
        tcfg = choose_tcfg(cfg, case, fsdp_size)
        state_shapes = jax.eval_shape(
            functools.partial(token_dqn.init_train_state, cfg, tcfg), key)
        sspec = token_dqn.state_specs(cfg, shd, state_shapes)
        s_shard = shardings_for(state_shapes, sspec, mesh)
        b_shapes = sds_batch(cfg, case)
        b_shard = shardings_for(b_shapes, batch_specs(b_shapes, shd), mesh)
        fn = functools.partial(token_dqn.train_step, cfg, shd, tcfg)
        jfn = jax.jit(fn, in_shardings=(s_shard, b_shard), donate_argnums=(0,))

        def lower():
            with jax.set_mesh(mesh):
                return jfn.lower(state_shapes, b_shapes)

        info = {"kind": "train", "accum": tcfg.accum,
                "state_bytes_per_device": tree_device_bytes(state_shapes, s_shard)}
        return lower, info

    params_shapes = jax.eval_shape(
        functools.partial(backbone.init_params, cfg), key)
    pspec = backbone.param_specs(cfg, shd, params_shapes)
    p_shard = shardings_for(params_shapes, pspec, mesh)

    if case.kind == "prefill":
        t_shapes = shp.token_specs(cfg, case)
        t_shard = shardings_for(t_shapes, batch_specs(t_shapes, shd), mesh)
        max_len = case.seq_len

        def fn(params, tokens, extra_embeds=None):
            logits, cache = backbone.prefill(cfg, shd, params, tokens,
                                             max_len, extra_embeds)
            return logits[:, -1, :], cache["pos"]  # actor bootstrap output

        kwargs = dict(t_shapes)
        tokens_s = kwargs.pop("tokens")
        extra_s = kwargs.pop("extra_embeds", None)
        in_sh = (p_shard, t_shard["tokens"]) + (
            (t_shard["extra_embeds"],) if extra_s is not None else ())
        jfn = jax.jit(fn, in_shardings=in_sh)

        def lower():
            with jax.set_mesh(mesh):
                if extra_s is not None:
                    return jfn.lower(params_shapes, tokens_s, extra_s)
                return jfn.lower(params_shapes, tokens_s)

        info = {"kind": "prefill",
                "state_bytes_per_device": tree_device_bytes(params_shapes, p_shard)}
        return lower, info

    # decode / long-decode: serve_step with a seq_len KV cache
    cache_shapes = jax.eval_shape(
        functools.partial(backbone.init_cache, cfg, NO_SHARDING,
                          case.global_batch, case.seq_len))
    c_shard = shardings_for(cache_shapes, cache_specs(cfg, shd, cache_shapes), mesh)
    tok_sds = jax.ShapeDtypeStruct((case.global_batch, 1), jnp.int32)
    tok_shard = NamedSharding(
        mesh, valid_spec(tok_sds.shape, P(shd.fsdp, None), mesh))
    fn = functools.partial(token_dqn.serve_step, cfg, shd)
    jfn = jax.jit(fn, in_shardings=(p_shard, c_shard, tok_shard),
                  donate_argnums=(1,))

    def lower():
        with jax.set_mesh(mesh):
            return jfn.lower(params_shapes, cache_shapes, tok_sds)

    info = {"kind": "decode",
            "state_bytes_per_device": tree_device_bytes(params_shapes, p_shard),
            "cache_bytes_per_device": tree_device_bytes(cache_shapes, c_shard)}
    return lower, info


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             force: bool = False, opt: bool = False) -> Dict[str, Any]:
    tag = f"{arch}_{shape}_{'pod2' if multi_pod else 'pod1'}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = optimized(get_config(arch)) if opt else get_config(arch)
    case = shp.SHAPES[shape]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "opt": opt,
        "mesh": [2, 16, 16] if multi_pod else [16, 16],
    }
    t0 = time.time()
    try:
        lower_fn, info = build_cell(arch, shape, multi_pod, opt=opt)
        rec.update(info)
        if info.get("skipped"):
            rec["status"] = "skipped"
        else:
            lowered = lower_fn()
            t_lower = time.time()
            compiled = lowered.compile()
            t_comp = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            coll = HA.parse_collectives(hlo)
            chips = 512 if multi_pod else 256
            # memory term: fused partitioned HLO, trip-count multiplied
            g_bytes = HA.hbm_bytes_per_device(hlo) * chips
            # compute term: unrolled unpartitioned probe (full batch, accum=1
            # → already whole-step FLOPs; + recurrence-scan corrections)
            t_probe = time.time()
            try:
                probe_cost = build_probe(arch, shape, opt=opt).cost_analysis() or {}
                g_flops = float(probe_cost.get("flops", 0.0))
                g_flops += HA.recurrence_flops_correction(
                    cfg, case, case.kind == "train")
                g_flops += HA.flash_attention_flops(
                    cfg, case, case.kind == "train")
                rec["probe_s"] = round(time.time() - t_probe, 1)
                rec["probe"] = "ok"
                rec["probe_bytes_naive"] = probe_cost.get("bytes accessed")
            except Exception as pe:  # noqa: BLE001
                g_flops = float(cost.get("flops", 0.0)) * chips
                rec["probe"] = f"failed: {type(pe).__name__}: {str(pe)[:200]}"
            terms = HA.cost_terms(g_flops, g_bytes, chips, coll)
            mf = HA.model_flops(cfg, case)
            rec.update({
                "status": "ok",
                "lower_s": round(t_lower - t0, 1),
                "compile_s": round(t_comp - t_lower, 1),
                "memory_analysis": repr(mem),
                "compiled_cost_flops_per_device": cost.get("flops"),
                "compiled_cost_bytes_per_device": cost.get("bytes accessed"),
                "collectives": coll.counts,
                "collective_raw_bytes": coll.raw_bytes,
                **terms,
                "model_flops_global": mf,
                "useful_flops_ratio": (mf / g_flops if g_flops else None),
                "dominant": HA.dominant(terms),
            })
            print(compiled.memory_analysis())
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    print(f"[{tag}] {rec['status']} ({rec['total_s']}s) "
          f"dominant={rec.get('dominant')} err={rec.get('error', '')[:120]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper optimized config (writes to --out)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = "experiments/dryrun_opt" if args.opt else "experiments/dryrun"

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes_ = list(shp.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes_:
                cells.append((a, s, mp))

    ok = err = skipped = 0
    for a, s, mp in cells:
        rec = run_cell(a, s, mp, args.out, args.force, opt=args.opt)
        st = rec["status"]
        ok += st == "ok"
        err += st == "error"
        skipped += st == "skipped"
    print(f"\ndry-run summary: {ok} ok, {skipped} skipped, {err} errors "
          f"of {len(cells)} cells")
    raise SystemExit(1 if err else 0)


if __name__ == "__main__":
    main()
