"""Wall-clock multi-process launcher (DESIGN.md §10).

Everything else in this repo emulates a device mesh inside one process
(``--xla_force_host_platform_device_count``), which serializes the
"parallel" shards on the host and makes the fig10 scaling curve a
simulation.  This module launches a real gang: N worker processes, each
owning its own XLA client with ``devices_per_proc`` forced host devices,
joined into one multi-controller SPMD runtime by
``core.distributed.initialize_distributed`` (gloo collectives on CPU).
After the handshake ``jax.devices()`` spans the whole gang in process
order, so the existing ``launch.mesh`` constructors and the shard_map
executors run unchanged — each process executes its addressable mesh
cells and the gradient reduce crosses real process boundaries.

Parent side (``launch``): picks a free coordinator port, spawns
``python -m repro.launch.multiprocess`` once per process id with
per-worker ``XLA_FLAGS``/``PYTHONPATH`` env, streams and collects
stdout, and raises with the failing worker's tail on non-zero exit.
Results travel as ``KEY=VALUE`` lines on process 0's stdout
(``parse_kv``) — the same convention as fig10's emulated pod workers.

Worker side (``main``): initializes the distributed runtime, then runs
one of three workloads:

  * ``--mode bench`` — DQN/CartPole through ``FusedExecutor`` (1 total
    device) or ``ShardedExecutor`` (data or pod×data mesh over the
    gang's global devices, optionally int8-compressed and/or overlapped
    cross-pod reduce), timed median-of-``--repeats`` with ``rel_spread``
    — the wall-clock arm of benchmarks/fig10_scalability.py.  With
    ``--publish-interval P > 0`` the async double buffer is republished
    *externally*: between chunks the fresh params make a real
    device→host→device round trip (``external_publish``) instead of the
    in-program copy.
  * ``--mode fused`` — the degenerate single-process launch: the exact
    ``FusedExecutor.train`` program, printing final metrics and a
    parameter checksum.  Bit-exact against the same executor run
    in-process (tests/test_multiprocess.py): the distributed runtime at
    N=1 must be a no-op.
  * ``--mode equiv`` — 2-process reducer equivalence: the overlapped
    and barrier cross-pod reduces driven over the same per-pod gradient
    streams through real cross-process collectives; process 0 prints
    the shift-identity and telescoping errors
    (tests/test_distributed.py).

Chunks are bracketed with ``jax.profiler.StepTraceAnnotation`` step
markers so profile traces segment per chunk.

**Replay-service gang** (DESIGN.md §11): ``launch_service`` spawns a
second kind of gang — one ``--mode replay-server`` process hosting the
sharded rate-limited ``ReplayService``, N ``--mode service-actor``
writer processes and one ``--mode service-learner`` sampler process.
These roles do NOT join ``jax.distributed``: each owns an independent
single-device jax runtime and they meet only at the service's TCP
boundary (append / sample / priority write-back / param channel), so an
actor crash can never wedge a collective.  Results ride the same
``KEY=VALUE`` stdout protocol; the server reports the rate limiter's
realized samples-per-insert ratio and its tolerance band.  With
``restart_learner_after`` the learner exits mid-run after checkpointing
and a fresh learner process resumes from the checkpoint against the
still-live service — actors park in writer backpressure for the gap
(the rate limiter, not a barrier, holds the fleet).
"""

from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

HANDSHAKE_TIMEOUT_S = 60.0


# -- parent side -------------------------------------------------------------


def free_port() -> int:
    """A port the coordinator can bind (raced, but single-host tests and
    benchmarks re-launch on collision rather than coordinate)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _src_root() -> str:
    # .../src/repro/launch/multiprocess.py → .../src
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def worker_env(devices_per_proc: int) -> Dict[str, str]:
    """Child env: forced per-process host device count (before any jax
    import — the whole reason the launcher is a separate process) and an
    import path that reaches ``repro`` regardless of the parent's cwd."""
    env = os.environ.copy()
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{devices_per_proc}")
    env["JAX_PLATFORMS"] = "cpu"
    path = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = _src_root() + (os.pathsep + path if path else "")
    return env


def launch(
    worker_args: Sequence[str],
    n_procs: int,
    devices_per_proc: int = 1,
    coordinator: Optional[str] = None,
    timeout_s: float = 900.0,
    handshake_timeout_s: float = HANDSHAKE_TIMEOUT_S,
) -> List[str]:
    """Spawn the full ``n_procs`` gang and return per-process stdout
    (index = process id).  Raises ``RuntimeError`` with the failing
    worker's output tail if any exits non-zero or overruns
    ``timeout_s``."""
    if n_procs < 1:
        raise ValueError(f"n_procs={n_procs}: need ≥ 1")
    coordinator = coordinator or f"127.0.0.1:{free_port()}"
    env = worker_env(devices_per_proc)
    procs = []
    for pid in range(n_procs):
        cmd = [sys.executable, "-m", "repro.launch.multiprocess",
               "--coordinator", coordinator,
               "--n-procs", str(n_procs),
               "--process-id", str(pid),
               "--handshake-timeout", str(handshake_timeout_s),
               *worker_args]
        procs.append(subprocess.Popen(
            cmd, env=env, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True))
    outs: List[str] = [""] * n_procs
    deadline = time.monotonic() + timeout_s
    failed = None
    for pid, p in enumerate(procs):
        left = max(1.0, deadline - time.monotonic())
        try:
            outs[pid], _ = p.communicate(timeout=left)
        except subprocess.TimeoutExpired:
            p.kill()
            outs[pid], _ = p.communicate()
            failed = failed or (pid, "timeout")
        if p.returncode not in (0, None) and failed is None:
            failed = (pid, f"exit code {p.returncode}")
    if failed is not None:
        # one worker down wedges the rest at the next collective — kill
        # the whole gang before reporting
        for p in procs:
            if p.poll() is None:
                p.kill()
        pid, why = failed
        tail = "\n".join(outs[pid].splitlines()[-25:])
        raise RuntimeError(
            f"wall-clock worker {pid}/{n_procs} failed ({why}); output "
            f"tail:\n{tail}")
    return outs


def parse_kv(text: str) -> Dict[str, str]:
    """The ``KEY=VALUE`` result lines a worker prints (keys are
    UPPER_SNAKE by convention; later lines win)."""
    out: Dict[str, str] = {}
    for line in text.splitlines():
        line = line.strip()
        if "=" in line and line.split("=", 1)[0].replace("_", "").isupper():
            k, v = line.split("=", 1)
            out[k] = v
    return out


# -- replay-service gang (parent side) ---------------------------------------


def _wait_for_server(port: int, proc: subprocess.Popen,
                     timeout_s: float = 90.0) -> None:
    """Poll the service port until it accepts; fail fast (with the
    server's output tail) if the server process dies during startup."""
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            out, _ = proc.communicate()
            tail = "\n".join(out.splitlines()[-25:])
            raise RuntimeError(
                f"replay server exited during startup (code "
                f"{proc.returncode}); output tail:\n{tail}")
        try:
            with socket.create_connection(("127.0.0.1", port), timeout=1.0):
                return
        except OSError:
            time.sleep(0.1)
    proc.kill()
    raise RuntimeError(
        f"replay server did not open port {port} within {timeout_s:.0f}s")


def launch_service(
    n_actors: int = 2,
    *,
    n_shards: int = 1,
    samples_per_insert: float = 16.0,
    batch_size: int = 64,
    warmup: int = 512,
    learn_steps: int = 1200,
    n_envs: int = 8,
    actor_chunk: int = 8,
    capacity_per_shard: int = 20_000,
    publish_every: int = 16,
    epsilon: float = 0.2,
    seed: int = 0,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    restart_learner_after: Optional[int] = None,
    restart_server_after: Optional[int] = None,
    snapshot_dir: Optional[str] = None,
    snapshot_every_appends: int = 0,
    retry_deadline: float = 180.0,
    timeout_s: float = 900.0,
) -> Dict[str, Dict[str, str]]:
    """Spawn the replay-service gang: 1 server + ``n_actors`` writers +
    1 learner, every role its own process with an independent jax
    runtime, meeting only at the service's TCP boundary.  Returns the
    parsed ``KEY=VALUE`` results per role (``server``, ``actor-<i>``,
    ``learner``, plus ``learner-0`` for the pre-restart learner when
    ``restart_learner_after`` is set, and ``server-0`` for the crashed
    server when ``restart_server_after`` is set).

    With ``restart_learner_after`` the first learner process checkpoints
    and exits after that many learn steps *without* stopping the service
    — actors park in writer backpressure — and a second learner process
    resumes from the checkpoint (``--resume``) and trains to completion:
    the elastic-restart drill of DESIGN.md §4.5 against a live service.

    With ``restart_server_after`` the *server* is the casualty
    (DESIGN.md §14): a hard FaultPlan kills it with os._exit(42) when
    its Nth append arrives, while ``snapshot_every_appends=1`` has been
    giving durable acks all along.  Clients park in reconnect backoff,
    a fresh server process restores the latest snapshot onto the same
    port, and training runs through to criterion — with the per-writer
    applied counters provably equal to the clients' acked counts."""
    if n_actors < 1:
        raise ValueError(f"n_actors={n_actors}: need ≥ 1")
    if restart_learner_after is not None and not (ckpt_dir and ckpt_every):
        raise ValueError("restart_learner_after requires ckpt_dir and "
                         "ckpt_every (the resumed learner restores from "
                         "the checkpoint directory)")
    if restart_server_after is not None and not (
            snapshot_dir and snapshot_every_appends):
        raise ValueError("restart_server_after requires snapshot_dir and "
                         "snapshot_every_appends (the restarted server "
                         "restores from the shard snapshots)")
    env = worker_env(1)
    port = free_port()
    deadline = time.monotonic() + timeout_s

    def spawn(role_args: List[str]) -> subprocess.Popen:
        cmd = [sys.executable, "-m", "repro.launch.multiprocess", *role_args]
        return subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, text=True)

    common = ["--serve-port", str(port), "--batch-size", str(batch_size),
              "--seed", str(seed),
              "--retry-deadline", str(retry_deadline)]
    # the admission window must absorb one full gang burst: every actor
    # can land a whole rollout chunk between two learner samples
    burst = n_actors * actor_chunk * n_envs
    server_args = ["--mode", "replay-server", *common,
                   "--n-shards", str(n_shards),
                   "--spi", str(samples_per_insert),
                   "--warmup", str(warmup),
                   "--capacity-per-shard", str(capacity_per_shard),
                   "--insert-burst", str(burst),
                   "--serve-timeout", str(timeout_s)]
    if snapshot_dir:
        server_args += ["--snapshot-dir", snapshot_dir,
                        "--snapshot-every-appends",
                        str(snapshot_every_appends)]
    first_server_args = list(server_args)
    if restart_server_after is not None:
        first_server_args += [
            "--fault-plan",
            f"crash_on_op=append:{restart_server_after},hard=1"]
    procs: Dict[str, subprocess.Popen] = {}
    procs["server"] = spawn(first_server_args)
    try:
        _wait_for_server(port, procs["server"],
                         timeout_s=min(90.0, timeout_s))
        for a in range(n_actors):
            procs[f"actor-{a}"] = spawn(
                ["--mode", "service-actor", *common,
                 "--actor-id", str(a),
                 "--n-envs", str(n_envs),
                 "--actor-chunk", str(actor_chunk),
                 "--epsilon", str(epsilon)])
        learner_args = ["--mode", "service-learner", *common,
                        "--n-envs", str(n_envs),
                        "--learn-steps", str(learn_steps),
                        "--publish-every", str(publish_every)]
        if ckpt_dir:
            learner_args += ["--ckpt-dir", ckpt_dir,
                             "--ckpt-every", str(ckpt_every)]
        if restart_learner_after is not None:
            first = spawn([*learner_args,
                           "--exit-after", str(restart_learner_after)])
            procs["learner-0"] = first
            first.wait(timeout=max(1.0, deadline - time.monotonic()))
            if first.returncode != 0:
                out, _ = first.communicate()
                tail = "\n".join(out.splitlines()[-25:])
                raise RuntimeError(
                    f"pre-restart learner failed (code {first.returncode}); "
                    f"output tail:\n{tail}")
            procs["learner"] = spawn([*learner_args, "--resume"])
        else:
            procs["learner"] = spawn(learner_args)
        if restart_server_after is not None:
            from repro.service.faults import CRASH_EXIT_CODE
            first_server = procs.pop("server")
            procs["server-0"] = first_server
            first_server.wait(timeout=max(1.0, deadline - time.monotonic()))
            if first_server.returncode != CRASH_EXIT_CODE:
                out, _ = first_server.communicate()
                tail = "\n".join(out.splitlines()[-25:])
                raise RuntimeError(
                    f"server did not crash as planned (code "
                    f"{first_server.returncode}, expected "
                    f"{CRASH_EXIT_CODE}); output tail:\n{tail}")
            # actors and learner are now parked in reconnect backoff;
            # the replacement restores the snapshot onto the SAME port
            # (SO_REUSEADDR) so nobody needs re-addressing
            procs["server"] = spawn([*server_args, "--restore-server"])
            _wait_for_server(port, procs["server"],
                             timeout_s=min(90.0, timeout_s))
    except Exception:
        for p in procs.values():
            if p.poll() is None:
                p.kill()
        raise

    expected_codes = {"server-0": {0}}
    if restart_server_after is not None:
        from repro.service.faults import CRASH_EXIT_CODE
        expected_codes["server-0"] = {CRASH_EXIT_CODE}
    outs: Dict[str, str] = {}
    failed = None
    for name, p in procs.items():
        left = max(1.0, deadline - time.monotonic())
        try:
            outs[name], _ = p.communicate(timeout=left)
        except subprocess.TimeoutExpired:
            p.kill()
            outs[name], _ = p.communicate()
            failed = failed or (name, "timeout")
        if (p.returncode not in (0, None) and failed is None
                and p.returncode not in expected_codes.get(name, ())):
            failed = (name, f"exit code {p.returncode}")
    if failed is not None:
        for p in procs.values():
            if p.poll() is None:
                p.kill()
        name, why = failed
        tail = "\n".join(outs.get(name, "").splitlines()[-25:])
        raise RuntimeError(
            f"replay-service worker {name} failed ({why}); output "
            f"tail:\n{tail}")
    return {name: parse_kv(text) for name, text in outs.items()}


# -- worker side -------------------------------------------------------------


def _median_spread(samples: Sequence[float]):
    """(median, (max−min)/median) — the rel_spread convention of
    benchmarks/timing.py, inlined because ``benchmarks`` is not
    importable from ``src``."""
    xs = sorted(samples)
    n = len(xs)
    med = (xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2]))
    spread = (xs[-1] - xs[0]) / med if med else 0.0
    return med, spread


def _dqn_cartpole(n_envs_local_hint: int):
    """The benchmark workload everything wall-clock measures: DQN on
    vectorized CartPole (matches fig10's emulated arms)."""
    import functools

    import jax.numpy as jnp

    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.envs.classic import make_vec

    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    del n_envs_local_hint
    return env_fn, spec, agent, example


def _build_executor(args):
    import jax

    from repro.core.distributed import ShardedPrioritizedReplay, \
        ShardedReplayConfig
    from repro.core.replay import PrioritizedReplay, ReplayConfig
    from repro.launch.mesh import data_mesh, pod_data_mesh
    from repro.runtime.executors import FusedExecutor, ShardedExecutor
    from repro.runtime.loop import LoopConfig

    env_fn, spec, agent, example = _dqn_cartpole(args.n_envs)
    cfg = LoopConfig(batch_size=64, warmup=64, epsilon=0.1,
                     update_interval=args.update_interval)
    n_cells = args.n_pods * args.n_data
    if n_cells != jax.device_count():
        raise RuntimeError(
            f"mesh {args.n_pods}x{args.n_data} wants {n_cells} cells but "
            f"the gang exposes {jax.device_count()} global devices "
            f"({jax.process_count()} procs × "
            f"{len(jax.local_devices())} local)")
    external = args.publish_interval > 0
    if n_cells == 1:
        replay = PrioritizedReplay(
            ReplayConfig(capacity=50_000, fanout=128), example)
        return FusedExecutor(agent, replay, env_fn, cfg, args.n_envs,
                             scan_chunk=args.scan_chunk,
                             publish_interval=args.publish_interval,
                             external_publish=external)
    if args.n_pods > 1:
        mesh, axes = pod_data_mesh(args.n_pods, args.n_data), ("pod", "data")
    else:
        mesh, axes = data_mesh(args.n_data), ("data",)
    replay = ShardedPrioritizedReplay(
        ShardedReplayConfig(capacity_per_shard=50_000 // n_cells,
                            fanout=128, axis_names=axes), example)
    return ShardedExecutor(agent, replay, env_fn, cfg, args.n_envs, mesh,
                           scan_chunk=args.scan_chunk,
                           publish_interval=args.publish_interval,
                           compress_pod_reduce=args.compress,
                           overlap_pod_reduce=args.overlap,
                           external_publish=external)


def _publish_host_roundtrip(ex, state):
    """The real device→host→device parameter publish of the wall-clock
    async mode: fetch the fresh learner params to the host (a true D2H
    transfer — ``jax.device_get`` materializes numpy), then rebuild the
    per-shard acting copies and zero the ages.  Replaces the in-program
    ``jnp.where`` republish (``make_step(external_publish=True)``)."""
    import jax
    import numpy as np

    host = jax.device_get(ex.agent.params_for_acting(state.agent))

    def republish(old, fresh):
        fresh = np.asarray(fresh)
        if old.shape == fresh.shape:        # fused path: plain put
            return jax.device_put(fresh, old.sharding)
        # sharded path: leading shard dim — broadcast the host copy into
        # every shard's slot of the global array
        wide = np.broadcast_to(fresh[None], old.shape)
        return jax.make_array_from_callback(
            old.shape, old.sharding, lambda idx: wide[idx])

    actor_params = jax.tree.map(republish, state.actor_params, host)
    age = state.params_age
    zero = np.zeros(age.shape, dtype=np.int32)
    params_age = jax.make_array_from_callback(
        age.shape, age.sharding, lambda idx: zero[idx])
    return state._replace(actor_params=actor_params, params_age=params_age)


def _bench_worker(args):
    import jax

    ex = _build_executor(args)
    pid = jax.process_index()
    publish = args.publish_interval

    def run_iters(state, iters, base_step):
        done = 0
        while done < iters:
            length = min(publish or ex.scan_chunk, iters - done)
            with jax.profiler.StepTraceAnnotation(
                    "wallclock_chunk", step_num=base_step + done):
                state, metrics = ex.run_chunk(state, length)
            if publish:
                state = _publish_host_roundtrip(ex, state)
            done += length
        return state, metrics

    state = ex.init(jax.random.PRNGKey(args.seed))
    # warmup compiles every chunk length the timed loop will use
    state, _ = run_iters(state, args.iters, 0)
    samples = []
    for r in range(args.repeats):
        t0 = time.perf_counter()
        state, metrics = run_iters(state, args.iters, (r + 1) * args.iters)
        jax.block_until_ready(metrics["env_steps"])
        dt = time.perf_counter() - t0
        samples.append(args.n_envs * args.iters / dt)
    med, spread = _median_spread(samples)
    if pid == 0:
        print(f"STEPS_PER_S={med:.2f}")
        print(f"REL_SPREAD={spread:.4f}")
        print(f"REPEATS={args.repeats}")
        print(f"ENV_STEPS={int(jax.device_get(metrics['env_steps'])[-1])}")


def _fused_worker(args):
    import jax

    if jax.process_count() != 1:
        raise RuntimeError("--mode fused is the degenerate single-process "
                           f"launch; got {jax.process_count()} procs")
    ex = _build_executor(args)
    state, hist = ex.train(args.iters, jax.random.PRNGKey(args.seed))
    params = jax.device_get(state.agent.params)
    checksum = 0.0
    for leaf in jax.tree.leaves(params):
        checksum += float(abs(leaf.astype("float64")).sum())
    print(f"FINAL_LOSS={float(hist['loss'][-1])!r}")
    print(f"FINAL_RETURN={float(hist['mean_episode_return'][-1])!r}")
    print(f"ENV_STEPS={int(hist['env_steps'][-1])}")
    print(f"PARAMS_CHECKSUM={checksum!r}")


def _equiv_worker(args):
    """Overlapped vs barrier cross-pod reduce over *real* 2-process
    collectives: same per-pod gradient streams, checked in-program
    (replicated scalar outputs — per-pod intermediates are never pulled
    to the host, which multi-controller mode would reject):

      * shift identity — on a constant stream, overlapped event t
        equals barrier event t−1 bit-exactly;
      * telescoping — on a varying stream the cumulative applied
        difference collapses to ``p_T − pm_T`` (one gradient's pod
        disagreement, not T of them).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.runtime.learner import make_grad_reducer

    if jax.device_count() != 2:
        raise RuntimeError(f"--mode equiv wants a 2-device (pod) gang, "
                           f"got {jax.device_count()}")
    mesh = Mesh(np.asarray(jax.devices()).reshape(2), ("pod",))
    barrier = make_grad_reducer(("pod",), compress_axis="pod")
    overlap = make_grad_reducer(("pod",), compress_axis="pod", overlap=True)
    T = 8

    def program(gc, gs):
        # local views: gc (1, dim), gs (T, 1, dim) — one pod per process
        z = jnp.zeros_like(gc)

        def b_chain(stream):
            ef, outs = z, []
            for g in stream:
                out, ef = barrier(g, None, ef)
                outs.append(out)
            return outs

        def o_chain(stream):
            ef = {"ef": z, "prev_mean": z, "prev_partial": z}
            outs = []
            for g in stream:
                out, ef = overlap(g, None, ef)
                outs.append(out)
            return outs

        const = [gc] * 6
        ob, oo = b_chain(const), o_chain(const)
        shift = jnp.max(jnp.stack(
            [jnp.max(jnp.abs(oo[t] - ob[t - 1])) for t in range(1, 6)]))

        varying = [gs[t] for t in range(T)]
        vb, vo = b_chain(varying), o_chain(varying)
        cum_diff = sum(vo) - sum(vb)
        # n_data = 1 ⇒ the intra-pod partial is the local gradient itself
        tele = jnp.max(jnp.abs(cum_diff - (varying[-1] - vb[-1])))
        return jax.lax.pmax(shift, "pod"), jax.lax.pmax(tele, "pod")

    run = jax.jit(shard_map(
        program, mesh=mesh, in_specs=(P("pod"), P(None, "pod")),
        out_specs=(P(), P()), check_rep=False))

    # identical host-side streams on every process, sharded pod-major
    dim = 16
    rng = np.random.RandomState(args.seed)
    gc_host = rng.randn(2, 1, dim).astype(np.float32)
    gs_host = rng.randn(T, 2, 1, dim).astype(np.float32)

    def gshard(x, spec):
        sh = NamedSharding(mesh, spec)
        return jax.make_array_from_callback(x.shape, sh, lambda idx: x[idx])

    shift, tele = run(gshard(gc_host, P("pod")),
                      gshard(gs_host, P(None, "pod")))
    if jax.process_index() == 0:
        print(f"SHIFT_MAX_ABS_ERR={float(jax.device_get(shift))!r}")
        print(f"TELESCOPE_MAX_ABS_ERR={float(jax.device_get(tele))!r}")


# -- replay-service workers ---------------------------------------------------


def _params_checksum(params) -> float:
    import jax

    checksum = 0.0
    for leaf in jax.tree.leaves(jax.device_get(params)):
        checksum += float(abs(leaf.astype("float64")).sum())
    return checksum


def _replay_server_worker(args):
    """``--mode replay-server``: host the sharded rate-limited service
    until the learner sends stop, then report flow-control stats.  With
    ``--snapshot-dir`` the service snapshots its full state every
    ``--snapshot-every-appends`` applied appends; ``--restore-server``
    resumes from the latest snapshot (the server-restart drill,
    DESIGN.md §14); ``--fault-plan`` arms deterministic wire faults —
    a ``hard=1`` crash plan kills this process with os._exit(42), so
    every print before it must flush."""
    from repro.service import (FaultPlan, RateLimiter, ReplayService,
                               ReplayServiceConfig, serve)

    _, _, _, example = _dqn_cartpole(1)
    spi = args.spi
    # loose gang band: the admission window absorbs the largest single
    # writer burst (a whole actor chunk), not one lockstep loop step
    eb = 2.0 * max(float(args.batch_size), spi * max(1, args.insert_burst))
    limiter = RateLimiter(samples_per_insert=spi,
                          min_size_to_sample=max(1, args.warmup),
                          error_buffer=eb)
    service = ReplayService(
        ReplayServiceConfig(capacity_per_shard=args.capacity_per_shard,
                            n_shards=args.n_shards,
                            fanout=128,
                            seed=args.seed),
        example, rate_limiter=limiter)
    restored_step = None
    if args.snapshot_dir:
        from repro.checkpoint.manager import CheckpointManager
        manager = CheckpointManager(args.snapshot_dir, keep=3)
        if args.restore_server:
            restored_step = service.restore_snapshot(manager)
            if restored_step is None:
                raise RuntimeError("--restore-server: no snapshot under "
                                   f"{args.snapshot_dir}")
            print(f"RESTORED_STEP={restored_step}", flush=True)
        service.attach_snapshots(
            manager, every_appends=max(1, args.snapshot_every_appends))
    fault_plan = (FaultPlan.parse(args.fault_plan)
                  if args.fault_plan else None)
    server, port = serve(service, port=args.serve_port,
                         fault_plan=fault_plan)
    print(f"SERVE_PORT={port}", flush=True)
    deadline = time.monotonic() + args.serve_timeout
    while not service.stopped and time.monotonic() < deadline:
        time.sleep(0.1)
    timed_out = not service.stopped
    service.stop()
    time.sleep(2.0)  # grace: parked clients drain their final replies
    server.shutdown()
    st = service.stats()
    rl = st["rate_limiter"]
    denom = max(1, int(rl["inserts"]) - int(rl["min_size_to_sample"]))
    print(f"INSERTS={rl['inserts']}")
    print(f"SAMPLES={rl['samples']}")
    print(f"CONFIGURED_SPI={spi!r}")
    print(f"REALIZED_SPI={rl['realized_spi']!r}")
    # the band theorem: |realized − spi| ≤ error_buffer/(inserts − min)
    print(f"SPI_TOLERANCE={eb / denom!r}")
    print(f"MEAN_RECENT_RETURN={st['mean_recent_return']!r}")
    print(f"N_RETURNS={st['n_returns']}")
    print("PER_SHARD_COUNT="
          + ",".join(str(c) for c in st["per_shard_count"]))
    print(f"PARAMS_VERSION={st['params_version']}")
    print(f"APPENDS={st['appends']}")
    print(f"DUP_APPENDS={st['dup_appends']}")
    print("WRITER_APPENDS=" + ",".join(
        f"{w}:{n}" for w, n in sorted(st["writer_appends"].items())))
    print(f"SNAPSHOTS={st['snapshots']}")
    if restored_step is not None:
        print(f"RESTORED_STEP={restored_step}")
    if timed_out:
        raise SystemExit("replay server: no stop received within "
                         f"--serve-timeout {args.serve_timeout:.0f}s")


def _service_actor_worker(args):
    """``--mode service-actor``: run the actor program against the
    service — pull params from the channel, push transition chunks
    through rate-limited appends, until the learner stops the service.
    The ε-schedule clocks off the service's *global* insert counter, so
    the fleet's exploration decays as one actor regardless of how many
    writers share the budget."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.runtime.loop import (LoopConfig, init_actor_slice,
                                    make_actor_program)
    from repro.service.client import (ReplayClient, RetryPolicy,
                                      wait_for_service)

    env_fn, _, agent, _ = _dqn_cartpole(args.n_envs)
    _, v_reset, v_step = env_fn(args.n_envs)
    cfg = LoopConfig(batch_size=args.batch_size, warmup=args.warmup,
                     epsilon=args.epsilon)
    program = make_actor_program(agent, v_step, cfg, args.n_envs)

    def chunk(agent_state, sl, key, env_steps0):
        def body(carry, t):
            sl, k = carry
            k_next, kk = jax.random.split(k)
            kk = jax.random.fold_in(kk, args.actor_id)  # decorrelate fleet
            k_act, k_env = jax.random.split(kk)
            sl, transitions = program(agent_state, sl, k_act, k_env,
                                      env_steps0 + t * args.n_envs)
            done = transitions["done"] > 0
            finished = jnp.where(done, sl.last_return, jnp.nan)
            return (sl, k_next), (transitions, finished)

        (sl, key), (trans, finished) = jax.lax.scan(
            body, (sl, key), jnp.arange(args.actor_chunk))
        flat = jax.tree.map(
            lambda x: x.reshape((args.actor_chunk * args.n_envs,)
                                + x.shape[2:]), trans)
        return sl, key, flat, finished

    chunk = jax.jit(chunk)

    wait_for_service("127.0.0.1", args.serve_port, timeout=60.0)
    client = ReplayClient("127.0.0.1", args.serve_port,
                          timeout=args.rpc_timeout,
                          retry=RetryPolicy(base=0.1, cap=3.0,
                                            deadline=args.retry_deadline,
                                            seed=args.seed + args.actor_id))
    # the learner publishes v1 before sampling — actors start on a real
    # policy, never on their own uninitialized weights
    out = client.get_params(min_version=1, timeout=120.0)
    agent_state = agent.init(jax.random.PRNGKey(args.seed))
    agent_state = agent.with_acting_params(
        agent_state, jax.tree.map(jnp.asarray, out["params"]))
    have_version = out["version"]

    sl = init_actor_slice(v_reset, jax.random.PRNGKey(args.seed + 1),
                          args.n_envs, shard_id=args.actor_id)
    key = jax.random.PRNGKey(1000 + args.seed + args.actor_id)
    env_steps0 = jnp.zeros((), jnp.int32)
    chunks = transitions = episodes = 0
    while True:
        sl, key, flat, finished = chunk(agent_state, sl, key, env_steps0)
        fin = np.asarray(finished).ravel()
        rets = [float(r) for r in fin[~np.isnan(fin)]]
        episodes += len(rets)
        reply = client.append(f"actor-{args.actor_id}", flat,
                              returns=rets or None,
                              timeout=args.append_timeout)
        if reply.get("stopped"):
            break
        chunks += 1
        transitions += args.actor_chunk * args.n_envs
        env_steps0 = jnp.asarray(int(reply["inserts"]), jnp.int32)
        if reply["params_version"] > have_version:
            try:
                out = client.get_params(min_version=have_version + 1,
                                        timeout=30.0)
            except RuntimeError:
                # graceful degradation (DESIGN.md §14): a restored
                # server's params version can sit briefly below what a
                # pre-crash reply advertised — keep acting on the
                # last-good params; a later reply re-triggers the pull
                continue
            agent_state = agent.with_acting_params(
                agent_state, jax.tree.map(jnp.asarray, out["params"]))
            have_version = out["version"]
    client.close()
    print(f"ACTOR_ID={args.actor_id}")
    print(f"CHUNKS={chunks}")
    print(f"TRANSITIONS={transitions}")
    print(f"EPISODES={episodes}")
    print(f"PARAMS_VERSION={have_version}")
    print(f"RECONNECTS={client.reconnects}")
    print(f"ACKED_APPENDS={client.acked_appends}")
    print(f"DEDUPED_APPENDS={client.deduped_appends}")


def _eval_policy(agent, agent_state, env_fn, n_envs: int, steps: int,
                 seed: int) -> float:
    """Near-greedy rollout of the learned policy (fresh envs, no replay):
    mean return over every episode that finishes in the window, plus the
    censored running return of any env that outlives the whole window —
    a policy good enough to never terminate must not score 0.0."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.runtime.loop import (LoopConfig, init_actor_slice,
                                    make_actor_program)

    _, v_reset, v_step = env_fn(n_envs)
    cfg = LoopConfig(epsilon=0.01, epsilon_final=0.01)
    program = make_actor_program(agent, v_step, cfg, n_envs)

    def body(sl, k):
        k_act, k_env = jax.random.split(k)
        sl, transitions = program(agent_state, sl, k_act, k_env,
                                  jnp.zeros((), jnp.int32))
        done = transitions["done"] > 0
        return sl, jnp.where(done, sl.last_return, jnp.nan)

    key = jax.random.PRNGKey(seed)
    sl = init_actor_slice(v_reset, jax.random.fold_in(key, 0), n_envs)
    keys = jax.random.split(jax.random.fold_in(key, 1), steps)
    final, fin = jax.jit(lambda s, ks: jax.lax.scan(body, s, ks))(sl, keys)
    fin = np.asarray(fin)                        # (steps, n_envs); NaN = alive
    finished = fin[~np.isnan(fin)]
    # an env with no completed episode in the window (CartPole's 500-step
    # limit exceeds the 250-step eval window, so a strong policy finishes
    # nothing) is scored by its running return — a lower bound, not a 0
    never_done = ~np.any(~np.isnan(fin), axis=0)
    censored = np.asarray(final.episode_return)[never_done]
    rets = np.concatenate([finished, censored])
    return float(rets.mean()) if rets.size else 0.0


def _service_learner_worker(args):
    """``--mode service-learner``: the sampler side — publish params,
    drain rate-limited samples through the learner program, write
    priorities back, checkpoint periodically, and stop the service when
    the learn budget is spent.  With ``--exit-after`` the process
    checkpoints and exits mid-run *without* stopping the service (the
    restart drill); with ``--resume`` it restores the latest checkpoint
    through the elastic reshard path and continues the count."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.runtime.loop import make_learner_program
    from repro.service.client import (ReplayClient, RetryPolicy,
                                      wait_for_service)

    env_fn, _, agent, _ = _dqn_cartpole(args.n_envs)
    learn = jax.jit(make_learner_program(agent))
    agent_state = agent.init(jax.random.PRNGKey(args.seed))
    step0 = 0
    manager = None
    if args.ckpt_dir:
        from repro.checkpoint.manager import CheckpointManager
        manager = CheckpointManager(args.ckpt_dir, keep=3)
        if args.resume:
            from jax.sharding import Mesh, PartitionSpec as P

            from repro.checkpoint.elastic import reshard

            example = {"agent": agent_state,
                       "learn_step": np.zeros((), np.int32)}
            step, restored = manager.restore_latest(example)
            if step is None:
                raise RuntimeError(
                    f"--resume: no checkpoint under {args.ckpt_dir}")
            mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
            specs = {"agent": jax.tree.map(lambda _: P(),
                                           restored["agent"]),
                     "learn_step": None}
            restored = reshard(restored, specs, mesh)
            agent_state = restored["agent"]
            step0 = int(restored["learn_step"])
            print(f"RESUMED_FROM={step0}", flush=True)
    if args.exit_after and manager is None:
        raise RuntimeError("--exit-after requires --ckpt-dir (the resumed "
                           "learner restores from the checkpoint)")

    wait_for_service("127.0.0.1", args.serve_port, timeout=60.0)
    client = ReplayClient("127.0.0.1", args.serve_port,
                          timeout=args.rpc_timeout,
                          retry=RetryPolicy(base=0.1, cap=3.0,
                                            deadline=args.retry_deadline,
                                            seed=args.seed + 1000))
    client.put_params(agent.params_for_acting(agent_state))

    def save(step):
        manager.save(step, {"agent": jax.device_get(agent_state),
                            "learn_step": np.int32(step)})

    learn_step = step0
    last_loss = float("nan")
    while learn_step < args.learn_steps:
        try:
            out = client.sample(args.batch_size, beta=0.4,
                                timeout=args.rpc_timeout)
            if out.get("stopped"):
                break
            agent_state, metrics, td = learn(
                agent_state, jax.tree.map(jnp.asarray, out["items"]),
                jnp.asarray(out["weights"]))
            client.update_priorities(out["sample_id"], np.asarray(td))
            learn_step += 1
            last_loss = float(metrics["loss"])
            if learn_step % args.publish_every == 0:
                client.put_params(agent.params_for_acting(agent_state))
        except ConnectionError:
            # bounded degradation (DESIGN.md §14): the client already
            # spent its full reconnect-retry budget — the service is
            # gone for good.  Checkpoint what we have and exit cleanly
            # instead of hanging the gang.
            if manager is not None:
                save(learn_step)
            client.close()
            print(f"LEARN_STEPS={learn_step}")
            print(f"FINAL_LOSS={last_loss!r}")
            print("SAMPLE_RETRY_EXHAUSTED=1")
            return
        if manager is not None and args.ckpt_every \
                and learn_step % args.ckpt_every == 0:
            save(learn_step)
        if args.exit_after and learn_step - step0 >= args.exit_after \
                and learn_step < args.learn_steps:
            # planned mid-run exit: checkpoint, leave the service up —
            # actors park in writer backpressure until the resumed
            # learner's samples pay the debt back down
            save(learn_step)
            client.close()
            print(f"LEARN_STEPS={learn_step}")
            print("EXITED_EARLY=1")
            return

    client.put_params(agent.params_for_acting(agent_state))
    if manager is not None and args.ckpt_every:
        save(learn_step)
    stats = client.stats()
    eval_ret = _eval_policy(agent, agent_state, env_fn, n_envs=8,
                            steps=250, seed=args.seed + 7)
    client.stop()
    client.close()
    rl = stats.get("rate_limiter", {})
    print(f"LEARN_STEPS={learn_step}")
    print(f"FINAL_LOSS={last_loss!r}")
    print(f"EVAL_RETURN={eval_ret!r}")
    print(f"PARAMS_CHECKSUM={_params_checksum(agent_state.params)!r}")
    print(f"MEAN_RECENT_RETURN={stats['mean_recent_return']!r}")
    print(f"SERVICE_INSERTS={stats['inserts']}")
    print(f"SERVICE_SAMPLES={stats['samples']}")
    print(f"REALIZED_SPI={rl.get('realized_spi', 0.0)!r}")


# -- entry -------------------------------------------------------------------


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="wall-clock multi-process worker (spawned by launch())")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of the jax.distributed coordinator "
                         "(process 0 binds it); required for the SPMD "
                         "modes, unused by the replay-service roles")
    ap.add_argument("--n-procs", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--handshake-timeout", type=float,
                    default=HANDSHAKE_TIMEOUT_S)
    ap.add_argument("--mode",
                    choices=("bench", "fused", "equiv", "replay-server",
                             "service-actor", "service-learner"),
                    default="bench")
    ap.add_argument("--n-pods", type=int, default=1)
    ap.add_argument("--n-data", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--overlap", action="store_true")
    ap.add_argument("--publish-interval", type=int, default=0)
    ap.add_argument("--update-interval", type=int, default=1)
    ap.add_argument("--n-envs", type=int, default=16)
    ap.add_argument("--iters", type=int, default=120)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--scan-chunk", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    # replay-service roles (DESIGN.md §11)
    ap.add_argument("--serve-port", type=int, default=0)
    ap.add_argument("--n-shards", type=int, default=1)
    ap.add_argument("--spi", type=float, default=16.0,
                    help="configured samples-per-insert ratio")
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--warmup", type=int, default=512)
    ap.add_argument("--capacity-per-shard", type=int, default=20_000)
    ap.add_argument("--insert-burst", type=int, default=64,
                    help="largest single writer append the band absorbs")
    ap.add_argument("--serve-timeout", type=float, default=600.0)
    ap.add_argument("--actor-id", type=int, default=0)
    ap.add_argument("--actor-chunk", type=int, default=8,
                    help="env steps per jitted actor rollout / append")
    ap.add_argument("--epsilon", type=float, default=0.2)
    ap.add_argument("--learn-steps", type=int, default=1200)
    ap.add_argument("--publish-every", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--exit-after", type=int, default=0,
                    help="learner: checkpoint and exit after this many "
                         "learn steps without stopping the service")
    ap.add_argument("--resume", action="store_true",
                    help="learner: restore the latest checkpoint")
    ap.add_argument("--rpc-timeout", type=float, default=300.0)
    ap.add_argument("--append-timeout", type=float, default=240.0)
    ap.add_argument("--retry-deadline", type=float, default=180.0,
                    help="client reconnect-retry budget per call — must "
                         "cover a full server restart (jax import included)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="server: shard-snapshot directory (DESIGN.md §14)")
    ap.add_argument("--snapshot-every-appends", type=int, default=0,
                    help="server: snapshot period in applied appends "
                         "(1 = durable acks, the restart drill setting)")
    ap.add_argument("--restore-server", action="store_true",
                    help="server: restore the latest shard snapshot from "
                         "--snapshot-dir before serving")
    ap.add_argument("--fault-plan", default=None,
                    help="server: FaultPlan.parse spec, e.g. "
                         "'crash_on_op=append:40,hard=1'")
    args = ap.parse_args(argv)

    service_roles = {"replay-server": _replay_server_worker,
                     "service-actor": _service_actor_worker,
                     "service-learner": _service_learner_worker}
    if args.mode in service_roles:
        # service roles never join jax.distributed: independent runtimes
        # meeting only at the TCP boundary (a dead actor cannot wedge a
        # collective — there are none)
        service_roles[args.mode](args)
        return
    if args.coordinator is None:
        ap.error("--coordinator is required for modes bench/fused/equiv")

    from repro.core.distributed import initialize_distributed

    initialize_distributed(args.coordinator, args.n_procs, args.process_id,
                           timeout_s=args.handshake_timeout)
    {"bench": _bench_worker,
     "fused": _fused_worker,
     "equiv": _equiv_worker}[args.mode](args)


if __name__ == "__main__":
    main()
