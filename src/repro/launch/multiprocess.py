"""Wall-clock multi-process launcher (DESIGN.md §10).

Everything else in this repo emulates a device mesh inside one process
(``--xla_force_host_platform_device_count``), which serializes the
"parallel" shards on the host and makes the fig10 scaling curve a
simulation.  This module launches a real gang: N worker processes, each
owning its own XLA client with ``devices_per_proc`` forced host devices,
joined into one multi-controller SPMD runtime by
``core.distributed.initialize_distributed`` (gloo collectives on CPU).
After the handshake ``jax.devices()`` spans the whole gang in process
order, so the existing ``launch.mesh`` constructors and the shard_map
executors run unchanged — each process executes its addressable mesh
cells and the gradient reduce crosses real process boundaries.

Parent side (``launch``): picks a free coordinator port, spawns
``python -m repro.launch.multiprocess`` once per process id with
per-worker ``XLA_FLAGS``/``PYTHONPATH`` env, streams and collects
stdout, and raises with the failing worker's tail on non-zero exit.
Results travel as ``KEY=VALUE`` lines on process 0's stdout
(``parse_kv``) — the same convention as fig10's emulated pod workers.

Worker side (``main``): initializes the distributed runtime, then runs
one of three workloads:

  * ``--mode bench`` — DQN/CartPole through ``FusedExecutor`` (1 total
    device) or ``ShardedExecutor`` (data or pod×data mesh over the
    gang's global devices, optionally int8-compressed and/or overlapped
    cross-pod reduce), timed median-of-``--repeats`` with ``rel_spread``
    — the wall-clock arm of benchmarks/fig10_scalability.py.  With
    ``--publish-interval P > 0`` the async double buffer is republished
    *externally*: between chunks the fresh params make a real
    device→host→device round trip (``external_publish``) instead of the
    in-program copy.
  * ``--mode fused`` — the degenerate single-process launch: the exact
    ``FusedExecutor.train`` program, printing final metrics and a
    parameter checksum.  Bit-exact against the same executor run
    in-process (tests/test_multiprocess.py): the distributed runtime at
    N=1 must be a no-op.
  * ``--mode equiv`` — 2-process reducer equivalence: the overlapped
    and barrier cross-pod reduces driven over the same per-pod gradient
    streams through real cross-process collectives; process 0 prints
    the shift-identity and telescoping errors
    (tests/test_distributed.py).

Chunks are bracketed with ``jax.profiler.StepTraceAnnotation`` step
markers so profile traces segment per chunk.
"""

from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

HANDSHAKE_TIMEOUT_S = 60.0


# -- parent side -------------------------------------------------------------


def free_port() -> int:
    """A port the coordinator can bind (raced, but single-host tests and
    benchmarks re-launch on collision rather than coordinate)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _src_root() -> str:
    # .../src/repro/launch/multiprocess.py → .../src
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def worker_env(devices_per_proc: int) -> Dict[str, str]:
    """Child env: forced per-process host device count (before any jax
    import — the whole reason the launcher is a separate process) and an
    import path that reaches ``repro`` regardless of the parent's cwd."""
    env = os.environ.copy()
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{devices_per_proc}")
    env["JAX_PLATFORMS"] = "cpu"
    path = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = _src_root() + (os.pathsep + path if path else "")
    return env


def launch(
    worker_args: Sequence[str],
    n_procs: int,
    devices_per_proc: int = 1,
    coordinator: Optional[str] = None,
    timeout_s: float = 900.0,
    handshake_timeout_s: float = HANDSHAKE_TIMEOUT_S,
) -> List[str]:
    """Spawn the full ``n_procs`` gang and return per-process stdout
    (index = process id).  Raises ``RuntimeError`` with the failing
    worker's output tail if any exits non-zero or overruns
    ``timeout_s``."""
    if n_procs < 1:
        raise ValueError(f"n_procs={n_procs}: need ≥ 1")
    coordinator = coordinator or f"127.0.0.1:{free_port()}"
    env = worker_env(devices_per_proc)
    procs = []
    for pid in range(n_procs):
        cmd = [sys.executable, "-m", "repro.launch.multiprocess",
               "--coordinator", coordinator,
               "--n-procs", str(n_procs),
               "--process-id", str(pid),
               "--handshake-timeout", str(handshake_timeout_s),
               *worker_args]
        procs.append(subprocess.Popen(
            cmd, env=env, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True))
    outs: List[str] = [""] * n_procs
    deadline = time.monotonic() + timeout_s
    failed = None
    for pid, p in enumerate(procs):
        left = max(1.0, deadline - time.monotonic())
        try:
            outs[pid], _ = p.communicate(timeout=left)
        except subprocess.TimeoutExpired:
            p.kill()
            outs[pid], _ = p.communicate()
            failed = failed or (pid, "timeout")
        if p.returncode not in (0, None) and failed is None:
            failed = (pid, f"exit code {p.returncode}")
    if failed is not None:
        # one worker down wedges the rest at the next collective — kill
        # the whole gang before reporting
        for p in procs:
            if p.poll() is None:
                p.kill()
        pid, why = failed
        tail = "\n".join(outs[pid].splitlines()[-25:])
        raise RuntimeError(
            f"wall-clock worker {pid}/{n_procs} failed ({why}); output "
            f"tail:\n{tail}")
    return outs


def parse_kv(text: str) -> Dict[str, str]:
    """The ``KEY=VALUE`` result lines a worker prints (keys are
    UPPER_SNAKE by convention; later lines win)."""
    out: Dict[str, str] = {}
    for line in text.splitlines():
        line = line.strip()
        if "=" in line and line.split("=", 1)[0].replace("_", "").isupper():
            k, v = line.split("=", 1)
            out[k] = v
    return out


# -- worker side -------------------------------------------------------------


def _median_spread(samples: Sequence[float]):
    """(median, (max−min)/median) — the rel_spread convention of
    benchmarks/timing.py, inlined because ``benchmarks`` is not
    importable from ``src``."""
    xs = sorted(samples)
    n = len(xs)
    med = (xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2]))
    spread = (xs[-1] - xs[0]) / med if med else 0.0
    return med, spread


def _dqn_cartpole(n_envs_local_hint: int):
    """The benchmark workload everything wall-clock measures: DQN on
    vectorized CartPole (matches fig10's emulated arms)."""
    import functools

    import jax.numpy as jnp

    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.envs.classic import make_vec

    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    del n_envs_local_hint
    return env_fn, spec, agent, example


def _build_executor(args):
    import jax

    from repro.core.distributed import ShardedPrioritizedReplay, \
        ShardedReplayConfig
    from repro.core.replay import PrioritizedReplay, ReplayConfig
    from repro.launch.mesh import data_mesh, pod_data_mesh
    from repro.runtime.executors import FusedExecutor, ShardedExecutor
    from repro.runtime.loop import LoopConfig

    env_fn, spec, agent, example = _dqn_cartpole(args.n_envs)
    cfg = LoopConfig(batch_size=64, warmup=64, epsilon=0.1,
                     update_interval=args.update_interval)
    n_cells = args.n_pods * args.n_data
    if n_cells != jax.device_count():
        raise RuntimeError(
            f"mesh {args.n_pods}x{args.n_data} wants {n_cells} cells but "
            f"the gang exposes {jax.device_count()} global devices "
            f"({jax.process_count()} procs × "
            f"{len(jax.local_devices())} local)")
    external = args.publish_interval > 0
    if n_cells == 1:
        replay = PrioritizedReplay(
            ReplayConfig(capacity=50_000, fanout=128), example)
        return FusedExecutor(agent, replay, env_fn, cfg, args.n_envs,
                             scan_chunk=args.scan_chunk,
                             publish_interval=args.publish_interval,
                             external_publish=external)
    if args.n_pods > 1:
        mesh, axes = pod_data_mesh(args.n_pods, args.n_data), ("pod", "data")
    else:
        mesh, axes = data_mesh(args.n_data), ("data",)
    replay = ShardedPrioritizedReplay(
        ShardedReplayConfig(capacity_per_shard=50_000 // n_cells,
                            fanout=128, axis_names=axes), example)
    return ShardedExecutor(agent, replay, env_fn, cfg, args.n_envs, mesh,
                           scan_chunk=args.scan_chunk,
                           publish_interval=args.publish_interval,
                           compress_pod_reduce=args.compress,
                           overlap_pod_reduce=args.overlap,
                           external_publish=external)


def _publish_host_roundtrip(ex, state):
    """The real device→host→device parameter publish of the wall-clock
    async mode: fetch the fresh learner params to the host (a true D2H
    transfer — ``jax.device_get`` materializes numpy), then rebuild the
    per-shard acting copies and zero the ages.  Replaces the in-program
    ``jnp.where`` republish (``make_step(external_publish=True)``)."""
    import jax
    import numpy as np

    host = jax.device_get(ex.agent.params_for_acting(state.agent))

    def republish(old, fresh):
        fresh = np.asarray(fresh)
        if old.shape == fresh.shape:        # fused path: plain put
            return jax.device_put(fresh, old.sharding)
        # sharded path: leading shard dim — broadcast the host copy into
        # every shard's slot of the global array
        wide = np.broadcast_to(fresh[None], old.shape)
        return jax.make_array_from_callback(
            old.shape, old.sharding, lambda idx: wide[idx])

    actor_params = jax.tree.map(republish, state.actor_params, host)
    age = state.params_age
    zero = np.zeros(age.shape, dtype=np.int32)
    params_age = jax.make_array_from_callback(
        age.shape, age.sharding, lambda idx: zero[idx])
    return state._replace(actor_params=actor_params, params_age=params_age)


def _bench_worker(args):
    import jax

    ex = _build_executor(args)
    pid = jax.process_index()
    publish = args.publish_interval

    def run_iters(state, iters, base_step):
        done = 0
        while done < iters:
            length = min(publish or ex.scan_chunk, iters - done)
            with jax.profiler.StepTraceAnnotation(
                    "wallclock_chunk", step_num=base_step + done):
                state, metrics = ex.run_chunk(state, length)
            if publish:
                state = _publish_host_roundtrip(ex, state)
            done += length
        return state, metrics

    state = ex.init(jax.random.PRNGKey(args.seed))
    # warmup compiles every chunk length the timed loop will use
    state, _ = run_iters(state, args.iters, 0)
    samples = []
    for r in range(args.repeats):
        t0 = time.perf_counter()
        state, metrics = run_iters(state, args.iters, (r + 1) * args.iters)
        jax.block_until_ready(metrics["env_steps"])
        dt = time.perf_counter() - t0
        samples.append(args.n_envs * args.iters / dt)
    med, spread = _median_spread(samples)
    if pid == 0:
        print(f"STEPS_PER_S={med:.2f}")
        print(f"REL_SPREAD={spread:.4f}")
        print(f"REPEATS={args.repeats}")
        print(f"ENV_STEPS={int(jax.device_get(metrics['env_steps'])[-1])}")


def _fused_worker(args):
    import jax

    if jax.process_count() != 1:
        raise RuntimeError("--mode fused is the degenerate single-process "
                           f"launch; got {jax.process_count()} procs")
    ex = _build_executor(args)
    state, hist = ex.train(args.iters, jax.random.PRNGKey(args.seed))
    params = jax.device_get(state.agent.params)
    checksum = 0.0
    for leaf in jax.tree.leaves(params):
        checksum += float(abs(leaf.astype("float64")).sum())
    print(f"FINAL_LOSS={float(hist['loss'][-1])!r}")
    print(f"FINAL_RETURN={float(hist['mean_episode_return'][-1])!r}")
    print(f"ENV_STEPS={int(hist['env_steps'][-1])}")
    print(f"PARAMS_CHECKSUM={checksum!r}")


def _equiv_worker(args):
    """Overlapped vs barrier cross-pod reduce over *real* 2-process
    collectives: same per-pod gradient streams, checked in-program
    (replicated scalar outputs — per-pod intermediates are never pulled
    to the host, which multi-controller mode would reject):

      * shift identity — on a constant stream, overlapped event t
        equals barrier event t−1 bit-exactly;
      * telescoping — on a varying stream the cumulative applied
        difference collapses to ``p_T − pm_T`` (one gradient's pod
        disagreement, not T of them).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.runtime.learner import make_grad_reducer

    if jax.device_count() != 2:
        raise RuntimeError(f"--mode equiv wants a 2-device (pod) gang, "
                           f"got {jax.device_count()}")
    mesh = Mesh(np.asarray(jax.devices()).reshape(2), ("pod",))
    barrier = make_grad_reducer(("pod",), compress_axis="pod")
    overlap = make_grad_reducer(("pod",), compress_axis="pod", overlap=True)
    T = 8

    def program(gc, gs):
        # local views: gc (1, dim), gs (T, 1, dim) — one pod per process
        z = jnp.zeros_like(gc)

        def b_chain(stream):
            ef, outs = z, []
            for g in stream:
                out, ef = barrier(g, None, ef)
                outs.append(out)
            return outs

        def o_chain(stream):
            ef = {"ef": z, "prev_mean": z, "prev_partial": z}
            outs = []
            for g in stream:
                out, ef = overlap(g, None, ef)
                outs.append(out)
            return outs

        const = [gc] * 6
        ob, oo = b_chain(const), o_chain(const)
        shift = jnp.max(jnp.stack(
            [jnp.max(jnp.abs(oo[t] - ob[t - 1])) for t in range(1, 6)]))

        varying = [gs[t] for t in range(T)]
        vb, vo = b_chain(varying), o_chain(varying)
        cum_diff = sum(vo) - sum(vb)
        # n_data = 1 ⇒ the intra-pod partial is the local gradient itself
        tele = jnp.max(jnp.abs(cum_diff - (varying[-1] - vb[-1])))
        return jax.lax.pmax(shift, "pod"), jax.lax.pmax(tele, "pod")

    run = jax.jit(shard_map(
        program, mesh=mesh, in_specs=(P("pod"), P(None, "pod")),
        out_specs=(P(), P()), check_rep=False))

    # identical host-side streams on every process, sharded pod-major
    dim = 16
    rng = np.random.RandomState(args.seed)
    gc_host = rng.randn(2, 1, dim).astype(np.float32)
    gs_host = rng.randn(T, 2, 1, dim).astype(np.float32)

    def gshard(x, spec):
        sh = NamedSharding(mesh, spec)
        return jax.make_array_from_callback(x.shape, sh, lambda idx: x[idx])

    shift, tele = run(gshard(gc_host, P("pod")),
                      gshard(gs_host, P(None, "pod")))
    if jax.process_index() == 0:
        print(f"SHIFT_MAX_ABS_ERR={float(jax.device_get(shift))!r}")
        print(f"TELESCOPE_MAX_ABS_ERR={float(jax.device_get(tele))!r}")


# -- entry -------------------------------------------------------------------


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="wall-clock multi-process worker (spawned by launch())")
    ap.add_argument("--coordinator", required=True,
                    help="host:port of the jax.distributed coordinator "
                         "(process 0 binds it)")
    ap.add_argument("--n-procs", type=int, required=True)
    ap.add_argument("--process-id", type=int, required=True)
    ap.add_argument("--handshake-timeout", type=float,
                    default=HANDSHAKE_TIMEOUT_S)
    ap.add_argument("--mode", choices=("bench", "fused", "equiv"),
                    default="bench")
    ap.add_argument("--n-pods", type=int, default=1)
    ap.add_argument("--n-data", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--overlap", action="store_true")
    ap.add_argument("--publish-interval", type=int, default=0)
    ap.add_argument("--update-interval", type=int, default=1)
    ap.add_argument("--n-envs", type=int, default=16)
    ap.add_argument("--iters", type=int, default=120)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--scan-chunk", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.core.distributed import initialize_distributed

    initialize_distributed(args.coordinator, args.n_procs, args.process_id,
                           timeout_s=args.handshake_timeout)
    {"bench": _bench_worker,
     "fused": _fused_worker,
     "equiv": _equiv_worker}[args.mode](args)


if __name__ == "__main__":
    main()
