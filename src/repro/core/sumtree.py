"""K-ary sum tree — the paper's core data structure (§IV), in JAX.

Layout (paper §IV-C4, adapted to TPU):
  * Implicit, pointer-free: one flat f32 array holding all levels
    concatenated top-down.  ``offsets[l]`` is the start of level ``l``.
  * Every sibling group of K children is contiguous and starts at a
    multiple of K.  On CPU the paper aligns groups to cache lines
    (``K % C == 0``); on TPU we align to the 128-lane vector register row
    (default ``K = 128``), so one descent step reads exactly one aligned
    (1, 128) row — the TPU analogue of "one cache line per level".
  * The root is padded to a full group of K ("pad the root node with K-1
    so that it is also cache aligned") — level 0 has K slots, root at 0.
  * One extra scratch slot is appended at the very end of the flat array;
    masked (duplicate) writes are dumped there, keeping every update a
    branch-free scatter.

Level sizes, bottom-up: ``m_H = ceil(N / K) * K`` leaves; each level above
has one node per child group, padded to a multiple of K; the topmost
non-root level has exactly K nodes (one group), whose parent is the root.

All operations are *batched*: the paper's asynchronous parallel
insert/sample/update from many threads becomes one data-parallel program
over B operations (DESIGN.md §2).  Batch semantics are defined to match
sequential application:
  * ``update``: duplicate indices resolve last-writer-wins;
  * ``sample``: pure read, order-free;
  * ``add``: duplicate indices accumulate.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_FANOUT = 128  # one VREG lane row; paper: K % cacheline == 0.


def _ceil_to(x: int, k: int) -> int:
    return ((x + k - 1) // k) * k


@dataclasses.dataclass(frozen=True)
class SumTreeSpec:
    """Static description of a K-ary sum tree (shapes only, no arrays)."""

    capacity: int            # number of usable leaves (N)
    fanout: int              # K
    level_sizes: Tuple[int, ...]   # padded node count per level, top-down
    offsets: Tuple[int, ...]       # flat-array offset of each level
    total_size: int                # flat array length (incl. scratch slot)

    @property
    def height(self) -> int:
        """Number of levels below the padded-root level."""
        return len(self.level_sizes) - 1

    @property
    def leaf_level(self) -> int:
        return len(self.level_sizes) - 1

    @property
    def leaf_offset(self) -> int:
        return self.offsets[self.leaf_level]

    @property
    def num_leaves(self) -> int:
        return self.level_sizes[self.leaf_level]

    @property
    def scratch_slot(self) -> int:
        return self.total_size - 1

    def groups(self, level: int) -> int:
        return self.level_sizes[level] // self.fanout


def make_spec(capacity: int, fanout: int = DEFAULT_FANOUT) -> SumTreeSpec:
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    if fanout < 2:
        raise ValueError(f"fanout must be >= 2, got {fanout}")
    sizes: List[int] = [_ceil_to(capacity, fanout)]
    # Build upward until a single group of K remains.
    while sizes[0] > fanout:
        groups = sizes[0] // fanout
        sizes.insert(0, _ceil_to(groups, fanout))
    # Padded root level (paper: root padded to one full group).
    sizes.insert(0, fanout)
    offsets = list(np.cumsum([0] + sizes[:-1]))
    total = int(np.sum(sizes)) + 1  # +1 scratch slot for masked writes
    return SumTreeSpec(
        capacity=capacity,
        fanout=fanout,
        level_sizes=tuple(int(s) for s in sizes),
        offsets=tuple(int(o) for o in offsets),
        total_size=total,
    )


def init(spec: SumTreeSpec, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros((spec.total_size,), dtype=dtype)


def total(spec: SumTreeSpec, tree: jax.Array) -> jax.Array:
    """Σ priorities — the root value, Θ(1) (paper §IV-A2)."""
    return tree[0]


def get(spec: SumTreeSpec, tree: jax.Array, idx: jax.Array) -> jax.Array:
    """Priority retrieval, Θ(1) per index (paper §IV-C1)."""
    return tree[spec.leaf_offset + idx]


def last_writer_mask(idx: jax.Array, num_slots: int | None = None) -> jax.Array:
    """mask[i] = True iff no j > i has idx[j] == idx[i].

    Resolves duplicate indices in a batched update to sequential
    last-writer-wins semantics (DESIGN.md §2: lock-free conflict
    resolution).  Sort-based, O(B log B): sort (idx, position) pairs and
    mark the last entry of each equal-idx run (replaces the old O(B²)
    broadcast compare, which scaled quadratically with the op batch).

    ``num_slots`` — an exclusive upper bound on the index values — lets
    the two sort keys pack into one int32 (``idx * B + pos``), which XLA
    sorts substantially faster than a stable two-operand sort; without
    it (or when the packing would overflow int32) the stable key/value
    sort is used.  Both paths produce identical masks.
    """
    b = idx.shape[0]
    if b <= 1:
        return jnp.ones((b,), bool)
    idx = jnp.asarray(idx, jnp.int32)
    pos = jnp.arange(b, dtype=jnp.int32)
    if num_slots is not None and num_slots * b < 2**31:
        packed = jax.lax.sort(idx * b + pos)
        sidx, spos = packed // b, packed % b
    else:
        sidx, spos = jax.lax.sort_key_val(idx, pos, is_stable=True)
    run_end = jnp.concatenate([sidx[1:] != sidx[:-1], jnp.ones((1,), bool)])
    return jnp.zeros((b,), bool).at[spos].set(run_end)


def _ancestor_indices(spec: SumTreeSpec, idx: jax.Array) -> List[jax.Array]:
    """Node index of ``idx``'s ancestor at every level, top-down.

    Leaf i's parent at level H-1 is node i // K; and so on up.  Level 0 is
    the padded root (node 0 always).
    """
    out = [idx]
    cur = idx
    for _ in range(spec.leaf_level - 1, -1, -1):
        cur = cur // spec.fanout
        out.append(cur)
    return out[::-1]  # top-down: [root(=0s), ..., leaf idx]


def update(
    spec: SumTreeSpec,
    tree: jax.Array,
    idx: jax.Array,
    values: jax.Array,
    *,
    unique: bool = False,
) -> jax.Array:
    """Batched priority SET (paper Alg. 2 UPDATEVALUE, vectorized).

    Sequential-equivalent semantics under duplicates (last writer wins).
    Θ((B + dedup) · log_K N) work; every scatter group is K-aligned.
    ``unique=True`` skips the dedup when the caller guarantees distinct
    indices (e.g. FIFO insert slots).

    This is the *eager* path: leaf write and upward propagation in one
    op.  The lazy-writing transaction path (``write_leaves`` + one
    ``rebuild`` per flush boundary, core/replay.py) coalesces many such
    ops into a single propagation pass per step.
    """
    idx = jnp.asarray(idx, jnp.int32)
    values = jnp.asarray(values, tree.dtype)
    mask = (jnp.ones(idx.shape, bool) if unique
            else last_writer_mask(idx, spec.num_leaves))
    old = tree[spec.leaf_offset + idx]
    delta = jnp.where(mask, values - old, jnp.zeros_like(values))
    # Leaf SET: masked duplicates are diverted to the scratch slot.
    leaf_target = jnp.where(mask, spec.leaf_offset + idx, spec.scratch_slot)
    tree = tree.at[leaf_target].set(values)
    # Upward delta propagation: scatter-ADD per level (duplicates sum).
    ancestors = _ancestor_indices(spec, idx)
    for level in range(spec.leaf_level - 1, -1, -1):
        node = ancestors[level]
        tree = tree.at[spec.offsets[level] + node].add(delta)
    return tree.at[spec.scratch_slot].set(0.0)


def write_leaves(
    spec: SumTreeSpec,
    tree: jax.Array,
    idx: jax.Array,
    values: jax.Array,
    *,
    unique: bool = False,
) -> jax.Array:
    """Leaf-only priority SET — the deferred half of a lazy write.

    Writes ``values`` into the leaf level (duplicates resolve
    last-writer-wins) and touches *nothing* above it: after this call
    the tree's interior no longer sums its leaves until ``rebuild``
    runs.  ``core/replay.py`` counts these deferred writes in its
    pending-delta ledger and flushes them in one merged propagation
    pass at the next sample boundary (paper §IV-D lazy writing).
    """
    idx = jnp.asarray(idx, jnp.int32)
    values = jnp.asarray(values, tree.dtype)
    if unique:
        return tree.at[spec.leaf_offset + idx].set(values)
    mask = last_writer_mask(idx, spec.num_leaves)
    leaf_target = jnp.where(mask, spec.leaf_offset + idx, spec.scratch_slot)
    tree = tree.at[leaf_target].set(values)
    return tree.at[spec.scratch_slot].set(0.0)


def rebuild(spec: SumTreeSpec, tree: jax.Array) -> jax.Array:
    """Recompute every interior level from the leaf level — one upward
    propagation pass (the ``TreeOps.flush`` payload).

    The interior becomes a *pure function of the current leaves*
    (K-aligned reshape-sums, the same reduction ``build`` uses), which
    is what makes lazy ≡ eager **bit-exact** at flush points: flushing
    after every write and flushing once after many writes reach the
    identical tree, because neither depends on the write history.  A
    side benefit over incremental delta propagation: f32 drift between
    interior sums and leaf sums cannot accumulate across steps.
    """
    level_vals = jax.lax.dynamic_slice(
        tree, (spec.leaf_offset,), (spec.num_leaves,))
    for level in range(spec.leaf_level - 1, -1, -1):
        groups = level_vals.shape[0] // spec.fanout
        parents = level_vals.reshape(groups, spec.fanout).sum(axis=-1)
        padded = jnp.zeros((spec.level_sizes[level],), tree.dtype)
        padded = padded.at[:groups].set(parents)
        tree = jax.lax.dynamic_update_slice(tree, padded,
                                            (spec.offsets[level],))
        level_vals = padded
    return tree


def add(
    spec: SumTreeSpec,
    tree: jax.Array,
    idx: jax.Array,
    deltas: jax.Array,
) -> jax.Array:
    """Batched priority increment (duplicates accumulate)."""
    idx = jnp.asarray(idx, jnp.int32)
    deltas = jnp.asarray(deltas, tree.dtype)
    ancestors = _ancestor_indices(spec, idx)
    for level in range(spec.leaf_level, -1, -1):
        tree = tree.at[spec.offsets[level] + ancestors[level]].add(deltas)
    return tree


def sample(
    spec: SumTreeSpec,
    tree: jax.Array,
    u: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Batched prefix-sum descent (paper Alg. 2 GETPREFIXSUMIDX).

    ``u`` ∈ [0, 1): B uniform draws.  Returns (leaf_idx, leaf_priority).
    Per level, reads exactly one K-aligned sibling row per sample and
    finds the cutoff node (Theorem 2) with a vectorized cumsum+argmax —
    the lane-parallel analogue of the paper's linear child scan.
    """
    u = jnp.asarray(u, tree.dtype)
    residual = jnp.clip(u, 1e-12, 1.0 - 1e-7) * tree[0]
    group = jnp.zeros(u.shape, jnp.int32)  # start: children of root = group 0
    k = spec.fanout

    for level in range(1, spec.leaf_level + 1):
        base = spec.offsets[level] + group * k

        def read_row(b):
            return jax.lax.dynamic_slice(tree, (b,), (k,))

        rows = jax.vmap(read_row)(base)            # (B, K) sibling rows
        csum = jnp.cumsum(rows, axis=-1)           # lane-parallel scan
        hit = csum >= residual[:, None]
        cutoff = jnp.argmax(hit, axis=-1).astype(jnp.int32)
        # No-hit (fp rounding at the tail): clamp to last child.
        cutoff = jnp.where(jnp.any(hit, axis=-1), cutoff, k - 1)
        picked = jnp.take_along_axis(csum, cutoff[:, None], axis=-1)[:, 0]
        row_val = jnp.take_along_axis(rows, cutoff[:, None], axis=-1)[:, 0]
        residual = residual - (picked - row_val)   # subtract prefix before cutoff
        group = group * k + cutoff

    leaf = jnp.minimum(group, spec.capacity - 1)
    return leaf, tree[spec.leaf_offset + leaf]


def build(spec: SumTreeSpec, priorities: jax.Array) -> jax.Array:
    """Bulk-build a tree from a dense (capacity,) priority vector."""
    pri = jnp.zeros((spec.num_leaves,), priorities.dtype)
    pri = pri.at[: spec.capacity].set(priorities)
    tree = init(spec, priorities.dtype)
    tree = jax.lax.dynamic_update_slice(tree, pri, (spec.leaf_offset,))
    return rebuild(spec, tree)


def leaves(spec: SumTreeSpec, tree: jax.Array) -> jax.Array:
    """Dense view of all usable leaf priorities, shape (capacity,)."""
    return jax.lax.dynamic_slice(tree, (spec.leaf_offset,), (spec.capacity,))


def check_invariant(spec: SumTreeSpec, tree: jax.Array, atol=1e-3) -> bool:
    """Every parent equals the sum of its children (test helper)."""
    t = np.asarray(tree)
    for level in range(spec.leaf_level):
        lo, size = spec.offsets[level], spec.level_sizes[level]
        nxt_lo, nxt_size = spec.offsets[level + 1], spec.level_sizes[level + 1]
        groups = nxt_size // spec.fanout
        child_sums = t[nxt_lo : nxt_lo + nxt_size].reshape(groups, spec.fanout).sum(-1)
        parents = t[lo : lo + size]
        if not np.allclose(parents[:groups], child_sums, atol=atol, rtol=1e-4):
            return False
        if level == 0 and not np.allclose(parents[1:], 0.0, atol=atol):
            return False
        if not np.allclose(parents[groups:], 0.0, atol=atol):
            return False
    return True
