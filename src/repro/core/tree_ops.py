"""Sum-tree op backends (DESIGN.md §4.2): one protocol, two impls.

The replay buffer (single-shard and sharded alike) dispatches its hot
tree/storage operations through a ``TreeOps`` object instead of
branching on a backend flag at every call site:

  * ``xla``    — the pure-jnp reference path (core/sumtree.py + take);
  * ``pallas`` — the Pallas kernels (kernels/ops.py), which themselves
    fall back to XLA above the VMEM working-set budget.

Both backends implement identical batched semantics (last-writer-wins
update, exact inverse-CDF sample), so they are interchangeable inside
jit, vmap, scan and shard_map.

The replay-transaction ops (DESIGN.md §9) split the eager ``update``
into its two halves:

  * ``write_leaves`` — leaf-only SET, upward propagation deferred;
  * ``flush``        — one merged propagation pass (interior rebuild
    from the leaf level).

Both backends share the XLA implementations of these two on purpose:
a leaf write is one small scatter and the flush is a dense K-aligned
reshape-sum sweep — regular-access patterns XLA already compiles
optimally.  The Pallas kernels earn their keep on the *irregular*
accesses: the inverse-CDF descent, the scattered eager update, and the
fused sample+gather (``sample_gather``), which runs the descent and the
storage-row fetch in one kernel so the sampled indices never round-trip
through HBM between two kernel launches.
"""

from __future__ import annotations

from typing import Any, Protocol, Tuple, runtime_checkable

import jax

from repro.core import sumtree
from repro.core.sumtree import SumTreeSpec

Pytree = Any


def default_fused_sample_gather() -> bool:
    """Backend-appropriate default for ``ReplayConfig.fused_sample_gather
    = None``: the fused descent+gather kernel pays off only where it
    actually *compiles* (TPU Mosaic — the sampled indices stay in VMEM
    between the tree walk and the row fetch).  On CPU Pallas refuses to
    compile ("Only interpret mode is supported on CPU backend") and
    interpret mode inverts the advantage — per-grid-step Python
    interpretation makes the fused arm ~4× slower than split sample +
    gather (BENCH_replay.json, ``fused_compiled`` record) — so non-TPU
    hosts default to the split path."""
    return jax.default_backend() == "tpu"


@runtime_checkable
class TreeOps(Protocol):
    """Backend protocol for batched sum-tree + storage ops."""

    name: str

    def update(self, spec: SumTreeSpec, tree: jax.Array, idx: jax.Array,
               values: jax.Array, unique: bool = False) -> jax.Array:
        """Eager batched priority SET (duplicates: last writer wins),
        leaf write + upward propagation in one op.  ``unique=True``
        skips the dedup for caller-guaranteed distinct indices."""
        ...

    def write_leaves(self, spec: SumTreeSpec, tree: jax.Array,
                     idx: jax.Array, values: jax.Array,
                     unique: bool = False) -> jax.Array:
        """Lazy batched priority SET: leaf level only, propagation
        deferred until the next ``flush``."""
        ...

    def flush(self, spec: SumTreeSpec, tree: jax.Array) -> jax.Array:
        """One merged upward propagation pass: rebuild every interior
        level from the current leaves (bit-exact regardless of how many
        ``write_leaves`` batches are outstanding)."""
        ...

    def sample(self, spec: SumTreeSpec, tree: jax.Array, u: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
        """Batched inverse-CDF descent → (leaf_idx, leaf_priority)."""
        ...

    def gather(self, storage: jax.Array, idx: jax.Array) -> jax.Array:
        """out[i] = storage[idx[i]] for one storage leaf."""
        ...

    def sample_gather(self, spec: SumTreeSpec, tree: jax.Array,
                      u: jax.Array, storage: Pytree
                      ) -> Tuple[jax.Array, jax.Array, Pytree]:
        """Fused descent + storage fetch → (idx, priority, items): the
        paper's irregular-memory-access fix — sampled rows are gathered
        in the same pass that finds them."""
        ...


class XlaTreeOps:
    """Pure-jnp reference backend."""

    name = "xla"

    def update(self, spec, tree, idx, values, unique=False):
        return sumtree.update(spec, tree, idx, values, unique=unique)

    def write_leaves(self, spec, tree, idx, values, unique=False):
        return sumtree.write_leaves(spec, tree, idx, values, unique=unique)

    def flush(self, spec, tree):
        return sumtree.rebuild(spec, tree)

    def sample(self, spec, tree, u):
        return sumtree.sample(spec, tree, u)

    def gather(self, storage, idx):
        return storage[idx]

    def sample_gather(self, spec, tree, u, storage):
        idx, pri = sumtree.sample(spec, tree, u)
        items = jax.tree.map(lambda buf: buf[idx], storage)
        return idx, pri, items


class PallasTreeOps:
    """Pallas-kernel backend (interpret mode on CPU, Mosaic on TPU).

    ``write_leaves``/``flush`` intentionally reuse the XLA
    implementations (regular-access ops — see module docstring); the
    kernels cover the irregular ones: eager update, descent, gather,
    and the fused ``sample_gather``.
    """

    name = "pallas"

    def __init__(self):
        from repro.kernels import ops as kernel_ops  # lazy: pallas import
        self._kops = kernel_ops

    def update(self, spec, tree, idx, values, unique=False):
        return self._kops.sumtree_update(spec, tree, idx, values,
                                         unique=unique)

    def write_leaves(self, spec, tree, idx, values, unique=False):
        return sumtree.write_leaves(spec, tree, idx, values, unique=unique)

    def flush(self, spec, tree):
        return sumtree.rebuild(spec, tree)

    def sample(self, spec, tree, u):
        return self._kops.sumtree_sample(spec, tree, u)

    def gather(self, storage, idx):
        return self._kops.prioritized_gather(storage, idx)

    def sample_gather(self, spec, tree, u, storage):
        return self._kops.sumtree_sample_gather(spec, tree, u, storage)


_BACKENDS = {"xla": XlaTreeOps, "pallas": PallasTreeOps}


def get_tree_ops(backend: str) -> TreeOps:
    try:
        return _BACKENDS[backend]()
    except KeyError:
        raise ValueError(
            f"unknown tree-ops backend {backend!r}; expected one of "
            f"{sorted(_BACKENDS)}") from None
