"""Sum-tree op backends (DESIGN.md §4.2): one protocol, two impls.

The replay buffer (single-shard and sharded alike) dispatches its three
hot tree/storage operations through a ``TreeOps`` object instead of
branching on ``use_kernels`` at every call site:

  * ``xla``    — the pure-jnp reference path (core/sumtree.py + take);
  * ``pallas`` — the Pallas kernels (kernels/ops.py), which themselves
    fall back to XLA above the VMEM working-set budget.

Both backends implement identical batched semantics (last-writer-wins
update, exact inverse-CDF sample), so they are interchangeable inside
jit, vmap, scan and shard_map.
"""

from __future__ import annotations

from typing import Protocol, Tuple, runtime_checkable

import jax

from repro.core import sumtree
from repro.core.sumtree import SumTreeSpec


@runtime_checkable
class TreeOps(Protocol):
    """Backend protocol for batched sum-tree + storage ops."""

    name: str

    def update(self, spec: SumTreeSpec, tree: jax.Array, idx: jax.Array,
               values: jax.Array) -> jax.Array:
        """Batched priority SET (duplicate indices: last writer wins)."""
        ...

    def sample(self, spec: SumTreeSpec, tree: jax.Array, u: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
        """Batched inverse-CDF descent → (leaf_idx, leaf_priority)."""
        ...

    def gather(self, storage: jax.Array, idx: jax.Array) -> jax.Array:
        """out[i] = storage[idx[i]] for one storage leaf."""
        ...


class XlaTreeOps:
    """Pure-jnp reference backend."""

    name = "xla"

    def update(self, spec, tree, idx, values):
        return sumtree.update(spec, tree, idx, values)

    def sample(self, spec, tree, u):
        return sumtree.sample(spec, tree, u)

    def gather(self, storage, idx):
        return storage[idx]


class PallasTreeOps:
    """Pallas-kernel backend (interpret mode on CPU, Mosaic on TPU)."""

    name = "pallas"

    def __init__(self):
        from repro.kernels import ops as kernel_ops  # lazy: pallas import
        self._kops = kernel_ops

    def update(self, spec, tree, idx, values):
        return self._kops.sumtree_update(spec, tree, idx, values)

    def sample(self, spec, tree, u):
        return self._kops.sumtree_sample(spec, tree, u)

    def gather(self, storage, idx):
        return self._kops.prioritized_gather(storage, idx)


_BACKENDS = {"xla": XlaTreeOps, "pallas": PallasTreeOps}


def get_tree_ops(backend: str) -> TreeOps:
    try:
        return _BACKENDS[backend]()
    except KeyError:
        raise ValueError(
            f"unknown tree-ops backend {backend!r}; expected one of "
            f"{sorted(_BACKENDS)}") from None
