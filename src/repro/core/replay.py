"""Prioritized replay buffer with lazy-writing transactions (paper §IV-D).

The paper's thread-safety mechanisms map to functional JAX as follows
(see DESIGN.md §2 and the transaction contract in §9):

  * locks            → batched single-program ops (no shared mutability);
  * lazy writing     → two-phase insert *plus deferred propagation*:
                       every mutation inside one loop iteration
                       (``insert_begin`` zeroes the in-flight slots,
                       ``update_priorities`` writes fresh priorities,
                       ``insert_commit`` restores P_max) touches only
                       the sum tree's *leaf level* eagerly and records
                       itself in the pending-delta ledger
                       (``ReplayState.pending``); the interior levels
                       are brought back in sync by **one** merged
                       propagation pass — ``flush`` — at the next
                       sample boundary.  Because the interior rebuild
                       is a pure function of the current leaves, the
                       flushed tree is bit-exact identical to flushing
                       after every op (lazy ≡ eager at flush points);
  * write-after-read → ``update_priorities`` applies priorities computed
                       at sample time even if inserts landed in between
                       (paper §IV-D3: tolerated transient inconsistency).

Each mutation also keeps its eager form (``lazy=False``, the default):
leaf write and upward propagation in a single op, for callers outside
the runtime loop that want every intermediate state consistent.

Priorities follow PER (Schaul et al., the paper's [24]): stored priority
``p = (|δ| + ε)^α``; importance weights ``w = (N·Pr(i))^(-β) / max_w``.
New insertions receive P_max (paper §IV-A1).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import sumtree, tree_ops
from repro.core.sumtree import SumTreeSpec

Pytree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ReplayState:
    """Functional state of one replay-buffer shard."""

    tree: jax.Array           # flat K-ary sum tree (priorities^α)
    storage: Pytree           # pytree of (capacity, ...) arrays
    head: jax.Array           # int32 — next insert position (FIFO eviction)
    count: jax.Array          # int32 — number of valid entries (≤ capacity)
    max_priority: jax.Array   # f32 — running P_max (already ^α-scaled)
    # pending-delta ledger of the lazy-writing transaction (DESIGN.md §9):
    # number of leaf writes whose upward propagation is deferred.  The
    # deltas themselves live implicitly in the leaf level (leaves are
    # always current; the interior lags until the next flush) — this
    # counter is the ledger head: 0 ⇔ the tree is fully consistent.
    pending: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.zeros((), jnp.int32))


@dataclasses.dataclass(frozen=True)
class ReplayConfig:
    capacity: int
    fanout: int = sumtree.DEFAULT_FANOUT
    alpha: float = 0.6          # priority exponent
    eps: float = 1e-6           # priority floor
    backend: Optional[str] = None   # TreeOps backend: "xla" | "pallas"
                                    # (None = unset → "xla")
    # descend + fetch rows in one op; None → backend-appropriate default
    # (tree_ops.default_fused_sample_gather: True only where the kernel
    # compiles, i.e. TPU — CPU interpret mode inverts the win)
    fused_sample_gather: Optional[bool] = None

    @property
    def tree_backend(self) -> str:
        return self.backend or "xla"

    @property
    def fused_sample_gather_resolved(self) -> bool:
        if self.fused_sample_gather is None:
            return tree_ops.default_fused_sample_gather()
        return self.fused_sample_gather


class PrioritizedReplay:
    """Single-shard prioritized replay buffer (paper §IV).

    All methods are pure functions of ``ReplayState`` and jit-friendly.
    Batched throughout: B parallel inserts / samples / updates per call
    replace the paper's B concurrent threads.

    **Transaction contract** (DESIGN.md §9): with ``lazy=True`` the
    mutating ops write only the tree's leaf level and bump the pending
    ledger; the caller must ``flush`` before the next ``sample`` (the
    runtime loop flushes exactly once per iteration).  With the default
    ``lazy=False`` every op leaves the tree fully consistent.
    """

    def __init__(self, config: ReplayConfig, example_item: Pytree):
        self.config = config
        self.spec: SumTreeSpec = sumtree.make_spec(config.capacity, config.fanout)
        self._example = jax.tree.map(jnp.asarray, example_item)
        self.ops: tree_ops.TreeOps = tree_ops.get_tree_ops(config.tree_backend)

    # -- state ------------------------------------------------------------

    def init(self) -> ReplayState:
        cap = self.config.capacity
        storage = jax.tree.map(
            lambda x: jnp.zeros((cap,) + tuple(x.shape), x.dtype), self._example
        )
        return ReplayState(
            tree=sumtree.init(self.spec),
            storage=storage,
            head=jnp.zeros((), jnp.int32),
            count=jnp.zeros((), jnp.int32),
            max_priority=jnp.ones((), jnp.float32),
            pending=jnp.zeros((), jnp.int32),
        )

    # -- tree-op dispatch (TreeOps backend protocol, DESIGN.md §4.2) -------

    def _tree_write(self, state: ReplayState, idx, vals, *, lazy: bool,
                    unique: bool = False) -> Tuple[jax.Array, jax.Array]:
        """One priority SET through the backend: eager (write + propagate)
        or lazy (leaf write, ledger bump).  Returns (tree, pending)."""
        if lazy:
            tree = self.ops.write_leaves(self.spec, state.tree, idx, vals,
                                         unique=unique)
            return tree, state.pending + idx.shape[0]
        tree = self.ops.update(self.spec, state.tree, idx, vals,
                               unique=unique)
        return tree, state.pending

    def _tree_update(self, tree, idx, vals):
        return self.ops.update(self.spec, tree, idx, vals)

    def _tree_sample(self, tree, u):
        return self.ops.sample(self.spec, tree, u)

    # -- the flush boundary (lazy-writing transaction, DESIGN.md §9) -------

    def flush(self, state: ReplayState) -> ReplayState:
        """Apply every deferred leaf write's upward propagation in one
        merged pass and reset the pending ledger.

        No-op (the tree passes through untouched) when nothing is
        pending, so defensive flushes are cheap.  After this returns the
        tree is bit-exact identical to the one produced by eagerly
        propagating each write in order — the interior rebuild is a pure
        function of the leaf level, so the write history cannot matter.
        """
        tree = jax.lax.cond(
            state.pending > 0,
            lambda t: self.ops.flush(self.spec, t),
            lambda t: t,
            state.tree)
        return dataclasses.replace(
            state, tree=tree, pending=jnp.zeros((), jnp.int32))

    # -- insertion (lazy writing, paper Alg. 3 INSERT) ---------------------

    def insert_slots(self, state: ReplayState, batch: int) -> jax.Array:
        """FIFO slot allocation: next ``batch`` indices after head."""
        return (state.head + jnp.arange(batch, dtype=jnp.int32)) % self.config.capacity

    def insert_begin(self, state: ReplayState, batch: int, *,
                     lazy: bool = False) -> Tuple[ReplayState, jax.Array]:
        """Phase 1 — atomically zero the in-flight slots' priorities.

        After this state is *flushed*, sampling can never select a slot
        whose data write is still pending (with ``lazy=False`` the
        returned state is already flushed).

        ``batch`` may not exceed the capacity: the FIFO slot allocation
        would wrap onto duplicate indices and the batched scatter writes
        into storage have unspecified ordering across duplicates — the
        surviving item per slot would be backend-dependent.
        """
        if batch > self.config.capacity:
            raise ValueError(
                f"insert batch={batch} exceeds capacity="
                f"{self.config.capacity}: the FIFO slot allocation would "
                "wrap onto duplicate indices and the duplicate-index "
                "scatter writes into storage resolve in unspecified order "
                "— insert at most `capacity` items per call (or grow the "
                "buffer)")
        slots = self.insert_slots(state, batch)
        tree, pending = self._tree_write(
            state, slots, jnp.zeros((batch,), jnp.float32),
            lazy=lazy, unique=True)
        return dataclasses.replace(state, tree=tree, pending=pending), slots

    def insert_commit(
        self, state: ReplayState, slots: jax.Array, items: Pytree, *,
        lazy: bool = False,
    ) -> ReplayState:
        """Phase 2 — storage write, then restore priority to P_max."""
        storage = jax.tree.map(
            lambda buf, x: buf.at[slots].set(x), state.storage, items
        )
        batch = slots.shape[0]
        pmax = jnp.broadcast_to(state.max_priority, (batch,))
        tree, pending = self._tree_write(state, slots, pmax,
                                         lazy=lazy, unique=True)
        return dataclasses.replace(
            state,
            tree=tree,
            storage=storage,
            head=(state.head + batch) % self.config.capacity,
            count=jnp.minimum(state.count + batch, self.config.capacity),
            pending=pending,
        )

    def insert(self, state: ReplayState, items: Pytree) -> ReplayState:
        """Convenience: begin + commit in one call (eager: the returned
        state is fully consistent)."""
        batch = jax.tree.leaves(items)[0].shape[0]
        state, slots = self.insert_begin(state, batch)
        return self.insert_commit(state, slots, items)

    def append(self, state: ReplayState, items: Pytree, *,
               lazy: bool = True) -> ReplayState:
        """Shard-local writer transaction (the replay-service append,
        DESIGN.md §11): begin + commit fused into one op, with *no*
        assumption that a learner call interleaves the two phases.

        With ``lazy=True`` (the service default) both phases write only
        the tree's leaf level and bump the pending ledger: the appended
        items become sampleable atomically at the shard's next ``flush``
        — the admission-window boundary — so concurrent writers never
        expose a half-written batch to the sampler.  This is the op the
        loop's lockstep insert_begin/learn/insert_commit interleave
        collapses to when actors and learners no longer share a program.
        """
        batch = jax.tree.leaves(items)[0].shape[0]
        state, slots = self.insert_begin(state, batch, lazy=lazy)
        return self.insert_commit(state, slots, items, lazy=lazy)

    # -- sampling (paper Alg. 3 SAMPLE) ------------------------------------

    def sample(
        self,
        state: ReplayState,
        rng: jax.Array,
        batch: int,
        beta: float | jax.Array = 0.4,
        global_total: jax.Array | None = None,
        global_count: jax.Array | None = None,
        max_across=None,
    ) -> Tuple[jax.Array, Pytree, jax.Array]:
        """Prioritized sample of ``batch`` items.

        Returns (indices, items, importance_weights).  The caller must
        have flushed any pending lazy writes (``state.pending == 0``) —
        the runtime loop samples only at its per-iteration flush
        boundary.  For a sharded buffer, pass the psum'd
        ``global_total`` / ``global_count`` so the importance weights
        are computed against the *global* distribution (stratified
        sampling across shards; DESIGN.md §2), and a ``max_across``
        reduction (pmax over the mesh axes) so the ``w / max w``
        normalization also uses the global max — otherwise each shard
        rescales its weights by a different local factor and the
        shards' learner objectives silently diverge.
        """
        u = jax.random.uniform(rng, (batch,))
        if self.config.fused_sample_gather_resolved:
            idx, pri, items = self.ops.sample_gather(
                self.spec, state.tree, u, state.storage)
        else:
            idx, pri = self._tree_sample(state.tree, u)
            items = self._gather(state.storage, idx)
        tot = state.tree[0] if global_total is None else global_total
        cnt = state.count if global_count is None else global_count
        prob = pri / jnp.maximum(tot, 1e-12)
        w = (jnp.maximum(cnt, 1).astype(jnp.float32)
             * jnp.maximum(prob, 1e-12)) ** (-beta)
        # fp tail rounding in the inverse-CDF descent can clamp a draw onto
        # a zero-priority leaf (in-flight or unfilled slot); its weight must
        # be 0, not 0**(-β) = inf, or one such draw NaNs the whole learn.
        w = jnp.where(pri > 0, w, 0.0)
        w_max = jnp.max(w)
        if max_across is not None:
            w_max = max_across(w_max)
        w = w / jnp.maximum(w_max, 1e-12)
        return idx, items, w

    def _gather(self, storage: Pytree, idx: jax.Array) -> Pytree:
        return jax.tree.map(lambda buf: self.ops.gather(buf, idx), storage)

    # -- priority maintenance ----------------------------------------------

    def priorities_from_td(self, td_errors: jax.Array) -> jax.Array:
        return (jnp.abs(td_errors) + self.config.eps) ** self.config.alpha

    def update_priorities(
        self, state: ReplayState, idx: jax.Array, td_errors: jax.Array, *,
        lazy: bool = False,
    ) -> ReplayState:
        """Write-after-read tolerated (paper §IV-D3).

        Indices whose current priority is zero (an in-flight or unfilled
        slot hit by an fp-tail draw — see ``sample``) are skipped: a
        legitimately sampled slot always has priority > 0, and writing a
        fresh priority to a dead slot would make its garbage storage
        sampleable until the FIFO head wraps back around to it.
        """
        cur = self.get_priority(state, idx)
        pri = jnp.where(cur > 0, self.priorities_from_td(td_errors), 0.0)
        tree, pending = self._tree_write(state, idx, pri, lazy=lazy)
        return dataclasses.replace(
            state,
            tree=tree,
            max_priority=jnp.maximum(state.max_priority, jnp.max(pri)),
            pending=pending,
        )

    def get_priority(self, state: ReplayState, idx: jax.Array) -> jax.Array:
        """Θ(1) priority retrieval (paper Alg. 3 PRIORITYRETRIEVAL).
        Leaf reads are always current — lazy writes defer only the
        interior propagation."""
        return sumtree.get(self.spec, state.tree, idx)

    def total_priority(self, state: ReplayState) -> jax.Array:
        return sumtree.total(self.spec, state.tree)
