"""Prioritized replay buffer with lazy-writing insertion (paper §IV-D).

The paper's thread-safety mechanisms map to functional JAX as follows
(see DESIGN.md §2):

  * locks            → batched single-program ops (no shared mutability);
  * lazy writing     → two-phase insert: ``insert_begin`` zeroes the
                       priorities of the in-flight slots, then sampling /
                       learning may run against that tree state (in-flight
                       slots are invisible, the paper's exact invariant),
                       then ``insert_commit`` writes storage and restores
                       P_max.  Because the learner step has *no data
                       dependency* on the storage write, XLA overlaps the
                       HBM copy with learner compute — the same overlap
                       the paper's lock split enables;
  * write-after-read → ``update_priorities`` applies priorities computed
                       at sample time even if inserts landed in between
                       (paper §IV-D3: tolerated transient inconsistency).

Priorities follow PER (Schaul et al., the paper's [24]): stored priority
``p = (|δ| + ε)^α``; importance weights ``w = (N·Pr(i))^(-β) / max_w``.
New insertions receive P_max (paper §IV-A1).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import sumtree, tree_ops
from repro.core.sumtree import SumTreeSpec

Pytree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ReplayState:
    """Functional state of one replay-buffer shard."""

    tree: jax.Array           # flat K-ary sum tree (priorities^α)
    storage: Pytree           # pytree of (capacity, ...) arrays
    head: jax.Array           # int32 — next insert position (FIFO eviction)
    count: jax.Array          # int32 — number of valid entries (≤ capacity)
    max_priority: jax.Array   # f32 — running P_max (already ^α-scaled)


@dataclasses.dataclass(frozen=True)
class ReplayConfig:
    capacity: int
    fanout: int = sumtree.DEFAULT_FANOUT
    alpha: float = 0.6          # priority exponent
    eps: float = 1e-6           # priority floor
    backend: str = "xla"        # TreeOps backend: "xla" | "pallas"
    use_kernels: bool = False   # legacy alias for backend="pallas"

    @property
    def tree_backend(self) -> str:
        return "pallas" if self.use_kernels else self.backend


class PrioritizedReplay:
    """Single-shard prioritized replay buffer (paper §IV).

    All methods are pure functions of ``ReplayState`` and jit-friendly.
    Batched throughout: B parallel inserts / samples / updates per call
    replace the paper's B concurrent threads.
    """

    def __init__(self, config: ReplayConfig, example_item: Pytree):
        self.config = config
        self.spec: SumTreeSpec = sumtree.make_spec(config.capacity, config.fanout)
        self._example = jax.tree.map(jnp.asarray, example_item)
        self.ops: tree_ops.TreeOps = tree_ops.get_tree_ops(config.tree_backend)

    # -- state ------------------------------------------------------------

    def init(self) -> ReplayState:
        cap = self.config.capacity
        storage = jax.tree.map(
            lambda x: jnp.zeros((cap,) + tuple(x.shape), x.dtype), self._example
        )
        return ReplayState(
            tree=sumtree.init(self.spec),
            storage=storage,
            head=jnp.zeros((), jnp.int32),
            count=jnp.zeros((), jnp.int32),
            max_priority=jnp.ones((), jnp.float32),
        )

    # -- tree-op dispatch (TreeOps backend protocol, DESIGN.md §4.2) -------

    def _tree_update(self, tree, idx, vals):
        return self.ops.update(self.spec, tree, idx, vals)

    def _tree_sample(self, tree, u):
        return self.ops.sample(self.spec, tree, u)

    # -- insertion (lazy writing, paper Alg. 3 INSERT) ---------------------

    def insert_slots(self, state: ReplayState, batch: int) -> jax.Array:
        """FIFO slot allocation: next ``batch`` indices after head."""
        return (state.head + jnp.arange(batch, dtype=jnp.int32)) % self.config.capacity

    def insert_begin(self, state: ReplayState, batch: int) -> Tuple[ReplayState, jax.Array]:
        """Phase 1 — atomically zero the in-flight slots' priorities.

        After this returns, sampling from ``state.tree`` can never select
        a slot whose data write is still pending.

        ``batch`` may not exceed the capacity: the FIFO slot allocation
        would wrap onto duplicate indices and the batched scatter writes
        into storage have unspecified ordering across duplicates — the
        surviving item per slot would be backend-dependent.
        """
        if batch > self.config.capacity:
            raise ValueError(
                f"insert batch={batch} exceeds capacity="
                f"{self.config.capacity}: the FIFO slot allocation would "
                "wrap onto duplicate indices and the duplicate-index "
                "scatter writes into storage resolve in unspecified order "
                "— insert at most `capacity` items per call (or grow the "
                "buffer)")
        slots = self.insert_slots(state, batch)
        tree = self._tree_update(state.tree, slots, jnp.zeros((batch,), jnp.float32))
        return dataclasses.replace(state, tree=tree), slots

    def insert_commit(
        self, state: ReplayState, slots: jax.Array, items: Pytree
    ) -> ReplayState:
        """Phase 2 — storage write, then restore priority to P_max."""
        storage = jax.tree.map(
            lambda buf, x: buf.at[slots].set(x), state.storage, items
        )
        batch = slots.shape[0]
        pmax = jnp.broadcast_to(state.max_priority, (batch,))
        tree = self._tree_update(state.tree, slots, pmax)
        return dataclasses.replace(
            state,
            tree=tree,
            storage=storage,
            head=(state.head + batch) % self.config.capacity,
            count=jnp.minimum(state.count + batch, self.config.capacity),
        )

    def insert(self, state: ReplayState, items: Pytree) -> ReplayState:
        """Convenience: begin + commit in one call."""
        batch = jax.tree.leaves(items)[0].shape[0]
        state, slots = self.insert_begin(state, batch)
        return self.insert_commit(state, slots, items)

    # -- sampling (paper Alg. 3 SAMPLE) ------------------------------------

    def sample(
        self,
        state: ReplayState,
        rng: jax.Array,
        batch: int,
        beta: float | jax.Array = 0.4,
        global_total: jax.Array | None = None,
        global_count: jax.Array | None = None,
        max_across=None,
    ) -> Tuple[jax.Array, Pytree, jax.Array]:
        """Prioritized sample of ``batch`` items.

        Returns (indices, items, importance_weights).  For a sharded
        buffer, pass the psum'd ``global_total`` / ``global_count`` so the
        importance weights are computed against the *global* distribution
        (stratified sampling across shards; DESIGN.md §2), and a
        ``max_across`` reduction (pmax over the mesh axes) so the
        ``w / max w`` normalization also uses the global max — otherwise
        each shard rescales its weights by a different local factor and
        the shards' learner objectives silently diverge.
        """
        u = jax.random.uniform(rng, (batch,))
        idx, pri = self._tree_sample(state.tree, u)
        items = self._gather(state.storage, idx)
        tot = state.tree[0] if global_total is None else global_total
        cnt = state.count if global_count is None else global_count
        prob = pri / jnp.maximum(tot, 1e-12)
        w = (jnp.maximum(cnt, 1).astype(jnp.float32)
             * jnp.maximum(prob, 1e-12)) ** (-beta)
        # fp tail rounding in the inverse-CDF descent can clamp a draw onto
        # a zero-priority leaf (in-flight or unfilled slot); its weight must
        # be 0, not 0**(-β) = inf, or one such draw NaNs the whole learn.
        w = jnp.where(pri > 0, w, 0.0)
        w_max = jnp.max(w)
        if max_across is not None:
            w_max = max_across(w_max)
        w = w / jnp.maximum(w_max, 1e-12)
        return idx, items, w

    def _gather(self, storage: Pytree, idx: jax.Array) -> Pytree:
        return jax.tree.map(lambda buf: self.ops.gather(buf, idx), storage)

    # -- priority maintenance ----------------------------------------------

    def priorities_from_td(self, td_errors: jax.Array) -> jax.Array:
        return (jnp.abs(td_errors) + self.config.eps) ** self.config.alpha

    def update_priorities(
        self, state: ReplayState, idx: jax.Array, td_errors: jax.Array
    ) -> ReplayState:
        """Write-after-read tolerated (paper §IV-D3).

        Indices whose current priority is zero (an in-flight or unfilled
        slot hit by an fp-tail draw — see ``sample``) are skipped: a
        legitimately sampled slot always has priority > 0, and writing a
        fresh priority to a dead slot would make its garbage storage
        sampleable until the FIFO head wraps back around to it.
        """
        cur = self.get_priority(state, idx)
        pri = jnp.where(cur > 0, self.priorities_from_td(td_errors), 0.0)
        tree = self._tree_update(state.tree, idx, pri)
        return dataclasses.replace(
            state,
            tree=tree,
            max_priority=jnp.maximum(state.max_priority, jnp.max(pri)),
        )

    def get_priority(self, state: ReplayState, idx: jax.Array) -> jax.Array:
        """Θ(1) priority retrieval (paper Alg. 3 PRIORITYRETRIEVAL)."""
        return sumtree.get(self.spec, state.tree, idx)

    def total_priority(self, state: ReplayState) -> jax.Array:
        return sumtree.total(self.spec, state.tree)
