"""Core: the paper's contribution — K-ary sum tree prioritized replay."""

from repro.core import sumtree
from repro.core.replay import PrioritizedReplay, ReplayConfig, ReplayState
from repro.core.distributed import ShardedPrioritizedReplay, ShardedReplayConfig

__all__ = [
    "sumtree",
    "PrioritizedReplay",
    "ReplayConfig",
    "ReplayState",
    "ShardedPrioritizedReplay",
    "ShardedReplayConfig",
]
