"""Sharded replay buffer across a device mesh (DESIGN.md §2, last row).

The paper's single shared buffer in DRAM becomes, at pod scale, one shard
per data-axis device: local storage + a local K-ary sum tree.  Sampling is
*stratified*: each learner shard draws B/D items from its own tree (full
data locality — no all-to-all of transitions) and the importance weights
are computed against the **global** priority distribution:

    inclusion prob of item i on shard d:  q(i) = (B/D) · p_i / S_d
    PER-consistent weight:                w_i ∝ (N_glob · p_i / S_glob)^(-β)

where S_d is the shard root sum (local tree root) and S_glob/N_glob come
from a single scalar ``psum`` — 8 bytes per step, negligible collective
cost.  The β-correction against the global distribution keeps the learner
objective equal to the paper's single-buffer objective in expectation (the
stratification across shards only changes variance, not bias, because the
per-shard sample count is fixed and weights divide out q(i)).

All functions are written to run inside ``shard_map`` over the data axes;
each call sees its local shard and the mesh axis name(s).

``initialize_distributed`` joins this process to the multi-controller
SPMD runtime (the wall-clock launch mode, DESIGN.md §10): after it
returns, ``jax.devices()`` spans every worker process in process order,
so the meshes in ``launch.mesh`` — and the shard_map executors over
them — transparently become multi-process, with each worker executing
its addressable shards and collectives crossing real process
boundaries.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.replay import PrioritizedReplay, ReplayConfig, ReplayState

Pytree = Any


def _wait_for_coordinator(coordinator_address: str, process_id: int,
                          num_processes: int, timeout_s: float) -> None:
    """Poll plain TCP connects against the coordinator until it accepts
    or ``timeout_s`` elapses — raising the handshake RuntimeError
    ourselves, because a dead coordinator otherwise kills the process
    via an uncatchable XLA ``LOG(FATAL)``."""
    import socket
    import time

    host, _, port = coordinator_address.rpartition(":")
    deadline = time.monotonic() + timeout_s
    while True:
        try:
            with socket.create_connection((host, int(port)), timeout=1.0):
                return
        except OSError:
            if time.monotonic() >= deadline:
                raise RuntimeError(
                    f"coordinator handshake failed: process {process_id}/"
                    f"{num_processes} could not join the coordinator at "
                    f"{coordinator_address} within {timeout_s:.0f}s — "
                    "check that every worker of the gang was actually "
                    "launched (launch/multiprocess.py spawns the full "
                    "set) and that the coordinator host:port is "
                    "reachable and not already bound") from None
            time.sleep(0.25)


def initialize_distributed(
    coordinator_address: str,
    num_processes: int,
    process_id: int,
    timeout_s: float = 60.0,
) -> None:
    """Join the multi-controller runtime (``launch/multiprocess.py``).

    On CPU backends the gloo collectives transport must be selected
    *before* the distributed runtime initializes — without it the first
    cross-process psum dies with "Multiprocess computations aren't
    implemented on the CPU backend".  ``jax.distributed.initialize``
    blocks until all ``num_processes`` workers reach the coordinator;
    ``timeout_s`` bounds that wait so a missing or crashed peer surfaces
    as a raised ``RuntimeError`` naming the coordinator instead of a
    silent hang (tests/test_multiprocess.py).  For workers other than
    process 0 the coordinator port is probed with plain TCP connects
    first: when process 0 never came up, the XLA coordination client
    aborts the interpreter with a C++ ``LOG(FATAL)`` on its RegisterTask
    deadline — uncatchable from Python — so the reachability check is
    the only place the missing-coordinator case can turn into a clear
    exception.
    """
    if num_processes < 1:
        raise ValueError(f"num_processes={num_processes}: need ≥ 1")
    if not 0 <= process_id < num_processes:
        raise ValueError(f"process_id={process_id}: need 0 ≤ id < "
                         f"{num_processes}")
    if process_id != 0:
        _wait_for_coordinator(coordinator_address, process_id,
                              num_processes, timeout_s)
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    try:
        jax.distributed.initialize(
            coordinator_address=coordinator_address,
            num_processes=num_processes,
            process_id=process_id,
            initialization_timeout=int(timeout_s),
        )
    except Exception as e:
        raise RuntimeError(
            f"coordinator handshake failed: process {process_id}/"
            f"{num_processes} could not join the coordinator at "
            f"{coordinator_address} within {timeout_s:.0f}s — check that "
            "every worker of the gang was actually launched (launch/"
            "multiprocess.py spawns the full set) and that the "
            "coordinator host:port is reachable and not already bound"
        ) from e


@dataclasses.dataclass(frozen=True)
class ShardedReplayConfig:
    """``axis_names`` may span multiple mesh axes — e.g. the pod-scale
    ``("pod", "data")`` two-axis executor — in which case every global
    stat psums/pmaxes over all of them (one shard per mesh *cell*).  The
    order convention is outer/slow axis first (the executor compresses
    gradients across ``axis_names[0]``); the buffer itself is
    order-insensitive, its collectives are all full reductions."""

    capacity_per_shard: int
    fanout: int = 128
    alpha: float = 0.6
    eps: float = 1e-6
    backend: Optional[str] = None   # TreeOps backend: "xla" | "pallas"
    # None → backend-appropriate default (see ReplayConfig)
    fused_sample_gather: Optional[bool] = None
    axis_names: Tuple[str, ...] = ("data",)

    @property
    def tree_backend(self) -> str:
        return self.backend or "xla"


class ShardedPrioritizedReplay:
    """Per-shard API; call inside shard_map over ``axis_names``."""

    def __init__(self, config: ShardedReplayConfig, example_item: Pytree):
        if not config.axis_names:
            raise ValueError("axis_names must name at least one mesh axis")
        if len(set(config.axis_names)) != len(config.axis_names):
            raise ValueError(
                f"duplicate mesh axes in axis_names={config.axis_names}: "
                "each axis reduces once in the global stats")
        self.config = config
        self.local = PrioritizedReplay(
            ReplayConfig(
                capacity=config.capacity_per_shard,
                fanout=config.fanout,
                alpha=config.alpha,
                eps=config.eps,
                backend=config.backend,
                fused_sample_gather=config.fused_sample_gather,
            ),
            example_item,
        )

    def init(self) -> ReplayState:
        return self.local.init()

    # -- global scalars (one psum of 2 floats) -----------------------------

    def global_stats(self, state: ReplayState) -> Tuple[jax.Array, jax.Array]:
        # total priority mass and item count ride ONE stacked psum per
        # axis — on a real multi-process transport each collective pays
        # a fixed launch latency, so two scalars share a wire vector
        stats = jnp.stack([state.tree[0], state.count.astype(jnp.float32)])
        for ax in self.config.axis_names:
            stats = jax.lax.psum(stats, ax)
        return stats[0], stats[1]

    def max_across(self, x: jax.Array) -> jax.Array:
        """Global max over the mesh axes (the importance-weight
        normalizer must be the max over *all* shards' draws, not the
        local batch max — one extra scalar collective)."""
        for ax in self.config.axis_names:
            x = jax.lax.pmax(x, ax)
        return x

    # -- ops ----------------------------------------------------------------

    def insert(self, state: ReplayState, items: Pytree) -> ReplayState:
        """Local insert — actors write to their own shard (no collective)."""
        return self.local.insert(state, items)

    def append(self, state: ReplayState, items: Pytree, *,
               lazy: bool = True) -> ReplayState:
        """Shard-local writer transaction (see PrioritizedReplay.append)."""
        return self.local.append(state, items, lazy=lazy)

    def insert_begin(self, state: ReplayState, batch: int, *,
                     lazy: bool = False):
        return self.local.insert_begin(state, batch, lazy=lazy)

    def insert_commit(self, state, slots, items, *, lazy: bool = False):
        return self.local.insert_commit(state, slots, items, lazy=lazy)

    def flush(self, state: ReplayState) -> ReplayState:
        """Per-shard flush boundary (no collective — each shard rebuilds
        its own tree's interior from its own leaves)."""
        return self.local.flush(state)

    def sample(
        self,
        state: ReplayState,
        rng: jax.Array,
        batch_per_shard: int,
        beta: float | jax.Array = 0.4,
    ) -> Tuple[jax.Array, Pytree, jax.Array]:
        """Stratified global sample: B/D local draws, global IS weights
        (distribution *and* max-normalizer both psum'd/pmax'd global)."""
        g_tot, g_cnt = self.global_stats(state)
        return self.local.sample(
            state, rng, batch_per_shard, beta,
            global_total=g_tot, global_count=g_cnt,
            max_across=self.max_across,
        )

    def update_priorities(self, state, idx, td_errors, *,
                          lazy: bool = False) -> ReplayState:
        return self.local.update_priorities(state, idx, td_errors, lazy=lazy)
