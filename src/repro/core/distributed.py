"""Sharded replay buffer across a device mesh (DESIGN.md §2, last row).

The paper's single shared buffer in DRAM becomes, at pod scale, one shard
per data-axis device: local storage + a local K-ary sum tree.  Sampling is
*stratified*: each learner shard draws B/D items from its own tree (full
data locality — no all-to-all of transitions) and the importance weights
are computed against the **global** priority distribution:

    inclusion prob of item i on shard d:  q(i) = (B/D) · p_i / S_d
    PER-consistent weight:                w_i ∝ (N_glob · p_i / S_glob)^(-β)

where S_d is the shard root sum (local tree root) and S_glob/N_glob come
from a single scalar ``psum`` — 8 bytes per step, negligible collective
cost.  The β-correction against the global distribution keeps the learner
objective equal to the paper's single-buffer objective in expectation (the
stratification across shards only changes variance, not bias, because the
per-shard sample count is fixed and weights divide out q(i)).

All functions are written to run inside ``shard_map`` over the data axes;
each call sees its local shard and the mesh axis name(s).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.replay import PrioritizedReplay, ReplayConfig, ReplayState

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ShardedReplayConfig:
    """``axis_names`` may span multiple mesh axes — e.g. the pod-scale
    ``("pod", "data")`` two-axis executor — in which case every global
    stat psums/pmaxes over all of them (one shard per mesh *cell*).  The
    order convention is outer/slow axis first (the executor compresses
    gradients across ``axis_names[0]``); the buffer itself is
    order-insensitive, its collectives are all full reductions."""

    capacity_per_shard: int
    fanout: int = 128
    alpha: float = 0.6
    eps: float = 1e-6
    backend: Optional[str] = None   # TreeOps backend: "xla" | "pallas"
    use_kernels: bool = False   # deprecated alias for backend="pallas"
    fused_sample_gather: bool = True
    axis_names: Tuple[str, ...] = ("data",)

    @property
    def tree_backend(self) -> str:
        from repro.core import tree_ops
        return tree_ops.resolve_tree_backend(self.backend, self.use_kernels)


class ShardedPrioritizedReplay:
    """Per-shard API; call inside shard_map over ``axis_names``."""

    def __init__(self, config: ShardedReplayConfig, example_item: Pytree):
        if not config.axis_names:
            raise ValueError("axis_names must name at least one mesh axis")
        if len(set(config.axis_names)) != len(config.axis_names):
            raise ValueError(
                f"duplicate mesh axes in axis_names={config.axis_names}: "
                "each axis reduces once in the global stats")
        self.config = config
        self.local = PrioritizedReplay(
            ReplayConfig(
                capacity=config.capacity_per_shard,
                fanout=config.fanout,
                alpha=config.alpha,
                eps=config.eps,
                backend=config.backend,
                use_kernels=config.use_kernels,
                fused_sample_gather=config.fused_sample_gather,
            ),
            example_item,
        )

    def init(self) -> ReplayState:
        return self.local.init()

    # -- global scalars (one psum of 2 floats) -----------------------------

    def global_stats(self, state: ReplayState) -> Tuple[jax.Array, jax.Array]:
        tot = state.tree[0]
        cnt = state.count.astype(jnp.float32)
        for ax in self.config.axis_names:
            tot = jax.lax.psum(tot, ax)
            cnt = jax.lax.psum(cnt, ax)
        return tot, cnt

    def max_across(self, x: jax.Array) -> jax.Array:
        """Global max over the mesh axes (the importance-weight
        normalizer must be the max over *all* shards' draws, not the
        local batch max — one extra scalar collective)."""
        for ax in self.config.axis_names:
            x = jax.lax.pmax(x, ax)
        return x

    # -- ops ----------------------------------------------------------------

    def insert(self, state: ReplayState, items: Pytree) -> ReplayState:
        """Local insert — actors write to their own shard (no collective)."""
        return self.local.insert(state, items)

    def insert_begin(self, state: ReplayState, batch: int, *,
                     lazy: bool = False):
        return self.local.insert_begin(state, batch, lazy=lazy)

    def insert_commit(self, state, slots, items, *, lazy: bool = False):
        return self.local.insert_commit(state, slots, items, lazy=lazy)

    def flush(self, state: ReplayState) -> ReplayState:
        """Per-shard flush boundary (no collective — each shard rebuilds
        its own tree's interior from its own leaves)."""
        return self.local.flush(state)

    def sample(
        self,
        state: ReplayState,
        rng: jax.Array,
        batch_per_shard: int,
        beta: float | jax.Array = 0.4,
    ) -> Tuple[jax.Array, Pytree, jax.Array]:
        """Stratified global sample: B/D local draws, global IS weights
        (distribution *and* max-normalizer both psum'd/pmax'd global)."""
        g_tot, g_cnt = self.global_stats(state)
        return self.local.sample(
            state, rng, batch_per_shard, beta,
            global_total=g_tot, global_count=g_cnt,
            max_across=self.max_across,
        )

    def update_priorities(self, state, idx, td_errors, *,
                          lazy: bool = False) -> ReplayState:
        return self.local.update_priorities(state, idx, td_errors, lazy=lazy)
