"""Adam/AdamW with configurable state dtype (ZeRO-1-style sharded states).

Optimizer state inherits the parameter sharding (params are already FSDP
× TP sharded at pod scale — see backbone.param_specs), which *is* ZeRO-1:
each device holds only its shard of m/v.  For ≥8B-param archs the m/v
dtype drops to bf16 (``state_dtype``) so params+grads+states fit a 16 GB
v5e HBM (budgeted in EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0          # global-norm clip; 0 disables
    state_dtype: Optional[str] = None  # None → f32 m/v; "bfloat16" for ZeRO-lite


class AdamState(NamedTuple):
    count: jax.Array
    m: Pytree
    v: Pytree


def init(params: Pytree, cfg: AdamConfig) -> AdamState:
    dt = jnp.dtype(cfg.state_dtype) if cfg.state_dtype else None

    def zeros(p):
        return jnp.zeros(p.shape, dt or jnp.promote_types(p.dtype, jnp.float32))

    return AdamState(
        count=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(
    grads: Pytree, state: AdamState, params: Pytree, cfg: AdamConfig
) -> Tuple[Pytree, AdamState, jax.Array]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    count = state.count + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(gf)
        step = cfg.lr * (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.lr * cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - step
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamState(count, new_m, new_v), gnorm


def ema_update(target: Pytree, online: Pytree, tau: float) -> Pytree:
    """Polyak target-network update (DQN/DDPG/TD3/SAC targets)."""
    return jax.tree.map(
        lambda t, o: (t.astype(jnp.float32) * (1 - tau)
                      + o.astype(jnp.float32) * tau).astype(t.dtype),
        target, online,
    )
