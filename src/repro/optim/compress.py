"""int8 error-feedback gradient compression for the cross-pod reduce.

Beyond-paper distributed-optimization trick (system-prompt requirement):
within a pod, gradients reduce in bf16/f32 over the fast 2-D ICI mesh;
*across* pods (the slow inter-pod links) each leaf is quantized to int8
with a per-leaf scale and the quantization error is fed back into the
next step (EF-SGD, Karimireddy et al. 2019 semantics) so compression
noise doesn't bias convergence.

Functional API — the error-feedback buffer is explicit state:

    comp, err = compress(grads, err)        # int8 payload + new error
    grads_hat = decompress(comp)            # dequantize after the reduce

The cross-pod reduce itself is a ``psum`` of the *dequantized* values
over the 'pod' axis (2 pods → one hop); the wire format is the int8
payload, 4× smaller than f32.  On a real fleet the payload rides the
collective; under GSPMD we model it by quantize→psum→dequantize, which
preserves the numerics exactly (tests assert the EF contraction).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class CompressedLeaf(NamedTuple):
    q: jax.Array       # int8 payload
    scale: jax.Array   # f32 per-leaf scale


def init_error(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads: Pytree, err: Pytree) -> Tuple[Pytree, Pytree]:
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return CompressedLeaf(q, scale), gf - deq

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(err)
    comps, new_err = zip(*[one(g, e) for g, e in zip(flat, eflat)])
    return (jax.tree.unflatten(treedef, comps),
            jax.tree.unflatten(treedef, new_err))


def decompress(comp: Pytree) -> Pytree:
    return jax.tree.map(
        lambda c: c.q.astype(jnp.float32) * c.scale,
        comp,
        is_leaf=lambda x: isinstance(x, CompressedLeaf),
    )


def compressed_psum(grads: Pytree, err: Pytree, axis_name: str
                    ) -> Tuple[Pytree, Pytree]:
    """EF-int8 all-reduce over ``axis_name`` (call inside shard_map)."""
    comp, new_err = compress(grads, err)
    deq = decompress(comp)
    reduced = jax.tree.map(lambda g: jax.lax.pmean(g, axis_name), deq)
    return reduced, new_err
