"""int8 error-feedback gradient compression for the cross-pod reduce.

Beyond-paper distributed-optimization trick (system-prompt requirement):
within a pod, gradients reduce in bf16/f32 over the fast 2-D ICI mesh;
*across* pods (the slow inter-pod links) each leaf is quantized to int8
with a per-leaf scale and the quantization error is fed back into the
next step (EF-SGD, Karimireddy et al. 2019 semantics) so compression
noise doesn't bias convergence.

Functional API — the error-feedback buffer is explicit state:

    comp, err = compress(grads, err)        # int8 payload + new error
    grads_hat = decompress(comp)            # dequantize after the reduce

The cross-pod reduce itself is ``compressed_pmean``: a **mean** of the
dequantized values over the 'pod' axis.  Mean — not sum — semantics are
what the hierarchical reduce in ``runtime/learner.py`` composes with:
``pmean(data) → compressed_pmean(pod)`` equals the global pmean up to
quantization error, so the effective learning rate never depends on the
pod count.  (A caller that needs the weighted *sum* across pods — the
bounded-staleness reduce — multiplies the mean by the static pod count.)
The wire format is the int8 payload, 4× smaller than f32.  On a real
fleet the payload rides the collective; under GSPMD we model it by
quantize→pmean→dequantize, which preserves the numerics exactly (tests
assert the EF contraction and the scale parity vs an uncompressed
pmean).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.optim.collectives import fused_tree_reduce

Pytree = Any


class CompressedLeaf(NamedTuple):
    q: jax.Array       # int8 payload
    scale: jax.Array   # f32 per-leaf scale


def init_error(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads: Pytree, err: Pytree) -> Tuple[Pytree, Pytree]:
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return CompressedLeaf(q, scale), gf - deq

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(err)
    if len(flat) != len(eflat):
        raise ValueError(
            f"error-feedback buffer has {len(eflat)} leaves but the "
            f"gradient pytree has {len(flat)} — initialize it with "
            "init_error(<gradient-shaped pytree>)")
    comps, new_err = zip(*[one(g, e) for g, e in zip(flat, eflat)])
    return (jax.tree.unflatten(treedef, comps),
            jax.tree.unflatten(treedef, new_err))


def decompress(comp: Pytree) -> Pytree:
    return jax.tree.map(
        lambda c: c.q.astype(jnp.float32) * c.scale,
        comp,
        is_leaf=lambda x: isinstance(x, CompressedLeaf),
    )


def l2_norm(tree: Pytree) -> jax.Array:
    """Global L2 norm of a pytree — the compression-error magnitude
    surfaced per step as the ``compress_error_norm`` loop metric (EF
    residual of the int8 pod leg, or the bf16 cast error of the
    intra-pod leg)."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def payload_bytes(comp: Pytree) -> int:
    """Wire bytes of the compressed payload crossing the slow link: one
    int8 per element plus one f32 scale per leaf."""
    leaves = jax.tree.leaves(
        comp, is_leaf=lambda x: isinstance(x, CompressedLeaf))
    return sum(c.q.size * c.q.dtype.itemsize + c.scale.size * 4
               for c in leaves if isinstance(c, CompressedLeaf))


def raw_bytes(tree: Pytree) -> int:
    """Bytes of the same pytree reduced uncompressed (f32 on the wire)."""
    return sum(x.size * 4 for x in jax.tree.leaves(tree))


def compressed_pmean(grads: Pytree, err: Pytree, axis_name: str
                     ) -> Tuple[Pytree, Pytree]:
    """EF-int8 all-reduce **mean** over ``axis_name`` (call inside
    shard_map): quantize each shard's contribution to int8 (folding in
    the carried error), ``pmean`` the dequantized values, and return the
    new per-shard error buffer.

    Mean semantics are load-bearing: ``compressed_pmean`` over P pods of
    identical inputs returns those inputs (up to quantization), exactly
    like ``jax.lax.pmean`` — so swapping it into a reduce never rescales
    the gradient by the pod count (the old ``compressed_psum`` name
    promised a sum while computing this mean, silently halving the
    documented gradient scale at 2 pods).
    """
    comp, new_err = compress(grads, err)
    deq = decompress(comp)
    # quantization stays per-leaf (each leaf keeps its own scale); the
    # dequantized f32 payload crosses the pod axis as ONE fused
    # collective instead of one per leaf — bit-exact, fewer launches on
    # the real multi-process transport (optim/collectives.py)
    reduced = fused_tree_reduce(deq, (axis_name,), jax.lax.pmean)
    return reduced, new_err
