"""Fused mesh collectives — one wire launch per dtype group.

On an emulated mesh (host devices in one process) a collective is a
cheap XLA region and nobody counts them.  On the real multi-process
transport of the wall-clock launch mode (the gloo CPU backend,
DESIGN.md §10) every collective pays a fixed per-launch latency — a
few milliseconds of rendezvous — that dwarfs the payload cost at
gradient sizes: a per-leaf ``pmean`` over a 10-leaf MLP issues 10
all-reduces where one would do, and the per-iteration metric scalars
add seven more.  At the paper's update ratios that is ~100 launches
per loop iteration, and measured wall-clock throughput collapses by
an order of magnitude (benchmarks/fig10_scalability.py ``--wall-clock``).

``fused_tree_reduce`` ravels the leaves into a single wire vector per
dtype group, reduces once per mesh axis, and splits the result back.
Elementwise reductions commute with concatenation — element *j* of the
fused vector sees exactly the same psum/pmean as it did in its own
leaf — so the transform is bit-exact (asserted against the per-leaf
form in tests/test_distributed.py), just N× fewer launches.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def fused_tree_reduce(
    tree: Pytree,
    axes: Tuple[str, ...],
    op: Callable[[jax.Array, str], jax.Array] = jax.lax.pmean,
    select: Optional[Callable[[jax.Array], bool]] = None,
) -> Pytree:
    """Reduce every leaf of ``tree`` over the mesh ``axes`` with one
    collective per dtype group per axis (call inside shard_map, or vmap
    with axis names in tests).

    ``op`` is the per-axis primitive (``jax.lax.pmean`` / ``psum`` /
    ``pmax`` — anything elementwise).  ``select`` optionally filters by
    leaf (e.g. only inexact dtypes); unselected leaves pass through
    untouched.  Leaves of different dtypes never share a wire vector —
    each dtype group keeps its own reduce precision, so a bf16-cast
    gradient leg and an f32 metrics leg fuse independently.
    """
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves or not axes:
        return tree
    out = [None] * len(leaves)
    groups: dict = {}
    for i, x in enumerate(leaves):
        if select is not None and not select(x):
            out[i] = x
            continue
        groups.setdefault(jnp.dtype(x.dtype), []).append(i)
    for idxs in groups.values():
        vec = (leaves[idxs[0]].ravel() if len(idxs) == 1 else
               jnp.concatenate([leaves[i].ravel() for i in idxs]))
        for ax in axes:
            vec = op(vec, ax)
        offset = 0
        for i in idxs:
            n = leaves[i].size
            out[i] = vec[offset:offset + n].reshape(leaves[i].shape)
            offset += n
    return jax.tree.unflatten(treedef, out)
