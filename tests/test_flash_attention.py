"""Flash-attention Pallas kernels vs oracle: values and gradients, all
mask variants, shape/dtype sweep, block-size sweep (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as FA
from repro.kernels.ref import flash_attention_ref


def mk(n=4, s=256, hd=64, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    mk1 = lambda: jnp.asarray(rng.normal(size=(n, s, hd)).astype(np.float32) * 0.3).astype(dtype)
    return mk1(), mk1(), mk1()


CASES = [
    ("full", 0, True, True),
    ("full", 0, False, True),
    ("sliding", 64, True, False),
    ("sliding", 64, True, True),
    ("chunked", 64, True, False),
]


@pytest.mark.parametrize("attn,win,causal,glob", CASES)
def test_forward_matches_ref(attn, win, causal, glob):
    q, k, v = mk()
    out = FA.flash_attention_nhsd(q, k, v, attn, win, causal, glob,
                                  bq=64, bk=64, interpret=True)
    ref = flash_attention_ref(q, k, v, attn, win, causal, glob)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=1e-4)


@pytest.mark.parametrize("attn,win,causal,glob", CASES[:3])
def test_gradients_match_ref(attn, win, causal, glob):
    q, k, v = mk(seed=1)

    def loss(fn):
        return lambda q, k, v: jnp.sum(jnp.sin(fn(q, k, v)))

    f = loss(lambda q, k, v: FA.flash_attention_nhsd(
        q, k, v, attn, win, causal, glob, bq=64, bk=64, interpret=True))
    r = loss(lambda q, k, v: flash_attention_ref(
        q, k, v, attn, win, causal, glob))
    gk = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=1e-3)


@pytest.mark.parametrize("s,hd,bq,bk", [
    (128, 32, 128, 64), (384, 128, 128, 128), (512, 64, 256, 512),
])
def test_shape_block_sweep(s, hd, bq, bk):
    q, k, v = mk(n=2, s=s, hd=hd, seed=s + hd)
    out = FA.flash_attention_nhsd(q, k, v, "full", 0, True, True,
                                  bq=bq, bk=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, "full", 0, True, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-6, rtol=1e-4)


def test_bf16_inputs():
    q, k, v = mk(n=2, s=128, hd=64, dtype=jnp.bfloat16, seed=7)
    out = FA.flash_attention_nhsd(q, k, v, "full", 0, True, True,
                                  bq=64, bk=64, interpret=True)
    ref = flash_attention_ref(q, k, v, "full", 0, True, True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)
    assert out.dtype == jnp.bfloat16


def test_traced_global_flag():
    q, k, v = mk(n=2, s=128, hd=32, seed=9)

    def f(g):
        return FA.flash_attention_nhsd(q, k, v, "sliding", 32, True,
                                       g != 0, bq=64, bk=64, interpret=True)

    out_local = jax.jit(f)(jnp.asarray(0))
    out_glob = jax.jit(f)(jnp.asarray(1))
    ref_local = flash_attention_ref(q, k, v, "sliding", 32, True, False)
    ref_glob = flash_attention_ref(q, k, v, "sliding", 32, True, True)
    np.testing.assert_allclose(np.asarray(out_local), np.asarray(ref_local),
                               atol=2e-6, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(out_glob), np.asarray(ref_glob),
                               atol=2e-6, rtol=1e-4)


def test_flash_in_model_matches_naive():
    """End-to-end: a smoke backbone with attn_impl=flash equals naive."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import backbone
    from repro.models.config import NO_SHARDING

    cfg = get_config("granite_8b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = backbone.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 128), 0, cfg.vocab_size)
    a = backbone.forward(cfg, NO_SHARDING, params, tokens)
    cfg_f = dataclasses.replace(cfg, attn_impl="flash")
    b = backbone.forward(cfg_f, NO_SHARDING, params, tokens)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=5e-5, rtol=1e-3)
