"""End-to-end behaviour tests for the paper's system: the full parallel
actors + lazy-write buffer + parallel learners pipeline improves a policy
and survives a checkpoint/restart cycle."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.agents.dqn import DQNConfig, make_dqn
from repro.checkpoint.manager import CheckpointManager
from repro.core.replay import PrioritizedReplay, ReplayConfig
from repro.envs.classic import make_vec
from repro.runtime import loop


def _example(spec):
    return {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }


def test_full_pipeline_improves_policy():
    """Paper Alg. 1 + §V: after training through the fused parallel_step,
    the policy must beat the random baseline (CartPole random ≈ 10)."""
    spec, v_reset, v_step = make_vec("cartpole", 8)
    agent = make_dqn(spec, DQNConfig())
    replay = PrioritizedReplay(ReplayConfig(capacity=20_000, fanout=128),
                               _example(spec))
    cfg = loop.LoopConfig(batch_size=64, warmup=400, epsilon=0.2)
    state, hist = loop.train(agent, replay, v_reset, v_step, cfg, n_envs=8,
                             iterations=1400, key=jax.random.PRNGKey(1))
    final = float(hist["mean_episode_return"][-1])
    assert final > 30.0, final


def test_checkpoint_restart_resumes_exactly(tmp_path):
    """Fault tolerance: save mid-training, clobber the state, restore —
    the agent parameters and step counter come back bit-exact."""
    spec, v_reset, v_step = make_vec("cartpole", 4)
    agent = make_dqn(spec, DQNConfig())
    replay = PrioritizedReplay(ReplayConfig(capacity=1024, fanout=8),
                               _example(spec))
    cfg = loop.LoopConfig(batch_size=32, warmup=64, epsilon=0.2)
    step = jax.jit(loop.make_parallel_step(agent, replay, v_step, cfg, 4))
    st = loop.init_loop_state(agent, replay, v_reset, jax.random.PRNGKey(2), 4)
    for _ in range(30):
        st, _ = step(st)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(30, st.agent)
    restored_step, restored = mgr.restore_latest(st.agent)
    assert restored_step == 30
    for a, b in zip(jax.tree.leaves(st.agent.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # training continues from the restored state
    st2 = st._replace(agent=restored)
    st2, metrics = step(st2)
    assert np.isfinite(float(metrics["loss"]))
