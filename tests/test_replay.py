"""Prioritized replay buffer: lazy-write invariant, PER weights, FIFO
eviction, priority updates (paper §IV)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.replay import PrioritizedReplay, ReplayConfig

EXAMPLE = {
    "obs": jnp.zeros((4,), jnp.float32),
    "action": jnp.zeros((), jnp.int32),
    "reward": jnp.zeros((), jnp.float32),
}


def make(capacity=256, **kw):
    return PrioritizedReplay(ReplayConfig(capacity=capacity, fanout=8, **kw),
                             EXAMPLE)


def items(n, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "obs": jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32)),
        "action": jnp.asarray(rng.integers(0, 3, n).astype(np.int32)),
        "reward": jnp.asarray(rng.uniform(0, 1, n).astype(np.float32)),
    }


def test_insert_sample_roundtrip():
    rb = make()
    st = rb.init()
    data = items(32)
    st = rb.insert(st, data)
    assert int(st.count) == 32
    idx, got, w = rb.sample(st, jax.random.PRNGKey(0), 16)
    assert (np.asarray(idx) < 32).all()
    np.testing.assert_allclose(np.asarray(got["obs"]),
                               np.asarray(data["obs"])[np.asarray(idx)])
    assert np.asarray(w).max() <= 1.0 + 1e-6 and (np.asarray(w) > 0).all()


def test_lazy_write_inflight_slots_invisible():
    """Between insert_begin and insert_commit the in-flight slots must
    never be sampled (paper Alg. 3 INSERT / §IV-D2)."""
    rb = make(capacity=64)
    st = rb.init()
    st = rb.insert(st, items(64))
    st2, slots = rb.insert_begin(st, 16)
    for seed in range(5):
        idx, _, _ = rb.sample(st2, jax.random.PRNGKey(seed), 64)
        assert not np.isin(np.asarray(idx), np.asarray(slots)).any()
    # commit restores sampleability at max priority
    st3 = rb.insert_commit(st2, slots, items(16, seed=1))
    pri = rb.get_priority(st3, slots)
    assert (np.asarray(pri) == float(st3.max_priority)).all()


def test_fifo_eviction_wraparound():
    rb = make(capacity=32)
    st = rb.init()
    st = rb.insert(st, items(32, seed=0))
    first = np.asarray(st.storage["reward"]).copy()
    st = rb.insert(st, items(8, seed=1))          # overwrites slots 0..7
    after = np.asarray(st.storage["reward"])
    assert int(st.count) == 32
    assert int(st.head) == 8
    assert not np.allclose(after[:8], first[:8])
    np.testing.assert_allclose(after[8:], first[8:])


def test_priority_update_shifts_sampling():
    rb = make(capacity=128, alpha=1.0)
    st = rb.init()
    st = rb.insert(st, items(128))
    # push all priorities low except index 7
    td = np.full(128, 1e-6, np.float32)
    td[7] = 10.0
    st = rb.update_priorities(st, jnp.arange(128), jnp.asarray(td))
    idx, _, w = rb.sample(st, jax.random.PRNGKey(1), 256)
    frac7 = (np.asarray(idx) == 7).mean()
    assert frac7 > 0.95
    # IS weight of the over-sampled item must be the smallest
    assert np.asarray(w)[np.asarray(idx) == 7].max() <= np.asarray(w).max()


def test_importance_weights_formula():
    rb = make(capacity=16, alpha=1.0)
    st = rb.init()
    st = rb.insert(st, items(16))
    td = np.linspace(0.1, 1.6, 16).astype(np.float32)
    st = rb.update_priorities(st, jnp.arange(16), jnp.asarray(td))
    beta = 0.7
    idx, _, w = rb.sample(st, jax.random.PRNGKey(2), 64, beta=beta)
    pri = np.asarray(rb.get_priority(st, idx))
    prob = pri / float(rb.total_priority(st))
    expect = (16 * prob) ** (-beta)
    expect = expect / expect.max()
    np.testing.assert_allclose(np.asarray(w), expect, rtol=1e-4)


def test_max_priority_tracked():
    rb = make(capacity=64, alpha=1.0)
    st = rb.init()
    st = rb.insert(st, items(8))
    st = rb.update_priorities(st, jnp.arange(8), jnp.full(8, 5.0))
    st = rb.insert(st, items(8, seed=2))
    new_slots = jnp.arange(8, 16)
    pri = np.asarray(rb.get_priority(st, new_slots))
    assert (pri >= 5.0).all()  # new items enter at P_max (paper §IV-A1)


def test_insert_batch_larger_than_capacity_rejected():
    """Regression: a batch wider than the buffer used to wrap
    ``insert_slots`` onto duplicate indices and issue duplicate-index
    scatter writes with unspecified ordering (backend-dependent surviving
    item).  Now a clear ValueError at the insert_begin boundary — and
    through the convenience ``insert`` wrapper."""
    rb = make(capacity=16)
    st = rb.init()
    with pytest.raises(ValueError, match="capacity"):
        rb.insert_begin(st, 17)
    with pytest.raises(ValueError, match="capacity"):
        rb.insert(st, items(32))
    # a full-capacity batch is the legal maximum (every slot distinct)
    st = rb.insert(st, items(16))
    assert int(st.count) == 16
    assert len(np.unique(np.asarray(rb.insert_slots(st, 16)))) == 16


def test_kernel_backed_buffer_equivalent():
    rb_j = make(capacity=512)
    rb_k = PrioritizedReplay(
        ReplayConfig(capacity=512, fanout=128, backend="pallas"), EXAMPLE)
    st_j, st_k = rb_j.init(), rb_k.init()
    data = items(256, seed=3)
    st_j, st_k = rb_j.insert(st_j, data), rb_k.insert(st_k, data)
    np.testing.assert_allclose(float(rb_j.total_priority(st_j)),
                               float(rb_k.total_priority(st_k)), rtol=1e-5)
    idx_j, _, _ = rb_j.sample(st_j, jax.random.PRNGKey(5), 64)
    idx_k, _, _ = rb_k.sample(st_k, jax.random.PRNGKey(5), 64)
    # same tree contents + same rng stream + different tree layout impl
    # must agree (both are exact inverse-cdf)
    assert (np.asarray(idx_j) == np.asarray(idx_k)).mean() > 0.98
