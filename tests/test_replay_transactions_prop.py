"""Hypothesis property test: lazy ≡ eager tree equivalence over random
interleavings of insert/sample/update/flush, both TreeOps backends,
duplicate-heavy index batches (DESIGN.md §9 transaction contract).

Separate module so the deterministic transaction tests still run where
hypothesis is absent (the container); CI installs requirements-dev."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sumtree

from test_replay_transactions import BACKENDS, items, make  # noqa: E402 — sibling test module (pytest rootdir import)

hyp = pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st_  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(
    backend=st_.sampled_from(BACKENDS),
    seed=st_.integers(0, 10_000),
    script=st_.lists(
        st_.sampled_from(["insert", "update", "flush", "sample"]),
        min_size=2, max_size=8),
)
def test_property_lazy_eager_equivalence_random_interleavings(
        backend, seed, script):
    """Over random interleavings of insert/update/sample/flush with
    duplicate-heavy index batches, the lazy arm (defer everything,
    flush at the script's flush points and before every sample) and the
    eager arm (flush after every mutation) stay bit-exact at every
    flush point and draw identical samples."""
    rng = np.random.default_rng(seed)
    rb = make(capacity=32, backend=backend)
    lazy_st = rb.insert(rb.init(), items(32, seed=seed))
    eager_st = lazy_st
    open_slots = []            # (slots, items) begun but not committed

    for step_i, op in enumerate(script):
        if op == "insert":
            if open_slots:
                slots, data = open_slots.pop()
                lazy_st = rb.insert_commit(lazy_st, slots, data, lazy=True)
                eager_st = rb.flush(
                    rb.insert_commit(eager_st, slots, data, lazy=True))
            else:
                n = int(rng.integers(1, 9))
                lazy_st, slots = rb.insert_begin(lazy_st, n, lazy=True)
                eager_st, _ = rb.insert_begin(eager_st, n, lazy=True)
                eager_st = rb.flush(eager_st)
                open_slots.append((slots, items(n, seed=seed + step_i)))
        elif op == "update":
            b = int(rng.integers(1, 12))
            # duplicate-heavy: draw from a handful of slots
            idx = jnp.asarray(rng.integers(0, 8, b).astype(np.int32))
            td = jnp.asarray(rng.uniform(0.05, 3.0, b).astype(np.float32))
            lazy_st = rb.update_priorities(lazy_st, idx, td, lazy=True)
            eager_st = rb.flush(
                rb.update_priorities(eager_st, idx, td, lazy=True))
        elif op == "flush":
            lazy_st = rb.flush(lazy_st)
            np.testing.assert_array_equal(np.asarray(lazy_st.tree),
                                          np.asarray(eager_st.tree))
        else:  # sample — a flush boundary by contract
            lazy_st = rb.flush(lazy_st)
            key = jax.random.PRNGKey(seed + step_i)
            li, _, lw = rb.sample(lazy_st, key, 16)
            ei, _, ew = rb.sample(eager_st, key, 16)
            np.testing.assert_array_equal(np.asarray(li), np.asarray(ei))
            np.testing.assert_array_equal(np.asarray(lw), np.asarray(ew))

    lazy_st = rb.flush(lazy_st)
    np.testing.assert_array_equal(np.asarray(lazy_st.tree),
                                  np.asarray(eager_st.tree))
    assert sumtree.check_invariant(rb.spec, lazy_st.tree)
