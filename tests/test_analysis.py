"""repro-lint (src/repro/analysis, DESIGN.md §12) — the four passes
against the fixtures corpus, suppression and baseline mechanics, CLI
exit codes, and the stale-baseline / lint-clean-repo meta-gates.

Everything runs the analyzer in-process (it's stdlib-only and fast);
one subprocess test pins the tools/repro_lint.py entry point.
"""

import subprocess
import sys
from pathlib import Path

from repro.analysis import PASSES, RULES, SourceFile
from repro.analysis import baseline as baseline_mod
from repro.analysis.cli import (DEFAULT_ROOTS, analyze_file, main,
                                run_paths)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "analysis"


def lint(name: str):
    """Unsuppressed (finding, snippet) pairs for one fixture."""
    return analyze_file(str(FIXTURES / name), name)[0]


def lint_text(text: str):
    sf = SourceFile("<mem>", "mem.py", text=text)
    found = list(sf.bad_suppressions)
    for p in PASSES:
        found.extend(p(sf))
    return sorted(f for f in found if not sf.is_suppressed(f))


def rules_at(found):
    return sorted((f.rule, f.line) for f, _ in found)


# -- pass 1: donation safety ------------------------------------------------

def test_bad_donation_fixture():
    got = rules_at(lint("bad_donation.py"))
    assert got == [("D101", 19), ("D101", 26), ("D102", 35), ("D102", 36)]


def test_good_donation_fixture():
    assert lint("good_donation.py") == []


# -- pass 2: collective uniformity ------------------------------------------

def test_bad_collectives_fixture():
    got = rules_at(lint("bad_collectives.py"))
    assert got == [("C201", 16), ("C201", 22), ("C202", 27)]


def test_good_collectives_fixture():
    assert lint("good_collectives.py") == []


# -- pass 3: lock discipline ------------------------------------------------

def test_bad_locks_fixture():
    got = rules_at(lint("bad_locks.py"))
    assert got == [("L301", 21), ("L302", 37), ("L303", 32)]


def test_good_locks_fixture():
    assert lint("good_locks.py") == []


# -- pass 4: retrace hazards ------------------------------------------------

def test_bad_retrace_fixture():
    got = rules_at(lint("bad_retrace.py"))
    assert got == [("R401", 20), ("R402", 27), ("R402", 36), ("R403", 48)]


def test_good_retrace_fixture():
    assert lint("good_retrace.py") == []


def test_static_argnums_branch_is_exempt():
    text = (
        "import jax\n"
        "def f(x, n):\n"
        "    if n > 0:\n"
        "        return x + n\n"
        "    return x\n"
        "g = jax.jit(f, static_argnums=(1,))\n")
    assert lint_text(text) == []
    # …but without the static marking the same branch is a finding
    assert [f.rule for f in lint_text(text.replace(
        ", static_argnums=(1,)", ""))] == ["R401"]


def test_wait_for_is_exempt_from_l302():
    text = (
        "import threading\n"
        "class C:\n"
        "    def __init__(self):\n"
        "        self._cond = threading.Condition()\n"
        "        self._ok = False\n"
        "    def set(self):\n"
        "        with self._cond:\n"
        "            self._ok = True\n"
        "    def get(self):\n"
        "        with self._cond:\n"
        "            self._cond.wait_for(lambda: self._ok)\n")
    assert lint_text(text) == []


# -- suppressions -----------------------------------------------------------

def test_suppression_fixture():
    # the justified waivers (def-line and standalone-comment forms) hold;
    # the empty-reason waiver yields X001 *and* leaves its L301 alive
    got = rules_at(lint("suppressed.py"))
    assert got == [("L301", 31), ("X001", 30)]


def test_rule_registry_covers_all_emitted_rules():
    for name in ("D101", "D102", "C201", "C202", "L301", "L302", "L303",
                 "R401", "R402", "R403", "X000", "X001"):
        assert name in RULES


def test_parse_error_is_a_finding(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    found = analyze_file(str(bad), "broken.py")[0]
    assert [f.rule for f, _ in found] == ["X000"]


# -- baseline ---------------------------------------------------------------

def test_baseline_round_trip(tmp_path):
    found = lint("bad_locks.py")
    payload = baseline_mod.to_payload(found)
    path = tmp_path / "baseline.json"
    path.write_text(baseline_mod.render(payload))
    fresh, absorbed = baseline_mod.subtract(found, baseline_mod.load(str(path)))
    assert fresh == [] and absorbed == len(found)


def test_baseline_matches_on_snippet_not_line(tmp_path):
    # an unrelated edit that shifts every line must not resurrect
    # baselined findings: matching is (file, rule, stripped source line)
    found = lint("bad_locks.py")
    path = tmp_path / "baseline.json"
    path.write_text(baseline_mod.render(baseline_mod.to_payload(found)))
    shifted = tmp_path / "bad_locks.py"
    shifted.write_text("# an unrelated leading comment\n\n"
                       + (FIXTURES / "bad_locks.py").read_text())
    moved = analyze_file(str(shifted), "bad_locks.py")[0]
    assert {f.line for f, _ in moved} != {f.line for f, _ in found}
    fresh, absorbed = baseline_mod.subtract(moved, baseline_mod.load(str(path)))
    assert fresh == [] and absorbed == len(found)


def test_baseline_is_a_multiset(tmp_path):
    found = lint("bad_locks.py")
    path = tmp_path / "baseline.json"
    path.write_text(baseline_mod.render(baseline_mod.to_payload(found[:1])))
    fresh, absorbed = baseline_mod.subtract(found, baseline_mod.load(str(path)))
    assert absorbed == 1 and len(fresh) == len(found) - 1


# -- CLI --------------------------------------------------------------------

def test_cli_check_fails_on_each_bad_fixture(tmp_path):
    empty = str(tmp_path / "none.json")
    for name in ("bad_donation.py", "bad_collectives.py", "bad_locks.py",
                 "bad_retrace.py"):
        code = main([str(FIXTURES / name), "--check", "--baseline", empty])
        assert code == 1, name


def test_cli_check_passes_on_good_fixtures(tmp_path):
    empty = str(tmp_path / "none.json")
    for name in ("good_donation.py", "good_collectives.py",
                 "good_locks.py", "good_retrace.py"):
        code = main([str(FIXTURES / name), "--check", "--baseline", empty])
        assert code == 0, name


def test_cli_without_check_reports_but_exits_zero(tmp_path):
    code = main([str(FIXTURES / "bad_locks.py"),
                 "--baseline", str(tmp_path / "none.json")])
    assert code == 0


def test_cli_usage_error_on_missing_path():
    assert main(["/no/such/path.py", "--check"]) == 2


def test_cli_write_baseline_then_check(tmp_path):
    base = str(tmp_path / "baseline.json")
    target = str(FIXTURES / "bad_retrace.py")
    assert main([target, "--write-baseline", "--baseline", base]) == 0
    assert main([target, "--check", "--baseline", base]) == 0


def test_cli_report_artifact(tmp_path):
    import json
    report = tmp_path / "report.json"
    main([str(FIXTURES / "bad_donation.py"),
          "--baseline", str(tmp_path / "none.json"),
          "--report", str(report)])
    payload = json.loads(report.read_text())
    assert {f["rule"] for f in payload["findings"]} == {"D101", "D102"}
    assert all({"file", "line", "rule", "name", "message"} <= set(f)
               for f in payload["findings"])


def test_tools_entry_point_gates_the_repo():
    # the acceptance gate itself: the committed tree must be lint-clean
    # (fixed, suppressed-with-reason, or baselined) through the
    # PYTHONPATH-free entry point CI uses
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "repro_lint.py"), "--check"],
        cwd=str(REPO), capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr


# -- meta-gates -------------------------------------------------------------

def repo_findings():
    paths = [str(REPO / d) for d in DEFAULT_ROOTS if (REPO / d).is_dir()]
    return run_paths(paths, str(REPO))


def test_committed_baseline_is_fresh():
    # stale-baseline detector: --write-baseline over the committed tree
    # must reproduce analysis/baseline.json byte for byte
    committed = (REPO / "analysis" / "baseline.json").read_text()
    fresh = baseline_mod.render(baseline_mod.to_payload(repo_findings()))
    assert fresh == committed, (
        "analysis/baseline.json is stale — rerun "
        "`python -m repro.analysis --write-baseline` and commit it")


def test_analysis_package_is_stdlib_only():
    # the CI lint stage runs repro-lint without the ML deps installed;
    # the analyzer must never grow a jax/numpy import
    import ast
    allowed = {"__future__", "argparse", "ast", "dataclasses", "io", "json",
               "os", "re", "sys", "tokenize", "typing"}
    pkg = REPO / "src" / "repro" / "analysis"
    for py in pkg.glob("*.py"):
        tree = ast.parse(py.read_text())
        for node in ast.walk(tree):
            mods = []
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                mods = [node.module]
            for m in mods:
                root = m.split(".")[0]
                assert root in allowed or m.startswith("repro.analysis"), (
                    f"{py.name} imports {m} — repro.analysis is stdlib-only")


# -- the audited production sites stay pinned -------------------------------

def _lint_real(relpath: str):
    path = REPO / relpath
    return analyze_file(str(path), relpath)[0]


def test_audited_sites_are_clean():
    for rel in ("src/repro/runtime/executors.py",
                "src/repro/service/server.py",
                "src/repro/service/rate_limiter.py",
                "src/repro/launch/multiprocess.py"):
        assert _lint_real(rel) == [], rel


def test_unguarding_server_shard_state_is_caught(tmp_path):
    # acceptance demo: move a guarded read out of `with self._lock:`
    # in ReplayService.total_inserts and L301 must fire
    src = (REPO / "src" / "repro" / "service" / "server.py").read_text()
    before = ('        with self._lock:\n'
              '            return self._inserts\n')
    after = ('        with self._lock:\n'
             '            pass\n'
             '        return self._inserts\n')
    assert before in src
    mutated = tmp_path / "server.py"
    mutated.write_text(src.replace(before, after, 1))
    found = analyze_file(str(mutated), "server.py")[0]
    assert ("L301", "_inserts") in [
        (f.rule, "_inserts" if "_inserts" in f.message else "")
        for f, _ in found]


def test_reading_donated_replay_after_jit_is_caught(tmp_path):
    # acceptance demo: read state.replay after the donating chunk call
    # in FusedExecutor and D101 must fire
    src = (REPO / "src" / "repro" / "runtime" / "executors.py").read_text()
    before = ("        def run(state: LoopState):\n"
              "            return fn(state.replay, state._replace(replay=()))\n")
    after = ("        def run(state: LoopState):\n"
             "            out = fn(state.replay, state._replace(replay=()))\n"
             "            leftover = state.replay.count\n"
             "            return out, leftover\n")
    assert before in src
    mutated = tmp_path / "executors.py"
    mutated.write_text(src.replace(before, after, 1))
    found = analyze_file(str(mutated), "executors.py")[0]
    assert "D101" in {f.rule for f, _ in found}


def test_misaligned_donate_argnum_is_caught(tmp_path):
    src = (REPO / "src" / "repro" / "runtime" / "executors.py").read_text()
    assert "donate_argnums=(0,)" in src
    mutated = tmp_path / "executors.py"
    mutated.write_text(src.replace("donate_argnums=(0,)",
                                   "donate_argnums=(7,)", 1))
    found = analyze_file(str(mutated), "executors.py")[0]
    assert "D102" in {f.rule for f, _ in found}
