"""Lazy-writing replay transactions (DESIGN.md §9): lazy ≡ eager
bit-exact at flush points, the pending-delta ledger, exactly one
upward-propagation pass per loop iteration (op-count trace), fused
sample+gather dispatch, donated replay buffers, and the committed
replay-microbenchmark acceptance (lazy beats eager)."""

import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sumtree
from repro.core.replay import PrioritizedReplay, ReplayConfig

EXAMPLE = {
    "obs": jnp.zeros((4,), jnp.float32),
    "action": jnp.zeros((), jnp.int32),
    "reward": jnp.zeros(()),
}

BACKENDS = ("xla", "pallas")


def make(capacity=256, backend="xla", **kw):
    return PrioritizedReplay(
        ReplayConfig(capacity=capacity, fanout=8, backend=backend, **kw),
        EXAMPLE)


def items(n, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "obs": jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32)),
        "action": jnp.asarray(rng.integers(0, 3, n).astype(np.int32)),
        "reward": jnp.asarray(rng.uniform(0, 1, n).astype(np.float32)),
    }


# -- lazy ≡ eager at flush points ---------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_lazy_flush_bitexact_vs_eager_per_op_flush(backend):
    """Deferring many leaf writes and flushing once must reach the
    bit-identical tree as flushing after every op: the interior rebuild
    is a pure function of the leaves, so the write history can't
    matter."""
    rb = make(capacity=64, backend=backend)
    st_lazy = rb.insert(rb.init(), items(64))
    st_eager = st_lazy

    # duplicate-heavy interleaving: begin, double priority update, commit
    st_lazy, slots = rb.insert_begin(st_lazy, 16, lazy=True)
    st_eager, slots_e = rb.insert_begin(st_eager, 16, lazy=True)
    st_eager = rb.flush(st_eager)
    np.testing.assert_array_equal(np.asarray(slots), np.asarray(slots_e))

    idx = jnp.asarray([3, 40, 3, 3, 25, 40, 63, 3], jnp.int32)
    td = jnp.linspace(0.1, 3.0, 8)
    st_lazy = rb.update_priorities(st_lazy, idx, td, lazy=True)
    st_eager = rb.flush(rb.update_priorities(st_eager, idx, td, lazy=True))

    st_lazy = rb.insert_commit(st_lazy, slots, items(16, seed=1), lazy=True)
    st_eager = rb.flush(
        rb.insert_commit(st_eager, slots_e, items(16, seed=1), lazy=True))

    st_lazy = rb.flush(st_lazy)   # ONE merged propagation pass
    np.testing.assert_array_equal(np.asarray(st_lazy.tree),
                                  np.asarray(st_eager.tree))
    assert int(st_lazy.pending) == 0
    assert sumtree.check_invariant(rb.spec, st_lazy.tree)


@pytest.mark.parametrize("backend", BACKENDS)
def test_lazy_matches_legacy_eager_update_allclose(backend):
    """The lazy transaction and the legacy eager path (incremental
    delta propagation per op) compute the same tree up to f32
    accumulation order."""
    rb = make(capacity=128, backend=backend)
    st0 = rb.insert(rb.init(), items(128))

    def run(lazy):
        st, slots = rb.insert_begin(st0, 32, lazy=lazy)
        if lazy:
            st = rb.flush(st)
        idx = jnp.asarray([5, 5, 77, 100, 5, 77], jnp.int32)
        st = rb.update_priorities(st, idx, jnp.linspace(0.2, 2.0, 6),
                                  lazy=lazy)
        st = rb.insert_commit(st, slots, items(32, seed=2), lazy=lazy)
        return rb.flush(st) if lazy else st

    lazy_tree = np.asarray(run(True).tree)
    eager_tree = np.asarray(run(False).tree)
    np.testing.assert_allclose(lazy_tree, eager_tree, rtol=1e-5, atol=1e-4)


def test_inflight_slots_invisible_after_flush():
    """The paper's lazy-write invariant holds through the transaction:
    once the insert-begin zeros are flushed, sampling can never select
    an in-flight slot, even with unflushed priority updates pending."""
    rb = make(capacity=64)
    st = rb.insert(rb.init(), items(64))
    st, slots = rb.insert_begin(st, 16, lazy=True)
    st = rb.flush(st)
    for seed in range(5):
        idx, _, _ = rb.sample(st, jax.random.PRNGKey(seed), 64)
        assert not np.isin(np.asarray(idx), np.asarray(slots)).any()
    st = rb.insert_commit(st, slots, items(16, seed=1), lazy=True)
    st = rb.flush(st)
    pri = rb.get_priority(st, slots)
    assert (np.asarray(pri) == float(st.max_priority)).all()


def test_pending_ledger_counts_and_flush_resets():
    rb = make(capacity=64)
    st = rb.insert(rb.init(), items(64))
    assert int(st.pending) == 0          # eager insert leaves no debt
    st, slots = rb.insert_begin(st, 8, lazy=True)
    assert int(st.pending) == 8
    st = rb.update_priorities(st, jnp.arange(4), jnp.ones(4), lazy=True)
    assert int(st.pending) == 12
    st = rb.insert_commit(st, slots, items(8, seed=3), lazy=True)
    assert int(st.pending) == 20
    st = rb.flush(st)
    assert int(st.pending) == 0
    assert sumtree.check_invariant(rb.spec, st.tree)
    # flushing a clean state is the identity
    st2 = rb.flush(st)
    np.testing.assert_array_equal(np.asarray(st.tree), np.asarray(st2.tree))


# -- fused sample+gather dispatch ---------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_sample_gather_matches_split(backend):
    """ReplayConfig.fused_sample_gather only changes the execution
    shape, never the draws or the gathered rows."""
    data = items(200, seed=4)
    rb_f = make(capacity=256, backend=backend, fused_sample_gather=True)
    rb_s = make(capacity=256, backend=backend, fused_sample_gather=False)
    st_f = rb_f.insert(rb_f.init(), data)
    st_s = rb_s.insert(rb_s.init(), data)
    for seed in range(3):
        i_f, it_f, w_f = rb_f.sample(st_f, jax.random.PRNGKey(seed), 64)
        i_s, it_s, w_s = rb_s.sample(st_s, jax.random.PRNGKey(seed), 64)
        np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_s))
        np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_s),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(it_f["obs"]),
                                   np.asarray(it_s["obs"]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(it_f["action"]),
                                      np.asarray(it_s["action"]))
        assert it_f["action"].dtype == jnp.int32


# -- tree_backend selection (post use_kernels removal) ------------------------


def test_use_kernels_alias_is_gone():
    """The deprecated ``use_kernels`` alias completed its deprecation
    cycle: the field no longer exists on either config, and backend
    selection goes through ``backend=`` alone."""
    with pytest.raises(TypeError, match="use_kernels"):
        ReplayConfig(capacity=64, use_kernels=True)
    assert ReplayConfig(capacity=64).tree_backend == "xla"
    assert ReplayConfig(capacity=64, backend="pallas").tree_backend == "pallas"


def test_unknown_backend_rejected_at_construction():
    with pytest.raises(ValueError, match="unknown tree-ops backend"):
        PrioritizedReplay(
            ReplayConfig(capacity=64, backend="cuda"), EXAMPLE)


def test_sharded_config_backend_selection():
    from repro.core.distributed import ShardedReplayConfig
    with pytest.raises(TypeError, match="use_kernels"):
        ShardedReplayConfig(capacity_per_shard=64, use_kernels=True)
    assert ShardedReplayConfig(capacity_per_shard=64).tree_backend == "xla"
    assert ShardedReplayConfig(capacity_per_shard=64,
                               backend="pallas").tree_backend == "pallas"


# -- exactly one propagation pass per loop iteration (op-count trace) ---------


class _CountingTreeOps:
    """TreeOps spy: counts propagation passes at trace time."""

    def __init__(self, inner):
        self._inner = inner
        self.name = inner.name
        self.update_calls = 0        # eager op: one propagation pass each
        self.flush_calls = 0         # merged pass
        self.write_calls = 0         # leaf-only (no propagation)

    def update(self, *a, **kw):
        self.update_calls += 1
        return self._inner.update(*a, **kw)

    def write_leaves(self, *a, **kw):
        self.write_calls += 1
        return self._inner.write_leaves(*a, **kw)

    def flush(self, *a, **kw):
        self.flush_calls += 1
        return self._inner.flush(*a, **kw)

    def sample(self, *a, **kw):
        return self._inner.sample(*a, **kw)

    def gather(self, *a, **kw):
        return self._inner.gather(*a, **kw)

    def sample_gather(self, *a, **kw):
        return self._inner.sample_gather(*a, **kw)


def _traced_step_counts(lazy_replay):
    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.envs.classic import make_vec
    from repro.runtime.loop import LoopConfig, init_loop_state, make_step

    env_fn = functools.partial(make_vec, "cartpole")
    spec, v_reset, v_step = env_fn(4)
    agent = make_dqn(spec, DQNConfig())
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    replay = PrioritizedReplay(ReplayConfig(capacity=512, fanout=8), example)
    spy = _CountingTreeOps(replay.ops)
    replay.ops = spy
    # update_interval == n_envs → period 1, exactly one learner call per
    # iteration (the schedule every executor realizes by default)
    cfg = LoopConfig(batch_size=32, warmup=0, update_interval=4,
                     lazy_replay=lazy_replay)
    step = make_step(agent, replay, v_step, cfg, 4)
    state = init_loop_state(agent, replay, v_reset, jax.random.PRNGKey(0), 4)
    jax.make_jaxpr(step)(state)      # trace only — the spy counts calls
    return spy


def test_loop_lazy_single_propagation_pass_per_iteration():
    """The acceptance criterion: the traced lazy step contains exactly
    ONE upward-propagation pass (the flush), zero eager update passes —
    vs three propagation passes in the eager step."""
    spy = _traced_step_counts(lazy_replay=True)
    assert spy.flush_calls == 1
    assert spy.update_calls == 0
    # begin + update_priorities + commit all went leaf-only
    assert spy.write_calls == 3

    spy = _traced_step_counts(lazy_replay=False)
    assert spy.flush_calls == 0
    assert spy.update_calls == 3     # the pre-optimization baseline


# -- donated replay buffers ---------------------------------------------------


def test_executor_chunk_donates_replay_but_not_actor_params():
    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.envs.classic import make_vec
    from repro.runtime.executors import AsyncExecutor
    from repro.runtime.loop import LoopConfig

    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    replay = PrioritizedReplay(ReplayConfig(capacity=512, fanout=8), example)
    ex = AsyncExecutor(agent, replay, env_fn, LoopConfig(batch_size=32,
                                                         warmup=0),
                       n_envs=4, publish_interval=2, scan_chunk=4)
    st = ex.init(jax.random.PRNGKey(0))
    old_tree, old_storage = st.replay.tree, st.replay.storage["obs"]
    old_actor = jax.tree.leaves(st.actor_params)[0]
    st2, _ = ex.run_chunk(st)
    # tree + storage buffers were donated (no surviving per-chunk copy)…
    assert old_tree.is_deleted()
    assert old_storage.is_deleted()
    # …while non-replay state stays readable across the chunk boundary
    # (the async double-buffer contract tests rely on this)
    assert not old_actor.is_deleted()
    np.asarray(old_actor)
    assert not st2.replay.tree.is_deleted()


# -- the committed microbenchmark acceptance ----------------------------------


def test_committed_bench_replay_shows_lazy_beating_eager():
    """BENCH_replay.json at the repo root (the committed smoke sweep the
    CI perf gate diffs against) must show the lazy path ahead of the
    eager path on every like-for-like (backend, fanout, fused) pair,
    and carry the fused-vs-split pallas arms for the kernel delta."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_replay.json")
    with open(path) as f:
        payload = json.load(f)
    assert payload["metric"] == "replay_ops_per_s"
    by_arm = {}
    for p in payload["points"]:
        key = (p["backend"], p["fanout"], p["fused"])
        by_arm.setdefault(key, {})[p["mode"]] = p["replay_ops_per_s"]
    pairs = {k: v for k, v in by_arm.items()
             if {"eager", "lazy"} <= set(v)}
    assert pairs, "no eager/lazy pair in the committed sweep"
    for key, modes in pairs.items():
        assert modes["lazy"] > modes["eager"], (
            f"lazy must beat eager for (backend, fanout, fused)={key}: "
            f"{modes}")
    # the fused-vs-split kernel arms are present (delta reported, not
    # gated: interpret mode on CPU penalizes the fused grid)
    fused_arms = {k for k in by_arm if k[2]}
    split_arms = {(b, f, False) for b, f, _ in fused_arms}
    assert fused_arms and split_arms <= set(by_arm)
