"""K-ary sum tree invariants: exact prefix-sum semantics, batched update
semantics (last-writer-wins), sampling distribution — incl. hypothesis
property tests over capacities/fanouts/priorities."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import sumtree


def build_ref(capacity, seed=0, low=0.0, high=2.0):
    rng = np.random.default_rng(seed)
    pri = rng.uniform(low, high, capacity).astype(np.float32)
    return pri


@pytest.mark.parametrize("capacity,fanout", [
    (1, 2), (5, 4), (100, 8), (1000, 128), (4096, 128), (4097, 64),
    (65536, 256), (999, 2),
])
def test_build_invariant_and_total(capacity, fanout):
    spec = sumtree.make_spec(capacity, fanout)
    pri = build_ref(capacity)
    tree = sumtree.build(spec, jnp.asarray(pri))
    assert sumtree.check_invariant(spec, tree)
    np.testing.assert_allclose(float(tree[0]), pri.sum(), rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(sumtree.leaves(spec, tree)), pri, rtol=1e-6)


def test_levels_are_fanout_aligned():
    spec = sumtree.make_spec(1000, 128)
    assert all(s % spec.fanout == 0 for s in spec.level_sizes)
    assert spec.level_sizes[0] == spec.fanout          # padded root (paper)
    # space complexity Θ(N + (N-1)/(K-1)) + padded root/top groups — §IV-C5
    assert spec.total_size <= 1000 + 999 // 127 + 3 * 128 + 2


def test_update_sequential_semantics_with_duplicates():
    spec = sumtree.make_spec(50, 4)
    pri = build_ref(50, seed=1)
    tree = sumtree.build(spec, jnp.asarray(pri))
    idx = jnp.array([7, 3, 7, 7, 12, 3], jnp.int32)
    val = jnp.array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0], jnp.float32)
    tree2 = sumtree.update(spec, tree, idx, val)
    ref = pri.copy()
    for i, v in zip(np.asarray(idx), np.asarray(val)):
        ref[i] = v
    np.testing.assert_allclose(np.asarray(sumtree.leaves(spec, tree2)), ref,
                               rtol=1e-5)
    assert sumtree.check_invariant(spec, tree2)


def test_sample_matches_inverse_cdf_exactly():
    spec = sumtree.make_spec(777, 16)
    pri = build_ref(777, seed=2, low=0.01)
    tree = sumtree.build(spec, jnp.asarray(pri))
    rng = np.random.default_rng(3)
    u = rng.uniform(0, 1, 2048).astype(np.float32)
    leaf, p = sumtree.sample(spec, tree, jnp.asarray(u))
    cdf = np.cumsum(pri)
    expect = np.searchsorted(cdf, u * float(tree[0]), side="left")
    expect = np.minimum(expect, 776)
    match = (np.asarray(leaf) == expect).mean()
    assert match > 0.999  # fp ties only
    np.testing.assert_allclose(np.asarray(p), pri[np.asarray(leaf)], rtol=1e-5)


def test_zero_priority_never_sampled():
    """The lazy-writing invariant (paper §IV-D2): priority-0 slots are
    invisible to sampling."""
    spec = sumtree.make_spec(256, 8)
    pri = build_ref(256, seed=4, low=0.5)
    zero_at = np.array([0, 17, 100, 255])
    pri[zero_at] = 0.0
    tree = sumtree.build(spec, jnp.asarray(pri))
    u = jnp.asarray(np.random.default_rng(5).uniform(0, 1, 4096).astype(np.float32))
    leaf, _ = sumtree.sample(spec, tree, u)
    assert not np.isin(np.asarray(leaf), zero_at).any()


def test_sampling_distribution_chi_square():
    spec = sumtree.make_spec(16, 4)
    pri = np.asarray([1, 2, 3, 4, 5, 6, 7, 8, 1, 2, 3, 4, 5, 6, 7, 8],
                     np.float32)
    tree = sumtree.build(spec, jnp.asarray(pri))
    n = 40000
    u = jax.random.uniform(jax.random.PRNGKey(0), (n,))
    leaf, _ = sumtree.sample(spec, tree, u)
    counts = np.bincount(np.asarray(leaf), minlength=16)
    expected = pri / pri.sum() * n
    chi2 = ((counts - expected) ** 2 / expected).sum()
    assert chi2 < 50  # df=15; 50 is far beyond the 0.999 quantile (~37.7)


@settings(max_examples=30, deadline=None)
@given(
    capacity=st.integers(1, 300),
    fanout=st.sampled_from([2, 3, 4, 8, 16, 128]),
    seed=st.integers(0, 10_000),
)
def test_property_update_then_invariant(capacity, fanout, seed):
    spec = sumtree.make_spec(capacity, fanout)
    rng = np.random.default_rng(seed)
    pri = rng.uniform(0, 3, capacity).astype(np.float32)
    tree = sumtree.build(spec, jnp.asarray(pri))
    b = rng.integers(1, 20)
    idx = rng.integers(0, capacity, b).astype(np.int32)
    val = rng.uniform(0, 5, b).astype(np.float32)
    tree = sumtree.update(spec, tree, jnp.asarray(idx), jnp.asarray(val))
    assert sumtree.check_invariant(spec, tree)
    ref = pri.copy()
    for i, v in zip(idx, val):
        ref[i] = v
    np.testing.assert_allclose(np.asarray(sumtree.leaves(spec, tree)), ref,
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    capacity=st.integers(2, 200),
    fanout=st.sampled_from([2, 4, 8, 64]),
    seed=st.integers(0, 10_000),
)
def test_property_sample_in_range_and_positive(capacity, fanout, seed):
    spec = sumtree.make_spec(capacity, fanout)
    rng = np.random.default_rng(seed)
    pri = rng.uniform(0.1, 3, capacity).astype(np.float32)
    tree = sumtree.build(spec, jnp.asarray(pri))
    u = jnp.asarray(rng.uniform(0, 1, 64).astype(np.float32))
    leaf, p = sumtree.sample(spec, tree, u)
    assert (np.asarray(leaf) >= 0).all() and (np.asarray(leaf) < capacity).all()
    assert (np.asarray(p) > 0).all()


def test_add_accumulates_duplicates():
    spec = sumtree.make_spec(64, 8)
    tree = sumtree.build(spec, jnp.zeros(64))
    idx = jnp.array([5, 5, 5, 9], jnp.int32)
    tree = sumtree.add(spec, tree, idx, jnp.ones(4))
    leaves = np.asarray(sumtree.leaves(spec, tree))
    assert leaves[5] == 3.0 and leaves[9] == 1.0
    assert float(tree[0]) == 4.0
