"""Sharded replay buffer on a real (forced 8-device) mesh via shard_map.

Runs in a subprocess because the device count must be set before jax
initializes (the same constraint the dry-run handles); validates the
stratified-sampling + global-IS-weights path end to end."""

import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.core.distributed import ShardedPrioritizedReplay, ShardedReplayConfig
    from repro.launch.mesh import use_mesh

    assert jax.device_count() == 8
    mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
    example = {"obs": jnp.zeros((3,), jnp.float32),
               "reward": jnp.zeros((), jnp.float32)}
    rb = ShardedPrioritizedReplay(
        ShardedReplayConfig(capacity_per_shard=64, fanout=8,
                            axis_names=("data",)), example)

    def init_fn():
        return rb.init()

    def insert_fn(state, items):
        return rb.insert(state, items)

    def sample_fn(state, rng):
        idx, items, w = rb.sample(state, rng[0], batch_per_shard=16, beta=1.0)
        pri = rb.local.get_priority(state, idx)
        g_tot, g_cnt = rb.global_stats(state)
        return idx, items, w, pri, g_tot, g_cnt

    def specs_like(shapes):
        # per-shard arrays concat over 'data'; rank-0 scalars (head/count/
        # max_priority) are identical across shards here → replicated spec
        return jax.tree.map(
            lambda s: P("data") if getattr(s, "ndim", 0) > 0 else P(), shapes)

    state_shapes = jax.eval_shape(init_fn)
    state_specs = specs_like(state_shapes)

    with use_mesh(mesh):
        sm_init = shard_map(init_fn, mesh=mesh, in_specs=(),
                            out_specs=state_specs, check_rep=False)
        state = sm_init()
        # per-shard distinct rewards so shards are distinguishable
        items = {
            "obs": jnp.arange(8 * 32 * 3, dtype=jnp.float32).reshape(8 * 32, 3),
            "reward": jnp.repeat(jnp.arange(8, dtype=jnp.float32), 32),
        }
        sm_insert = shard_map(insert_fn, mesh=mesh,
                              in_specs=(state_specs, P("data")),
                              out_specs=state_specs, check_rep=False)
        state = sm_insert(state, items)
        assert int(state.count) == 32  # per-shard count (replicated scalar)

        rngs = jax.random.split(jax.random.PRNGKey(0), 8)
        sm_sample = shard_map(sample_fn, mesh=mesh,
                              in_specs=(state_specs, P("data")),
                              out_specs=(P("data"), P("data"), P("data"),
                                         P("data"), P(), P()),
                              check_rep=False)
        idx, got, w, pri, g_tot, g_cnt = sm_sample(state, rngs)
        # global stats from the psum: full global count across all shards
        np.testing.assert_allclose(float(g_cnt), 256.0)
        assert float(g_tot) > 0
        # stratified locality: each shard sampled its own rewards
        rew = np.asarray(got["reward"]).reshape(8, 16)
        for d in range(8):
            assert (rew[d] == d).all(), (d, rew[d])
        # weights computed against the GLOBAL distribution ∈ (0, 1]
        w_ = np.asarray(w)
        assert (w_ > 0).all() and w_.max() <= 1.0 + 1e-6
        # multi-shard weight parity: every shard normalized by the SAME
        # (pmax'd) global max — recomputing the PER weights from the
        # global stats on the host and dividing by the max over ALL
        # shards' draws must reproduce the shard_map result exactly.
        # (Before the pmax hook each shard divided by its local batch
        # max, an inconsistent per-shard scale factor.)
        pri_ = np.asarray(pri)
        w_ref = (float(g_cnt) * pri_ / float(g_tot)) ** (-1.0)
        w_ref = np.where(pri_ > 0, w_ref, 0.0)
        w_ref = w_ref / w_ref.max()
        np.testing.assert_allclose(w_, w_ref, rtol=1e-5)
        np.testing.assert_allclose(w_.max(), 1.0, rtol=1e-6)
    print("SHARDED_REPLAY_OK")
""")


def test_sharded_replay_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=420, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "SHARDED_REPLAY_OK" in r.stdout, r.stdout[-800:] + r.stderr[-2000:]


TWO_AXIS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.distributed import ShardedPrioritizedReplay, ShardedReplayConfig
    from repro.launch.mesh import pod_data_mesh, use_mesh

    assert jax.device_count() == 4
    mesh = pod_data_mesh(2, 2)
    axes = ("pod", "data")
    example = {"obs": jnp.zeros((3,), jnp.float32),
               "reward": jnp.zeros((), jnp.float32)}
    rb = ShardedPrioritizedReplay(
        ShardedReplayConfig(capacity_per_shard=64, fanout=8,
                            axis_names=axes), example)

    def init_fn():
        return rb.init()

    def insert_fn(state, items):
        return rb.insert(state, items)

    def sample_fn(state, rng):
        idx, items, w = rb.sample(state, rng[0], batch_per_shard=16, beta=1.0)
        pri = rb.local.get_priority(state, idx)
        g_tot, g_cnt = rb.global_stats(state)
        return idx, items, w, pri, g_tot, g_cnt

    def specs_like(shapes):
        return jax.tree.map(
            lambda s: P(axes) if getattr(s, "ndim", 0) > 0 else P(), shapes)

    state_specs = specs_like(jax.eval_shape(init_fn))

    with use_mesh(mesh):
        state = shard_map(init_fn, mesh=mesh, in_specs=(),
                          out_specs=state_specs, check_rep=False)()
        # per-mesh-cell distinct rewards (flattened shard id 0..3) with
        # distinct priority masses per cell, so the global stats are a
        # nontrivial sum over BOTH axes
        items = {
            "obs": jnp.arange(4 * 32 * 3, dtype=jnp.float32).reshape(4 * 32, 3),
            "reward": jnp.repeat(jnp.arange(4, dtype=jnp.float32), 32),
        }
        state = shard_map(insert_fn, mesh=mesh,
                          in_specs=(state_specs, P(axes)),
                          out_specs=state_specs, check_rep=False)(state, items)
        # skew cell 3's priorities upward so the global max normalizer
        # provably comes from a different cell than 0..2 sample locally
        def skew_fn(state):
            sid = jax.lax.axis_index("pod") * 2 + jax.lax.axis_index("data")
            pri = jnp.where(sid == 3, 9.0, 1.0) * jnp.ones((32,))
            return rb.update_priorities(state, jnp.arange(32), pri)
        state = shard_map(skew_fn, mesh=mesh, in_specs=(state_specs,),
                          out_specs=state_specs, check_rep=False)(state)

        rngs = jax.random.split(jax.random.PRNGKey(0), 4)
        idx, got, w, pri, g_tot, g_cnt = shard_map(
            sample_fn, mesh=mesh,
            in_specs=(state_specs, P(axes)),
            out_specs=(P(axes), P(axes), P(axes), P(axes), P(), P()),
            check_rep=False)(state, rngs)

        # global stats psum over BOTH axes: all 4 cells' counts/totals
        np.testing.assert_allclose(float(g_cnt), 128.0)
        # stratified locality: each cell sampled its own rewards
        rew = np.asarray(got["reward"]).reshape(4, 16)
        for d in range(4):
            assert (rew[d] == d).all(), (d, rew[d])
        # IS weights against the GLOBAL two-axis distribution: recompute
        # on the host from the psum'd stats and the pmax'd global max —
        # must match the shard_map result exactly for every cell
        pri_ = np.asarray(pri)
        w_ = np.asarray(w)
        w_ref = (float(g_cnt) * pri_ / float(g_tot)) ** (-1.0)
        w_ref = np.where(pri_ > 0, w_ref, 0.0)
        w_ref = w_ref / w_ref.max()
        np.testing.assert_allclose(w_, w_ref, rtol=1e-5)
        # the max normalizer is global: cells 0..2 (low priority, high
        # weight) dominate, cell 3's draws carry weight < 1
        np.testing.assert_allclose(w_.max(), 1.0, rtol=1e-6)
        assert w_.reshape(4, 16)[3].max() < 0.9
    print("TWO_AXIS_REPLAY_OK")
""")


def test_sharded_replay_two_axis_multidevice():
    """Two-axis ``axis_names=("pod", "data")`` global stats and IS
    weights under a real 2×2 shard_map (the multi-axis loops in
    core/distributed.py, previously untested beyond one axis)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", TWO_AXIS_SCRIPT],
                       capture_output=True, text=True, timeout=420, env=env,
                       cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "TWO_AXIS_REPLAY_OK" in r.stdout, r.stdout[-800:] + r.stderr[-2000:]
