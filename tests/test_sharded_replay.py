"""Sharded replay buffer on a real (forced 8-device) mesh via shard_map.

Runs in a subprocess because the device count must be set before jax
initializes (the same constraint the dry-run handles); validates the
stratified-sampling + global-IS-weights path end to end."""

import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.core.distributed import ShardedPrioritizedReplay, ShardedReplayConfig
    from repro.launch.mesh import use_mesh

    assert jax.device_count() == 8
    mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
    example = {"obs": jnp.zeros((3,), jnp.float32),
               "reward": jnp.zeros((), jnp.float32)}
    rb = ShardedPrioritizedReplay(
        ShardedReplayConfig(capacity_per_shard=64, fanout=8,
                            axis_names=("data",)), example)

    def init_fn():
        return rb.init()

    def insert_fn(state, items):
        return rb.insert(state, items)

    def sample_fn(state, rng):
        idx, items, w = rb.sample(state, rng[0], batch_per_shard=16, beta=1.0)
        pri = rb.local.get_priority(state, idx)
        g_tot, g_cnt = rb.global_stats(state)
        return idx, items, w, pri, g_tot, g_cnt

    def specs_like(shapes):
        # per-shard arrays concat over 'data'; rank-0 scalars (head/count/
        # max_priority) are identical across shards here → replicated spec
        return jax.tree.map(
            lambda s: P("data") if getattr(s, "ndim", 0) > 0 else P(), shapes)

    state_shapes = jax.eval_shape(init_fn)
    state_specs = specs_like(state_shapes)

    with use_mesh(mesh):
        sm_init = shard_map(init_fn, mesh=mesh, in_specs=(),
                            out_specs=state_specs, check_rep=False)
        state = sm_init()
        # per-shard distinct rewards so shards are distinguishable
        items = {
            "obs": jnp.arange(8 * 32 * 3, dtype=jnp.float32).reshape(8 * 32, 3),
            "reward": jnp.repeat(jnp.arange(8, dtype=jnp.float32), 32),
        }
        sm_insert = shard_map(insert_fn, mesh=mesh,
                              in_specs=(state_specs, P("data")),
                              out_specs=state_specs, check_rep=False)
        state = sm_insert(state, items)
        assert int(state.count) == 32  # per-shard count (replicated scalar)

        rngs = jax.random.split(jax.random.PRNGKey(0), 8)
        sm_sample = shard_map(sample_fn, mesh=mesh,
                              in_specs=(state_specs, P("data")),
                              out_specs=(P("data"), P("data"), P("data"),
                                         P("data"), P(), P()),
                              check_rep=False)
        idx, got, w, pri, g_tot, g_cnt = sm_sample(state, rngs)
        # global stats from the psum: full global count across all shards
        np.testing.assert_allclose(float(g_cnt), 256.0)
        assert float(g_tot) > 0
        # stratified locality: each shard sampled its own rewards
        rew = np.asarray(got["reward"]).reshape(8, 16)
        for d in range(8):
            assert (rew[d] == d).all(), (d, rew[d])
        # weights computed against the GLOBAL distribution ∈ (0, 1]
        w_ = np.asarray(w)
        assert (w_ > 0).all() and w_.max() <= 1.0 + 1e-6
        # multi-shard weight parity: every shard normalized by the SAME
        # (pmax'd) global max — recomputing the PER weights from the
        # global stats on the host and dividing by the max over ALL
        # shards' draws must reproduce the shard_map result exactly.
        # (Before the pmax hook each shard divided by its local batch
        # max, an inconsistent per-shard scale factor.)
        pri_ = np.asarray(pri)
        w_ref = (float(g_cnt) * pri_ / float(g_tot)) ** (-1.0)
        w_ref = np.where(pri_ > 0, w_ref, 0.0)
        w_ref = w_ref / w_ref.max()
        np.testing.assert_allclose(w_, w_ref, rtol=1e-5)
        np.testing.assert_allclose(w_.max(), 1.0, rtol=1e-6)
    print("SHARDED_REPLAY_OK")
""")


def test_sharded_replay_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=420, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "SHARDED_REPLAY_OK" in r.stdout, r.stdout[-800:] + r.stderr[-2000:]
