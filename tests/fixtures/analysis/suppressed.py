# ruff: noqa
"""Suppression-mechanics fixtures.

Expected findings: exactly one X001 (empty reason) and one L301 (the
empty-reason waiver does not suppress).  Everything else is waived with
a justification — def-line waivers cover the body, standalone comments
cover the next statement.
"""
import threading


class CallerHolds:
    def __init__(self):
        self._lock = threading.Lock()
        self._cond = threading.Condition()
        self._n = 0
        self._flag = False

    def bump(self):
        with self._lock:
            self._n += 1

    def set_flag(self):
        with self._cond:
            self._flag = True

    def _peek(self):  # repro-lint: disable=L301(caller holds self._lock)
        return self._n

    def peek_unlocked(self):  # repro-lint: disable=L301()
        return self._n

    def poke(self):
        # repro-lint: disable=L303(benchmark-only poke; the race is acceptable here)
        self._cond.notify_all()
