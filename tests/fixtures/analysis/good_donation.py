# ruff: noqa
"""Known-good donation fixtures — zero findings expected.

The donated binding is consumed exactly once; later code uses the
returned value (linear handoff) or rebinds the root (loop handoff).
"""
import jax


def chunk(replay, rest):
    return rest, replay


fn = jax.jit(chunk, donate_argnums=(0,))
aligned = jax.jit(chunk, donate_argnums=(0,), static_argnums=(1,))


def linear_handoff(state):
    rest, replay = fn(state.replay, state)
    return rest, replay.count


def loop_handoff(state):
    for _ in range(4):
        state = fn(state.replay, state)[0]
    return state
