# ruff: noqa
"""Known-good collective fixtures — zero findings expected.

Closure-driven loops and trace-time shape probes are uniform across the
gang (the function traces once, identically, on every process), and the
axis names come from the known mesh set.
"""
import jax
from jax.experimental.shard_map import shard_map

AXES = ("pod", "data")


def uniform(x):
    for ax in AXES:
        x = jax.lax.pmean(x, ax)
    if x.ndim == 2:
        x = jax.lax.psum(x, "pod")
    return x


mapped = shard_map(uniform, mesh=None, in_specs=None, out_specs=None)
