# ruff: noqa
"""Known-bad retrace fixtures.

R401: Python branch on a traced parameter.
R402: traced function mutating or freezing mutable external state.
R403: unhashable literal at a static_argnums position.
"""
import jax

_STEP_SIZE = 0.1


def set_step(v):
    global _STEP_SIZE
    _STEP_SIZE = v


@jax.jit
def traced_branch(x, n):
    if n > 0:                          # R401: n is traced
        x = x + 1.0
    return x


@jax.jit
def stale_closure(x):
    return x * _STEP_SIZE              # R402: frozen at trace time


class Counter:
    def __init__(self):
        self.n = 0

    @jax.jit
    def bump(self, x):
        self.n = self.n + 1            # R402: trace-time write to self
        return x + self.n


def f(x, cfg):
    return x


jitted = jax.jit(f, static_argnums=(1,))


def call_bad(x):
    return jitted(x, [1, 2, 3])        # R403: list is unhashable
