# ruff: noqa
"""Known-bad collective fixtures.

C201: collectives under control flow fed by nonuniform host sources —
each gang process can disagree on the launch count and deadlock gloo.
C202: axis-name literals outside the known mesh axis set.
"""
import time

import jax
from jax.experimental.shard_map import shard_map


def time_divergent(x):
    if time.monotonic() > 100.0:
        x = jax.lax.psum(x, "data")    # C201: time differs per host
    return x


def rank_divergent(x):
    if jax.process_index() == 0:
        x = jax.lax.pmax(x, "pod")     # C201: only rank 0 launches
    return x


def typo_axis(x):
    return jax.lax.pmean(x, "pods")    # C202: not pod/data/model


m1 = shard_map(time_divergent, mesh=None, in_specs=None, out_specs=None)
m2 = shard_map(rank_divergent, mesh=None, in_specs=None, out_specs=None)
m3 = shard_map(typo_axis, mesh=None, in_specs=None, out_specs=None)
