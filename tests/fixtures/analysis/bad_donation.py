# ruff: noqa
"""Known-bad donation fixtures — every marked line must be flagged.

D101: reads of a donated binding after the donating call.
D102: argnums misaligned with the callee signature.
"""
import jax


def chunk(replay, rest):
    return rest, replay


fn = jax.jit(chunk, donate_argnums=(0,))


def use_after_donate(state):
    out = fn(state.replay, state)
    size = state.replay.count          # D101: donated buffer read
    return out, size


def use_after_donate_in_loop(state):
    acc = None
    for _ in range(4):
        acc = state.replay.count       # D101: stale on iteration 2+
        _out = fn(state.replay, state)
    return acc


def two_arg(a, b):
    return a


misaligned = jax.jit(two_arg, donate_argnums=(5,))                    # D102
overlapped = jax.jit(two_arg, donate_argnums=(0,), static_argnums=(0,))  # D102
