# ruff: noqa
"""Known-bad lock-discipline fixtures.

L301: guarded attribute touched without the lock.
L302: Condition.wait outside a predicate while-loop.
L303: notify on an unheld Condition.
"""
import threading


class Unguarded:
    def __init__(self):
        self._lock = threading.Lock()
        self._count = 0

    def bump(self):
        with self._lock:
            self._count += 1

    def peek(self):
        return self._count             # L301: no lock held


class BareWait:
    def __init__(self):
        self._cond = threading.Condition()
        self._ready = False

    def publish(self):
        with self._cond:
            self._ready = True
        self._cond.notify_all()        # L303: lock already released

    def consume(self):
        with self._cond:
            if not self._ready:
                self._cond.wait()      # L302: if, not while
