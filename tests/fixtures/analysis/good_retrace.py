# ruff: noqa
"""Known-good retrace fixtures — zero findings expected.

is-None defaults, shape/dtype probes and len() resolve at trace time;
static args marked via static_argnums may be branched on and must be
hashable at call sites.
"""
import jax

_SCALE = 2.0


@jax.jit
def trace_time_predicates(x, y=None):
    if y is None:
        y = 0.0
    if x.ndim == 2:
        x = x.sum(axis=0)
    if len(x.shape) == 1:
        x = x * _SCALE
    return x + y


def f(x, cfg):
    return x * len(cfg)


jitted = jax.jit(f, static_argnums=(1,))


def call_good(x):
    return jitted(x, (1, 2, 3))


def static_branch_ok(x, n):
    return x + n


jitted_static = jax.jit(static_branch_ok, static_argnums=(1,))
