# ruff: noqa
"""Known-good lock-discipline fixtures — zero findings expected."""
import threading


class Guarded:
    def __init__(self):
        self._lock = threading.Lock()
        self._cond = threading.Condition()
        self._count = 0
        self._ready = False

    def bump(self):
        with self._lock:
            self._count += 1

    def peek(self):
        with self._lock:
            return self._count

    def publish(self):
        with self._cond:
            self._ready = True
            self._cond.notify_all()

    def consume(self):
        with self._cond:
            while not self._ready:
                self._cond.wait()
            return self._ready

    def consume_predicate(self):
        with self._cond:
            self._cond.wait_for(lambda: self._ready)
