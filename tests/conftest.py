import os

# Tests must see the real single-device CPU (the 512-device flag is
# dry-run-only, set inside launch/dryrun.py before any jax import).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
