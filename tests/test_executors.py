"""Runtime executor layer: ratio scheduler honored, fused ≡ sharded at
one shard, and the sharded end-to-end path (replay shards + pmean'd
learner) on forced multi-device meshes."""

import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agents.dqn import DQNConfig, make_dqn
from repro.core.distributed import ShardedPrioritizedReplay, ShardedReplayConfig
from repro.core.replay import PrioritizedReplay, ReplayConfig
from repro.envs.classic import make_vec
from repro.launch.mesh import data_mesh
from repro.runtime.executors import FusedExecutor, ShardedExecutor
from repro.runtime.loop import LoopConfig, RatioSchedule


def transition_example(spec):
    return {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }


def test_ratio_schedule_math():
    # U ≥ E: learn every U/E iterations
    s = RatioSchedule.from_config(LoopConfig(update_interval=32), 8)
    assert (s.period, s.learns) == (4, 1) and s.realized_ratio == 32.0
    # U < E: E/U learns every iteration
    s = RatioSchedule.from_config(LoopConfig(update_interval=2), 8)
    assert (s.period, s.learns) == (1, 4) and s.realized_ratio == 2.0
    # learns_per_step multiplies the learner calls per event
    s = RatioSchedule.from_config(
        LoopConfig(update_interval=8, learns_per_step=2), 8)
    assert (s.period, s.learns) == (1, 2) and s.realized_ratio == 4.0


@pytest.mark.parametrize("update_interval,expected_ratio", [(4, 4), (16, 16)])
def test_update_interval_changes_realized_ratio(update_interval, expected_ratio):
    """`update_interval` provably changes actor-steps-per-learn, observed
    in the executor's metrics (not just the static schedule)."""
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    replay = PrioritizedReplay(ReplayConfig(capacity=2048, fanout=8),
                               transition_example(spec))
    cfg = LoopConfig(batch_size=32, warmup=0, epsilon=0.3,
                     update_interval=update_interval)
    ex = FusedExecutor(agent, replay, env_fn, cfg, n_envs=4, scan_chunk=16)
    assert ex.schedule.realized_ratio == expected_ratio
    state, hist = ex.train(64, jax.random.PRNGKey(0))
    env_steps = int(hist["env_steps"][-1])
    learn_steps = int(hist["learn_steps"][-1])
    assert learn_steps > 0
    assert env_steps / learn_steps == pytest.approx(expected_ratio)


@pytest.mark.parametrize("iterations,scan_chunk", [
    (10, 16),    # fewer than one chunk
    (100, 64),   # the ISSUE's example: 1 full chunk + a 36-iter tail
    (37, 16),    # 2 full chunks + a 5-iter tail
    (64, 64),    # exactly divisible: no tail
])
def test_run_performs_exact_iteration_count(iterations, scan_chunk):
    """Regression: ``Executor.run(iterations=N)`` used to round N up to
    the next multiple of ``scan_chunk`` (train(100) with chunk 64 ran
    128).  Exact N iterations now, for any N/chunk combination."""
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    replay = PrioritizedReplay(ReplayConfig(capacity=2048, fanout=8),
                               transition_example(spec))
    cfg = LoopConfig(batch_size=32, warmup=0, epsilon=0.3)
    ex = FusedExecutor(agent, replay, env_fn, cfg, n_envs=4,
                       scan_chunk=scan_chunk)
    state, hist = ex.train(iterations, jax.random.PRNGKey(0))
    assert int(state.env_steps) == iterations * 4
    assert int(hist["env_steps"][-1]) == iterations * 4
    # one history entry per chunk, tail included
    assert hist["env_steps"].shape[0] == -(-iterations // scan_chunk)
    # learn events happened on every iteration of the exact count
    assert int(hist["learn_steps"][-1]) == iterations * 4


def test_run_log_every_fires_on_boundary_crossings(capsys):
    """The log condition fires once per crossed ``log_every`` boundary
    (the old ``done % log_every < scan_chunk`` test mis-fired when the
    chunk size and log interval were coprime)."""
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    replay = PrioritizedReplay(ReplayConfig(capacity=2048, fanout=8),
                               transition_example(spec))
    cfg = LoopConfig(batch_size=32, warmup=0, epsilon=0.3)
    ex = FusedExecutor(agent, replay, env_fn, cfg, n_envs=4, scan_chunk=16)
    ex.train(32, jax.random.PRNGKey(0), log_every=16)
    lines = [ln for ln in capsys.readouterr().out.splitlines()
             if ln.startswith("iter=")]
    assert [ln.split()[0] for ln in lines] == ["iter=16", "iter=32"]


def test_run_rejects_non_positive_iterations():
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    replay = PrioritizedReplay(ReplayConfig(capacity=256, fanout=8),
                               transition_example(spec))
    ex = FusedExecutor(agent, replay, env_fn, LoopConfig(), n_envs=4)
    with pytest.raises(ValueError, match="iterations"):
        ex.train(0, jax.random.PRNGKey(0))


def _pair(cfg, example, env_fn, agent, scan_chunk):
    fused = FusedExecutor(
        agent, PrioritizedReplay(ReplayConfig(capacity=1024, fanout=8), example),
        env_fn, cfg, n_envs=4, scan_chunk=scan_chunk)
    sharded = ShardedExecutor(
        agent,
        ShardedPrioritizedReplay(
            ShardedReplayConfig(capacity_per_shard=1024, fanout=8), example),
        env_fn, cfg, n_envs=4, mesh=data_mesh(1), scan_chunk=scan_chunk)
    assert fused.schedule == sharded.schedule
    return fused, sharded


def test_fused_and_sharded_1shard_equivalent_short_strict():
    """A 1-shard ShardedExecutor (shard_map + pmean'd grads + sharded
    replay) reproduces FusedExecutor from the same seed.  The two XLA
    programs differ at the ulp level, so strict comparison is only
    meaningful on a short horizon before fp drift compounds: 12
    iterations with learning from iteration 2."""
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    cfg = LoopConfig(batch_size=32, warmup=8, epsilon=0.2)
    fused, sharded = _pair(cfg, transition_example(spec), env_fn, agent, 4)

    key = jax.random.PRNGKey(7)
    s1, h1 = fused.train(12, key)
    s2, h2 = sharded.train(12, key)

    for k in ("env_steps", "learn_steps", "buffer_size"):
        np.testing.assert_array_equal(np.asarray(h1[k]), np.asarray(h2[k]),
                                      err_msg=k)
    np.testing.assert_allclose(np.asarray(h1["mean_episode_return"]),
                               np.asarray(h2["mean_episode_return"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h1["loss"]), np.asarray(h2["loss"]),
                               rtol=1e-4, atol=1e-6)
    for a, b in zip(jax.tree.leaves(s1.agent.params),
                    jax.tree.leaves(s2.agent.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_fused_and_sharded_1shard_equivalent_long_trajectory():
    """Long-horizon agreement: with ε=1 (pure exploration) the action
    stream is rng-driven, so env trajectories cannot fork on ulp-level
    greedy-argmax flips — collection metrics must match exactly while the
    full learn path (sharded sample, pmean'd grads, priority write-back)
    still runs every iteration.  Learned params agree loosely (fp drift
    across ~200 learns), which still catches any wiring difference."""
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    cfg = LoopConfig(batch_size=32, warmup=64, epsilon=1.0, epsilon_final=1.0)
    fused, sharded = _pair(cfg, transition_example(spec), env_fn, agent, 16)

    key = jax.random.PRNGKey(7)
    s1, h1 = fused.train(80, key)
    s2, h2 = sharded.train(80, key)

    for k in ("env_steps", "learn_steps", "buffer_size"):
        np.testing.assert_array_equal(np.asarray(h1[k]), np.asarray(h2[k]),
                                      err_msg=k)
    np.testing.assert_allclose(np.asarray(h1["mean_episode_return"]),
                               np.asarray(h2["mean_episode_return"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h1["loss"]), np.asarray(h2["loss"]),
                               rtol=0.5, atol=0.02)
    # a PER cumsum tie-flip swaps the odd batch item over ~200 learns, so
    # a few weights drift by ~1e-2; wiring bugs move params by O(1)
    for a, b in zip(jax.tree.leaves(s1.agent.params),
                    jax.tree.leaves(s2.agent.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.1)


SHARDED_E2E = textwrap.dedent("""
    import functools, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.core.distributed import (ShardedPrioritizedReplay,
                                        ShardedReplayConfig)
    from repro.envs.classic import make_vec
    from repro.launch.mesh import data_mesh
    from repro.runtime.executors import ShardedExecutor
    from repro.runtime.loop import LoopConfig

    assert jax.device_count() == 4
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    replay = ShardedPrioritizedReplay(
        ShardedReplayConfig(capacity_per_shard=2048, fanout=8), example)
    cfg = LoopConfig(batch_size=64, warmup=128, epsilon=0.2,
                     update_interval=8)
    ex = ShardedExecutor(agent, replay, env_fn, cfg, n_envs=8,
                         mesh=data_mesh(4), scan_chunk=16)
    assert ex.n_envs_local == 2
    state, hist = ex.train(192, jax.random.PRNGKey(0))

    # trained through the sharded path: learns happened at the scheduled
    # ratio, every shard's buffer filled (psum'd count = global), loss and
    # params are finite, and the policy collects reward
    env_steps = int(hist["env_steps"][-1])
    learn_steps = int(hist["learn_steps"][-1])
    assert env_steps == 192 * 8
    assert learn_steps > 0
    realized = (env_steps - 128) / learn_steps   # post-warmup ratio
    assert abs(realized - 8.0) <= 1.0, realized
    assert int(hist["buffer_size"][-1]) == 192 * 8   # 4 shards x 2 envs x iters
    assert np.isfinite(np.asarray(hist["loss"])).all()
    assert all(np.isfinite(np.asarray(p)).all()
               for p in jax.tree.leaves(state.agent.params))
    assert float(hist["mean_episode_return"][-1]) > 0.0
    print("SHARDED_E2E_OK")
""")


@pytest.mark.slow
def test_sharded_executor_multidevice_e2e():
    """End-to-end DQN/CartPole training through ShardedExecutor on 4
    forced host devices (subprocess: the device-count flag must be set
    before jax initializes)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SHARDED_E2E],
                       capture_output=True, text=True, timeout=600,
                       env=env, cwd=root)
    assert "SHARDED_E2E_OK" in r.stdout, r.stdout[-800:] + r.stderr[-2000:]
