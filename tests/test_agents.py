"""Agent learning tests: DQN solves CartPole via the full lazy-write loop
(the paper's end-to-end pipeline); continuous agents improve on Pendulum;
all learners produce finite TD priorities."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agents.ddpg import DDPGConfig, make_ddpg
from repro.agents.dqn import DQNConfig, make_dqn
from repro.agents.sac import SACConfig, make_sac
from repro.agents.td3 import TD3Config, make_td3
from repro.core.replay import PrioritizedReplay, ReplayConfig
from repro.envs.classic import make_vec
from repro.runtime import loop


def transition_example(spec):
    return {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": (jnp.zeros((), jnp.int32) if spec.discrete
                   else jnp.zeros((spec.action_dim,), jnp.float32)),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }


@pytest.mark.slow
def test_dqn_learns_cartpole():
    spec, v_reset, v_step = make_vec("cartpole", 8)
    agent = make_dqn(spec, DQNConfig())
    replay = PrioritizedReplay(ReplayConfig(capacity=20_000, fanout=128),
                               transition_example(spec))
    cfg = loop.LoopConfig(batch_size=64, warmup=500, epsilon=0.15)
    state, hist = loop.train(agent, replay, v_reset, v_step, cfg, n_envs=8,
                             iterations=2600, key=jax.random.PRNGKey(0))
    final = float(hist["mean_episode_return"][-1])
    assert final > 60.0, final  # random policy scores ~10


@pytest.mark.parametrize("make_agent,cfg", [
    (make_ddpg, DDPGConfig()),
    (make_td3, TD3Config()),
    (make_sac, SACConfig()),
])
def test_continuous_agents_learn_step(make_agent, cfg):
    spec, v_reset, v_step = make_vec("pendulum", 4)
    agent = make_agent(spec, cfg)
    st = agent.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "obs": jnp.asarray(rng.normal(size=(32, 3)).astype(np.float32)),
        "action": jnp.asarray(rng.uniform(-2, 2, (32, 1)).astype(np.float32)),
        "reward": jnp.asarray(rng.uniform(-10, 0, 32).astype(np.float32)),
        "next_obs": jnp.asarray(rng.normal(size=(32, 3)).astype(np.float32)),
        "done": jnp.zeros((32,)),
    }
    is_w = jnp.ones((32,))
    losses = []
    for _ in range(20):
        st, metrics, td = agent.learn(st, batch, is_w)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(np.asarray(td)).all() and (np.asarray(td) >= 0).all()
    assert losses[-1] < losses[0]  # fits the fixed batch

    # act path produces in-range actions
    obs = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
    a = agent.act(st, obs, jax.random.PRNGKey(1), 0.1)
    assert a.shape == (4, 1)
    assert (np.abs(np.asarray(a)) <= 2.0 + 1e-5).all()


def test_ddqn_differs_from_dqn():
    spec, _, _ = make_vec("cartpole", 2)
    a1 = make_dqn(spec, DQNConfig(double_q=False))
    a2 = make_dqn(spec, DQNConfig(double_q=True))
    s1, s2 = a1.init(jax.random.PRNGKey(3)), a2.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(1)
    batch = {
        "obs": jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32)),
        "action": jnp.asarray(rng.integers(0, 2, 16).astype(np.int32)),
        "reward": jnp.ones((16,)),
        "next_obs": jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32)),
        "done": jnp.zeros((16,)),
    }
    # push target/online apart so DDQN's decoupled argmax matters
    for _ in range(5):
        s1, _, td1 = a1.learn(s1, batch, jnp.ones((16,)))
        s2, _, td2 = a2.learn(s2, batch, jnp.ones((16,)))
    assert not np.allclose(np.asarray(td1), np.asarray(td2))


def test_priorities_flow_into_buffer():
    spec, v_reset, v_step = make_vec("cartpole", 4)
    agent = make_dqn(spec, DQNConfig())
    replay = PrioritizedReplay(ReplayConfig(capacity=512, fanout=8),
                               transition_example(spec))
    cfg = loop.LoopConfig(batch_size=32, warmup=64, epsilon=0.3)
    step = loop.make_parallel_step(agent, replay, v_step, cfg, 4)
    st = loop.init_loop_state(agent, replay, v_reset, jax.random.PRNGKey(0), 4)
    before = float(replay.total_priority(st.replay))
    for _ in range(40):
        st, m = jax.jit(step)(st)
    after = float(replay.total_priority(st.replay))
    assert int(st.replay.count) == 160
    assert after != before and np.isfinite(after)
