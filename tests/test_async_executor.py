"""AsyncExecutor (bounded-staleness backend, DESIGN.md §5): identity
settings reproduce the synchronous executors trajectory-exactly, delayed
publishing still learns, and the staleness-weighted renormalized reduce
preserves the gradient scale (hypothesis property) and runs end to end
on a forced multi-device mesh."""

import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agents.dqn import DQNConfig, make_dqn
from repro.core.replay import PrioritizedReplay, ReplayConfig
from repro.envs.classic import make_vec
from repro.runtime.executors import AsyncExecutor, FusedExecutor
from repro.runtime.learner import staleness_reduce_weights, staleness_weights
from repro.runtime.loop import LoopConfig


def transition_example(spec):
    return {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }


def _setup(cfg, capacity=1024):
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    mk_replay = lambda: PrioritizedReplay(
        ReplayConfig(capacity=capacity, fanout=8), transition_example(spec))
    return env_fn, agent, mk_replay


def test_async_identity_reproduces_fused_exactly():
    """At publish_interval=1, max_staleness=0 the acting copy is
    republished after every iteration, so the async program must be the
    synchronous one — metrics and learned params trajectory-exact (bit
    -exact, not just close) from the same seed."""
    cfg = LoopConfig(batch_size=32, warmup=8, epsilon=0.2)
    env_fn, agent, mk_replay = _setup(cfg)
    fused = FusedExecutor(agent, mk_replay(), env_fn, cfg, n_envs=4,
                          scan_chunk=16)
    async_ex = AsyncExecutor(agent, mk_replay(), env_fn, cfg, n_envs=4,
                             publish_interval=1, max_staleness=0,
                             scan_chunk=16)
    assert fused.schedule == async_ex.schedule

    key = jax.random.PRNGKey(7)
    s1, h1 = fused.train(40, key)
    s2, h2 = async_ex.train(40, key)

    for k in h1:
        np.testing.assert_array_equal(np.asarray(h1[k]), np.asarray(h2[k]),
                                      err_msg=k)
    for a, b in zip(jax.tree.leaves(s1.agent.params),
                    jax.tree.leaves(s2.agent.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the async state actually carries the double buffer, synced at age 0
    assert int(s2.params_age) == 0
    for a, b in zip(jax.tree.leaves(s2.actor_params),
                    jax.tree.leaves(s2.agent.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_staleness_delays_acting_copy():
    """With publish_interval=4 the acting copy is only republished every
    4th iteration: between publishes it stays bitwise frozen while the
    learner params move, and params_age cycles 0..3."""
    cfg = LoopConfig(batch_size=32, warmup=0, epsilon=0.2)
    env_fn, agent, mk_replay = _setup(cfg)
    ex = AsyncExecutor(agent, mk_replay(), env_fn, cfg, n_envs=4,
                       publish_interval=4, scan_chunk=1)
    state = ex.init(jax.random.PRNGKey(3))
    ages, frozen = [], []
    prev_actor = state.actor_params
    for _ in range(12):
        state, _ = ex.run_chunk(state)
        ages.append(int(state.params_age))
        frozen.append(all(
            np.array_equal(np.asarray(a), np.asarray(b)) for a, b in
            zip(jax.tree.leaves(prev_actor), jax.tree.leaves(state.actor_params))))
        prev_actor = state.actor_params
    # publish at the end of iterations 3, 7, 11 (it+1 ≡ 0 mod 4)
    assert ages == [1, 2, 3, 0, 1, 2, 3, 0, 1, 2, 3, 0]
    # the buffer is untouched except on publish ticks, where the learner
    # has moved the fresh params away from the held copy
    for age, untouched in zip(ages, frozen):
        assert untouched == (age != 0)


def test_async_publish4_still_learns_cartpole():
    """Acting 4 iterations behind the learner must not break learning:
    DQN/CartPole through AsyncExecutor(publish_interval=4) still beats
    the random baseline (≈ 10)."""
    cfg = LoopConfig(batch_size=64, warmup=400, epsilon=0.2)
    env_fn, agent, mk_replay = _setup(cfg, capacity=20_000)
    ex = AsyncExecutor(agent, mk_replay(), env_fn, cfg, n_envs=8,
                       publish_interval=4, scan_chunk=64)
    state, hist = ex.train(1400, jax.random.PRNGKey(1))
    final = float(hist["mean_episode_return"][-1])
    assert final > 30.0, final
    assert np.isfinite(np.asarray(hist["loss"])).all()


def test_async_executor_validates_knobs():
    cfg = LoopConfig()
    env_fn, agent, mk_replay = _setup(cfg)
    with pytest.raises(ValueError, match="publish_interval"):
        AsyncExecutor(agent, mk_replay(), env_fn, cfg, n_envs=4,
                      publish_interval=0)
    with pytest.raises(ValueError, match="max_staleness"):
        AsyncExecutor(agent, mk_replay(), env_fn, cfg, n_envs=4,
                      max_staleness=-1)


# -- staleness-weighted reduce properties ------------------------------------
#
# Property: the realized reduce weights (staleness_weights renormalized
# by their sum) preserve the gradient scale — they sum to exactly the
# synchronous pmean's 1 whenever at least one shard is within the bound,
# stragglers past the bound contribute exactly 0, and an all-stale round
# degrades to a zero-scale (skipped) update.  Checked by hypothesis when
# available (CI installs it), and by a seeded sweep regardless.

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - hypothesis is a dev extra
    given = None


def _assert_gradient_scale_preserved(ages, max_staleness):
    w = np.asarray(staleness_reduce_weights(jnp.asarray(ages), max_staleness))
    assert (w >= 0).all()
    alive = np.asarray(ages) <= max_staleness
    if alive.any():
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)
        assert (w[~alive] == 0).all()
    else:
        np.testing.assert_allclose(w.sum(), 0.0, atol=1e-12)


if given is not None:
    @settings(max_examples=200, deadline=None)
    @given(
        ages=st.lists(st.integers(min_value=0, max_value=64), min_size=1,
                      max_size=16),
        max_staleness=st.integers(min_value=0, max_value=16),
    )
    def test_staleness_renormalization_preserves_gradient_scale(
            ages, max_staleness):
        _assert_gradient_scale_preserved(ages, max_staleness)


def test_staleness_renormalization_seeded_sweep():
    rng = np.random.default_rng(0)
    for _ in range(300):
        n = int(rng.integers(1, 17))
        ages = rng.integers(0, 65, size=n)
        _assert_gradient_scale_preserved(ages, int(rng.integers(0, 17)))
    # pinned corner cases: all alive at age 0, exactly one alive, all stale
    _assert_gradient_scale_preserved(np.zeros(4, np.int32), 0)
    _assert_gradient_scale_preserved(np.asarray([0, 5, 5, 5]), 1)
    _assert_gradient_scale_preserved(np.asarray([3, 4, 5]), 2)


def test_staleness_weights_monotone_in_age():
    """Fresher shards never get a smaller raw weight than staler ones."""
    rng = np.random.default_rng(1)
    for _ in range(50):
        ages = rng.integers(0, 9, size=int(rng.integers(2, 9)))
        w = np.asarray(staleness_weights(jnp.asarray(ages), max_staleness=8))
        order = np.argsort(ages)
        assert (np.diff(w[order]) <= 1e-7).all()


# -- sharded async path on a forced 4-device mesh ----------------------------

ASYNC_SHARDED = textwrap.dedent("""
    import functools, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.core.distributed import (ShardedPrioritizedReplay,
                                        ShardedReplayConfig)
    from repro.envs.classic import make_vec
    from repro.launch.mesh import data_mesh
    from repro.runtime.executors import AsyncExecutor, ShardedExecutor
    from repro.runtime.loop import LoopConfig

    assert jax.device_count() == 4
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    mk_replay = lambda: ShardedPrioritizedReplay(
        ShardedReplayConfig(capacity_per_shard=1024, fanout=8), example)
    cfg = LoopConfig(batch_size=64, warmup=32, epsilon=0.2)
    key = jax.random.PRNGKey(5)

    # identity settings: the async sharded program reproduces the
    # synchronous sharded one (the staleness-weighted reduce with all
    # ages 0 IS the pmean, up to reduce-order ulps — so the horizon is
    # kept short, before fp drift can fork greedy actions)
    sync = ShardedExecutor(agent, mk_replay(), env_fn, cfg, n_envs=8,
                           mesh=data_mesh(4), scan_chunk=4)
    ident = AsyncExecutor(agent, mk_replay(), env_fn, cfg, n_envs=8,
                          publish_interval=1, max_staleness=0,
                          mesh=data_mesh(4), scan_chunk=4)
    s1, h1 = sync.train(12, key)
    s2, h2 = ident.train(12, key)
    for k in ("env_steps", "learn_steps", "buffer_size"):
        np.testing.assert_array_equal(np.asarray(h1[k]), np.asarray(h2[k]),
                                      err_msg=k)
    np.testing.assert_allclose(np.asarray(h1["mean_episode_return"]),
                               np.asarray(h2["mean_episode_return"]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h1["loss"]),
                               np.asarray(h2["loss"]), rtol=1e-4, atol=1e-6)

    # bounded staleness: staggered publishes give the 4 shards distinct
    # parameter ages (global params_age is (4,)), a shard past
    # max_staleness=1 is dropped from the reduce, and training stays
    # finite and on-ratio
    ex = AsyncExecutor(agent, mk_replay(), env_fn, cfg, n_envs=8,
                       publish_interval=4, max_staleness=1,
                       mesh=data_mesh(4), scan_chunk=8)
    state, hist = ex.train(96, key)
    ages = np.asarray(state.params_age)
    assert ages.shape == (4,)
    assert len(set(ages.tolist())) > 1, ages      # staggered shard clocks
    assert (ages < 4).all(), ages                 # bounded by the interval
    assert int(hist["env_steps"][-1]) == 96 * 8
    assert int(hist["learn_steps"][-1]) > 0
    assert np.isfinite(np.asarray(hist["loss"])).all()
    assert all(np.isfinite(np.asarray(p)).all()
               for p in jax.tree.leaves(state.agent.params))

    # aliasing guard: when publish_interval shares a factor with the
    # learn period larger than max_staleness+1, some shards' staggered
    # clocks would put them past the bound at EVERY learn tick —
    # permanently dropped, their replay data never training.  The
    # executor must refuse that configuration up front.
    try:
        AsyncExecutor(agent, mk_replay(), env_fn,
                      LoopConfig(batch_size=64, update_interval=32),
                      n_envs=8, publish_interval=4, max_staleness=0,
                      mesh=data_mesh(4), scan_chunk=8)
        raise AssertionError("expected ValueError for publish/learn-period "
                             "aliasing that permanently drops shards")
    except ValueError as e:
        assert "permanently dropped" in str(e), e
    print("ASYNC_SHARDED_OK")
""")


@pytest.mark.slow
def test_async_sharded_staleness_multidevice():
    """The sharded async path (staggered publishes + staleness-weighted
    renormalized gradient reduce) end to end on 4 forced host devices
    (subprocess: the device-count flag must be set before jax
    initializes)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", ASYNC_SHARDED],
                       capture_output=True, text=True, timeout=600,
                       env=env, cwd=root)
    assert "ASYNC_SHARDED_OK" in r.stdout, r.stdout[-800:] + r.stderr[-2000:]
