"""Runtime configuration planner (runtime/planner.py, DESIGN.md §8):
Eq. 5 backward-compat with the 1-D DSE, measured-faster backend
selection, staleness/aliasing feasibility, BENCH json round trips, the
schema/compare CI gates, and plan → executor instantiation."""

import functools
import json
import math
import os
import subprocess
import sys

import jax
import pytest

from repro.runtime import dse, planner
from repro.runtime.loop import LoopConfig, RatioSchedule


def _fig9_point(backend="fused", shards=0, publish_interval=0, n_envs=8,
                steps=1000.0):
    return {"backend": backend, "shards": shards, "pods": 1,
            "publish_interval": publish_interval, "max_staleness": 0,
            "n_envs": n_envs, "env_steps_per_s": steps,
            "speedup_vs_sync": 1.0}


def _fig10_point(shards, pods=1, compressed=False, steps=1000.0, n_envs=16):
    backend = "sharded_pod_data" if pods > 1 else "sharded"
    return {"backend": backend, "shards": shards, "pods": pods,
            "compressed": compressed, "n_envs": n_envs,
            "env_steps_per_s": steps}


# -- Eq. 5 lane split: backward compatibility with the 1-D DSE ---------------


def test_solve_lanes_matches_dse_solve():
    """The planner's lane split IS dse.solve on identical curves — the
    1-D DSE remains a special case of the planner (acceptance
    criterion)."""
    actor = {x: 100.0 * x for x in range(1, 9)}
    learner = {x: 300.0 * x ** 0.8 for x in range(1, 9)}
    for ui in (1.0, 2.0, 4.0):
        a = planner.solve_lanes(actor, learner, total=8, update_interval=ui)
        b = dse.solve(actor, learner, total=8, update_interval=ui)
        assert (a.x_actor, a.x_learner) == (b.x_actor, b.x_learner)
        assert a.actor_throughput == b.actor_throughput
        assert a.ratio_error == b.ratio_error


def test_learn_period_matches_ratio_schedule():
    """planner.learn_period is dependency-free on purpose (a plan must
    be checkable before jax imports) — assert parity with the schedule
    the executors actually realize."""
    for u in (1, 2, 4, 8, 16, 32, 100):
        for e in (1, 2, 4, 8, 16):
            sched = RatioSchedule.from_config(
                LoopConfig(update_interval=u), e)
            assert planner.learn_period(u, e) == sched.period, (u, e)


# -- dse scoring normalization (tie-break bugfix) ----------------------------


def test_backend_selection_not_dominated_by_curve_units():
    """Regression: ranking Eq. 5 solutions across backends used the raw
    ``-(fa + fl)`` sum, so a backend whose json curves happened to be
    recorded in larger units won every comparison on magnitude alone.
    Backend selection must follow ratio fit + env-steps/s, not the
    learner curve's unit."""
    # "good": clean ratio match, modest learner units (batches/s)
    good = ({1: 100.0, 2: 200.0, 4: 400.0},
            {1: 100.0, 2: 200.0, 4: 400.0})
    # "bloated": worse achievable ratio, learner curve in items/s-style
    # huge numbers — the raw sum would dwarf "good"
    bloated = ({1: 100.0, 2: 200.0, 4: 400.0},
               {1: 9.9e6, 2: 9.95e6, 4: 1e7})
    name, res = planner.solve_backend_curves(
        {"good": good, "bloated": bloated}, total=8, update_interval=1.0)
    assert name == "good"
    assert res.ratio_error == pytest.approx(0.0)
    # the old raw tie-break really would have ranked "bloated" first:
    raw_good = res.actor_throughput + res.learner_throughput
    bl = dse.solve(*bloated, total=8, update_interval=1.0)
    raw_bloated = bl.actor_throughput + bl.learner_throughput
    assert raw_bloated > raw_good  # magnitude lies; ratio error doesn't


def test_backend_selection_unit_invariant():
    """Jointly rescaling one backend's curves (a unit change — e.g. a
    json emitted in k-steps/s) must not change which backend wins on
    ratio fit."""
    a = ({1: 100.0, 2: 200.0}, {1: 100.0, 2: 200.0})
    b = ({1: 80.0, 2: 150.0}, {1: 120.0, 2: 130.0})
    base, _ = planner.solve_backend_curves({"a": a, "b": b}, total=4)
    scaled_b = ({k: v * 1024.0 for k, v in b[0].items()},
                {k: v * 1024.0 for k, v in b[1].items()})
    rescaled, _ = planner.solve_backend_curves(
        {"a": a, "b": scaled_b}, total=4)
    # ratio error is scale-free, so the ranking must be identical
    assert base == rescaled == "a"


def test_solve_tiebreak_unit_invariant():
    """The in-solve tie-break must not depend on the learner curve's
    unit: rescaling it by a power of two (lossless in floats) together
    with the target ratio leaves the chosen allocation unchanged."""
    actor = {1: 60.0, 2: 60.0}            # saturated collection
    learner = {1: 2560.0, 2: 5120.0}
    u = 1.0 / 64.0                        # binary-exact target ratio
    base = dse.solve(actor, learner, total=4, update_interval=u)
    scaled = dse.solve(actor, {k: v * 1024.0 for k, v in learner.items()},
                       total=4, update_interval=u / 1024.0)
    assert (base.x_actor, base.x_learner) == (scaled.x_actor,
                                              scaled.x_learner)


def test_relative_score_orders_unit_free():
    res = dse.solve({1: 10.0, 2: 20.0}, {1: 1e6, 2: 2e6}, total=4)
    s = dse.relative_score(res, {1: 10.0, 2: 20.0}, {1: 1e6, 2: 2e6})
    assert s[0] == res.ratio_error
    assert -2.0 <= s[1] <= 0.0            # both terms normalized to ≤ 1


# -- full-config planning ----------------------------------------------------


def test_plan_picks_measured_faster_backend():
    fig9 = [_fig9_point("fused", steps=1000.0),
            _fig9_point("async", publish_interval=2, steps=1400.0)]
    fig10 = [_fig10_point(2, steps=1800.0),
             _fig10_point(2, pods=2, compressed=True, steps=2600.0)]
    pc = planner.plan(fig9, fig10)
    assert pc.backend == "sharded"
    assert (pc.n_pods, pc.n_data) == (2, 2)
    assert pc.compress_pod_reduce
    assert pc.predicted_env_steps_per_s == 2600.0
    assert pc.n_devices == 4

    # without the shard/pod sweep the fastest fig9 point wins
    pc = planner.plan(fig9, [])
    assert pc.backend == "async"
    assert pc.publish_interval == 2


def test_plan_respects_device_budget():
    fig9 = [_fig9_point("fused", steps=1000.0)]
    fig10 = [_fig10_point(4, steps=4000.0)]
    pc = planner.plan(fig9, fig10, max_devices=1)
    assert pc.backend == "fused"          # the 4-shard point needs 4 devices
    pc = planner.plan(fig9, fig10, max_devices=4)
    assert pc.backend == "sharded" and pc.n_data == 4


def test_plan_never_selects_aliasing_rejected_async():
    """A publish_interval sharing a factor with the learn period beyond
    max_staleness+1 would make ShardedExecutor raise at construction —
    the planner must skip it even when it measured fastest."""
    # n_envs=8, update_interval=32 → learn period 4; publish_interval=2
    # shares gcd 2 with it; 4 shards; max_staleness=0 → min(2,4) > 1
    fast_bad = _fig9_point("async", shards=4, publish_interval=2,
                           n_envs=8, steps=9999.0)
    slow_ok = _fig10_point(4, steps=500.0, n_envs=8)
    pc = planner.plan([fast_bad], [slow_ok], update_interval=32,
                      max_staleness=0)
    assert pc.backend == "sharded"        # not the infeasible 9999 point
    # raising the staleness bound makes the fast point legal again
    pc = planner.plan([fast_bad], [slow_ok], update_interval=32,
                      max_staleness=1)
    assert pc.backend == "async" and pc.publish_interval == 2
    assert pc.max_staleness == 1


def test_plan_lane_split_rides_along():
    actor = {x: 100.0 * x for x in range(1, 9)}
    learner = {x: 300.0 * x ** 0.8 for x in range(1, 9)}
    ref = dse.solve(actor, learner, total=8, update_interval=1.0)
    pc = planner.plan([_fig9_point("fused", steps=800.0, n_envs=8)], [],
                      actor_curve=actor, learner_curve=learner)
    assert (pc.x_actor, pc.x_learner) == (ref.x_actor, ref.x_learner)
    # the executable config keeps the env count the point was MEASURED
    # at — the plan's throughput claim stays on the measured hull
    assert pc.n_envs == 8

    # sharded winner: measured env count, rounded to shard divisibility
    pc = planner.plan([], [_fig10_point(4, steps=9000.0, n_envs=16)],
                      actor_curve=actor, learner_curve=learner)
    assert pc.n_data == 4
    assert pc.n_envs == 16 and pc.n_envs % 4 == 0


def _wallclock_point(shards=2, pods=1, steps=500.0, n_envs=16, ui=1,
                     overlapped=False, compressed=False, n_procs=2):
    return {"backend": "wallclock", "shards": shards, "pods": pods,
            "compressed": compressed, "overlapped": overlapped,
            "n_procs": n_procs, "update_interval": ui, "n_envs": n_envs,
            "env_steps_per_s": steps}


def test_plan_prefers_wallclock_over_emulated_same_config():
    """A config measured both emulated and on a real multi-process gang
    keeps the gang number: emulated host devices time-slice one process,
    so the inflated emulated figure must not win the ranking."""
    emu_2shard = _fig10_point(2, steps=9000.0)     # emulated, inflated
    wc_2shard = _wallclock_point(shards=2, steps=400.0, ui=1)
    emu_4shard = _fig10_point(4, steps=800.0)
    pc = planner.plan([], [emu_2shard, wc_2shard, emu_4shard])
    # the gang's 400 replaces the emulated 9000 for the 2-shard config,
    # so the honestly-slower 4-shard emulated point wins
    assert (pc.backend, pc.n_data) == ("sharded", 4)
    assert pc.predicted_env_steps_per_s == 800.0
    # without the wall-clock measurement the emulated 2-shard wins
    pc = planner.plan([], [emu_2shard, emu_4shard])
    assert (pc.n_data, pc.predicted_env_steps_per_s) == (2, 9000.0)


def test_plan_wallclock_ratio_filter_and_overlap_flows_through():
    """A wall-clock point carries the update_interval it was measured at
    — a different requested ratio is a different workload, so the point
    is filtered; the overlapped-reduce flag flows into the plan (with
    max_staleness pinned to 0: overlap is incompatible with the
    bounded-staleness reduce)."""
    wc = _wallclock_point(shards=1, pods=2, steps=900.0, ui=8,
                          overlapped=True, compressed=True)
    slow = _fig10_point(2, steps=100.0)
    pc = planner.plan([], [wc, slow], update_interval=8, max_staleness=2)
    assert (pc.n_pods, pc.n_data) == (2, 1)
    assert pc.compress_pod_reduce and pc.overlap_pod_reduce
    assert pc.max_staleness == 0
    assert pc.source.endswith("fig10-wallclock")
    # at the default ratio the ui=8 gang point is a different workload
    pc = planner.plan([], [wc, slow], update_interval=1)
    assert (pc.backend, pc.n_data) == ("sharded", 2)
    assert not pc.overlap_pod_reduce


def test_interp_hull_clamps_to_measured_range():
    curve = {2: 200.0, 4: 400.0}
    assert dse.interp_hull(curve, 1) == 200.0     # below the hull → edge
    assert dse.interp_hull(curve, 100) == 400.0   # above the hull → edge
    assert dse.interp_hull(curve, 3) == 300.0     # inside → interpolated
    assert dse.interp_hull(curve, 4) == 400.0


def test_plan_curve_only_fallback_and_empty_inputs():
    actor = {1: 100.0, 2: 200.0}
    learner = {1: 100.0, 2: 200.0}
    pc = planner.plan(actor_curve=actor, learner_curve=learner)
    assert pc.backend == "fused" and pc.n_data == 0
    assert pc.x_actor >= 1
    with pytest.raises(ValueError, match="no feasible"):
        planner.plan()


def test_planned_config_validation():
    with pytest.raises(ValueError, match="backend"):
        planner.PlannedConfig(backend="warp")
    with pytest.raises(ValueError, match="publish_interval"):
        planner.PlannedConfig(backend="async", publish_interval=0)
    with pytest.raises(ValueError, match="synchronous"):
        planner.PlannedConfig(backend="fused", publish_interval=2)
    with pytest.raises(ValueError, match="n_data"):
        planner.PlannedConfig(backend="sharded", n_data=0)
    with pytest.raises(ValueError, match="compress"):
        planner.PlannedConfig(backend="sharded", n_data=2,
                              compress_pod_reduce=True)
    with pytest.raises(ValueError, match="divisible"):
        planner.PlannedConfig(backend="sharded", n_data=4, n_envs=6)
    with pytest.raises(ValueError, match="unknown"):
        planner.PlannedConfig.from_dict({"backend": "fused", "warp": 9})


def test_plan_json_round_trip(tmp_path):
    fig9 = [_fig9_point("fused", steps=1000.0)]
    pc = planner.plan(fig9, [])
    path = tmp_path / "BENCH_plan.json"
    payload = planner.save_plan(pc, str(path),
                                realized_env_steps_per_s=950.0)
    assert payload["realized_env_steps_per_s"] == 950.0
    assert planner.load_plan(str(path)) == pc
    # bare-config dicts work too (hand-written plans)
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps(pc.to_dict()))
    assert planner.load_plan(str(bare)) == pc


def test_plan_from_json_dir(tmp_path):
    (tmp_path / planner.FIG9_JSON).write_text(json.dumps(
        {"figure": "fig9", "metric": "env_steps_per_s",
         "points": [_fig9_point("fused", steps=1200.0)]}))
    pc = planner.plan_from_json(str(tmp_path))
    assert pc.backend == "fused"
    assert pc.predicted_env_steps_per_s == 1200.0
    with pytest.raises(FileNotFoundError, match="emit-json"):
        planner.plan_from_json(str(tmp_path / "nope"))


# -- feasibility property test (hypothesis) ----------------------------------


def test_planner_feasibility_property():
    """Whatever the measured points and knobs, a returned plan is always
    instantiable: it matches a measured candidate (config-level profiled
    hull), its lane split respects the budget, envs divide over shards,
    and the async aliasing rule holds (an executor-construction
    ValueError can never come out of a plan)."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.settings(max_examples=60, deadline=None)
    @hypothesis.given(
        steps=st.lists(st.floats(1.0, 1e6), min_size=1, max_size=6),
        publish=st.lists(st.integers(1, 8), min_size=1, max_size=4),
        shards=st.lists(st.sampled_from([1, 2, 4, 8]), min_size=1,
                        max_size=4),
        update_interval=st.integers(1, 64),
        max_staleness=st.integers(0, 3),
        total=st.integers(2, 12),
    )
    def check(steps, publish, shards, update_interval, max_staleness,
              total):
        fig9 = [_fig9_point("fused", steps=steps[0])]
        fig9 += [_fig9_point("async", shards=s, publish_interval=p,
                             steps=steps[(i + 1) % len(steps)])
                 for i, (p, s) in enumerate(zip(publish, [0] + shards))]
        fig10 = [_fig10_point(s, steps=steps[i % len(steps)])
                 for i, s in enumerate(shards)]
        actor = {x: 50.0 * x for x in (1, 2, 4, 8)}
        learner = {x: 120.0 * x ** 0.7 for x in (1, 2, 4, 8)}
        try:
            pc = planner.plan(fig9, fig10, actor_curve=actor,
                              learner_curve=learner, total_lanes=total,
                              update_interval=update_interval,
                              max_staleness=max_staleness)
        except ValueError as e:
            assert "no feasible" in str(e) or "total=" in str(e)
            return
        # inside the lane budget and the profiled lane hull
        if pc.x_actor:
            assert pc.x_actor + pc.x_learner <= total
            assert 1 <= pc.x_actor <= 8 and 1 <= pc.x_learner <= 8
        # the config itself was measured (candidate hull)
        cands = planner.candidates_from_points(fig9, fig10)
        assert any(c.backend == pc.backend and c.n_pods == pc.n_pods
                   and c.n_data == pc.n_data
                   and c.publish_interval == pc.publish_interval
                   for c in cands)
        # divisibility + aliasing: the executor would accept this
        assert pc.n_envs % pc.n_shards == 0
        period = planner.learn_period(pc.update_interval, pc.n_envs)
        assert planner.aliasing_ok(pc.publish_interval, period,
                                   pc.n_shards, pc.max_staleness)
        if pc.publish_interval and pc.n_shards > 1:
            g = math.gcd(pc.publish_interval, period)
            assert min(g, pc.n_shards) <= pc.max_staleness + 1

    check()


# -- schema + compare gates --------------------------------------------------


def test_schema_accepts_emitted_shapes():
    from benchmarks import schema

    assert schema.validate({"figure": "fig9", "metric": "env_steps_per_s",
                            "smoke": True,
                            "points": [_fig9_point()]}) == "fig9"
    assert schema.validate({"figure": "fig10", "metric": "env_steps_per_s",
                            "points": [_fig10_point(2)]}) == "fig10"
    pc = planner.plan([_fig9_point()], [])
    assert schema.validate({"figure": "plan", "metric": "env_steps_per_s",
                            "config": pc.to_dict(),
                            "predicted_env_steps_per_s": 1.0,
                            "realized_env_steps_per_s": None}) == "plan"


def test_schema_rejects_bad_payloads():
    from benchmarks import schema

    with pytest.raises(schema.SchemaError, match="figure"):
        schema.validate({"figure": "fig99", "points": []})
    with pytest.raises(schema.SchemaError, match="metric"):
        schema.validate({"figure": "fig9", "metric": "bananas",
                         "points": [_fig9_point()]})
    with pytest.raises(schema.SchemaError, match="non-empty"):
        schema.validate({"figure": "fig9", "metric": "env_steps_per_s",
                         "points": []})
    bad = _fig9_point()
    del bad["backend"]
    with pytest.raises(schema.SchemaError, match="backend"):
        schema.validate({"figure": "fig9", "metric": "env_steps_per_s",
                         "points": [bad]})
    bad = _fig9_point()
    bad["env_steps_per_s"] = "fast"
    with pytest.raises(schema.SchemaError, match="env_steps_per_s"):
        schema.validate({"figure": "fig9", "metric": "env_steps_per_s",
                         "points": [bad]})
    bad = _fig10_point(2)
    bad["mystery"] = 1
    with pytest.raises(schema.SchemaError, match="mystery"):
        schema.validate({"figure": "fig10", "metric": "env_steps_per_s",
                         "points": [bad]})


def test_compare_gate(tmp_path):
    from benchmarks import compare

    base_dir = tmp_path / "base"
    fresh_dir = tmp_path / "fresh"
    base_dir.mkdir()
    fresh_dir.mkdir()

    def write(d, fname, points):
        (d / fname).write_text(json.dumps(
            {"figure": "fig9", "metric": "env_steps_per_s",
             "points": points}))

    p_fast = _fig9_point("fused", steps=1000.0)
    p_slow = dict(p_fast, env_steps_per_s=600.0)
    p_jitter = dict(p_fast, env_steps_per_s=820.0)
    p_other = _fig9_point("async", publish_interval=2, steps=500.0)

    # >30% drop on a matching point fails
    write(base_dir, "BENCH_fig9.json", [p_fast])
    write(fresh_dir, "BENCH_fig9.json", [p_slow])
    assert compare.compare_dirs(str(fresh_dir), str(base_dir),
                                compare.THRESHOLD) == 1
    # 18% drop passes the default 30% gate
    write(fresh_dir, "BENCH_fig9.json", [p_jitter])
    assert compare.compare_dirs(str(fresh_dir), str(base_dir),
                                compare.THRESHOLD) == 0
    # missing/new points are tolerated in both directions
    write(base_dir, "BENCH_fig9.json", [p_fast, p_other])
    write(fresh_dir, "BENCH_fig9.json", [p_jitter])
    assert compare.compare_dirs(str(fresh_dir), str(base_dir),
                                compare.THRESHOLD) == 0
    # threshold is read from the one module constant
    assert compare.THRESHOLD == 0.30


def test_compare_fails_hard_when_no_points_match(tmp_path, capsys):
    """An identity-field change (e.g. a new sweep env count) de-matches
    every point: a baseline whose points all fail to match gated
    nothing, so the gate must fail hard, not print a vacuous OK."""
    from benchmarks import compare

    base_dir = tmp_path / "base"
    fresh_dir = tmp_path / "fresh"
    base_dir.mkdir()
    fresh_dir.mkdir()
    old = _fig9_point("fused", n_envs=8, steps=1000.0)
    new = _fig9_point("fused", n_envs=16, steps=100.0)   # huge "drop"
    for d, pt in ((base_dir, old), (fresh_dir, new)):
        (d / "BENCH_fig9.json").write_text(json.dumps(
            {"figure": "fig9", "metric": "env_steps_per_s",
             "points": [pt]}))
    assert compare.compare_dirs(str(fresh_dir), str(base_dir),
                                compare.THRESHOLD) == 1   # blocking
    assert "0 matching points" in capsys.readouterr().out

    # an *empty* baseline points list still gates nothing quietly —
    # only a baseline that has identities to match can fail this way
    (base_dir / "BENCH_fig9.json").write_text(json.dumps(
        {"figure": "fig9", "metric": "env_steps_per_s", "points": []}))
    assert compare.compare_dirs(str(fresh_dir), str(base_dir),
                                compare.THRESHOLD) == 0


# -- plan → executor instantiation -------------------------------------------


def _agent_and_example():
    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.envs.classic import make_vec
    import jax.numpy as jnp

    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    return agent, env_fn, example


def test_executor_from_plan_fused_and_async():
    from repro.runtime.executors import (AsyncExecutor, FusedExecutor,
                                         executor_from_plan)

    agent, env_fn, example = _agent_and_example()
    cfg = LoopConfig(batch_size=32, warmup=0, epsilon=0.3)

    pc = planner.PlannedConfig(backend="fused", n_envs=4, update_interval=4)
    ex = executor_from_plan(pc, agent, env_fn, cfg, example)
    assert isinstance(ex, FusedExecutor)
    assert ex.n_envs == 4
    assert ex.cfg.update_interval == 4    # the plan's ratio wins
    state, hist = ex.train(16, jax.random.PRNGKey(0))
    assert int(hist["env_steps"][-1]) == 64

    pc = planner.PlannedConfig(backend="async", publish_interval=3,
                               max_staleness=0, n_envs=4)
    ex = executor_from_plan(pc, agent, env_fn, cfg, example)
    assert isinstance(ex, AsyncExecutor)
    assert ex.publish_interval == 3


def test_executor_from_plan_sharded_single_device():
    """A 1-shard data mesh exists on any host — the sharded plan path
    end-to-end without forced devices."""
    from repro.runtime.executors import ShardedExecutor, executor_from_plan

    agent, env_fn, example = _agent_and_example()
    cfg = LoopConfig(batch_size=32, warmup=0, epsilon=0.3)
    pc = planner.PlannedConfig(backend="sharded", n_data=1, n_envs=4)
    ex = executor_from_plan(pc, agent, env_fn, cfg, example)
    assert isinstance(ex, ShardedExecutor)
    assert ex.n_shards == 1
    state, hist = ex.train(8, jax.random.PRNGKey(0))
    assert int(hist["env_steps"][-1]) == 32


def test_mesh_from_plan_shapes():
    from repro.launch.mesh import mesh_from_plan

    assert mesh_from_plan(
        planner.PlannedConfig(backend="fused")) is None
    m = mesh_from_plan(planner.PlannedConfig(backend="sharded", n_data=1))
    assert m.axis_names == ("data",) and m.devices.size == 1


@pytest.mark.slow
def test_quickstart_trains_from_plan_json(tmp_path):
    """The acceptance path: a planner-emitted BENCH_plan.json drives
    quickstart into the planned (sharded, forced-device) executor."""
    pc = planner.PlannedConfig(backend="sharded", n_data=2, n_envs=8,
                               update_interval=1,
                               predicted_env_steps_per_s=1234.0,
                               source="test")
    plan_path = tmp_path / "BENCH_plan.json"
    planner.save_plan(pc, str(plan_path))

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)            # quickstart sets the device flag
    env["PYTHONPATH"] = (f"{os.path.join(root, 'src')}:"
                         f"{env.get('PYTHONPATH', '')}").rstrip(":")
    r = subprocess.run(
        [sys.executable, os.path.join(root, "examples", "quickstart.py"),
         "--plan", str(plan_path), "--iterations", "48"],
        capture_output=True, text=True, timeout=600, env=env, cwd=root)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "planner-selected sharded executor on 2 device(s)" in r.stdout
    assert "final mean episode return" in r.stdout


# -- replay-service degree of freedom (DESIGN.md §11) ------------------------


def _serve_point(writers=2, n_shards=1, inserts=2000.0, samples=16000.0,
                 spi=8.0, batch=64):
    return {"writers": writers, "n_shards": n_shards, "spi": spi,
            "batch_size": batch, "inserts_per_s": inserts,
            "samples_per_s": samples, "realized_spi": spi,
            "repeats": 3, "rel_spread": 0.01}


def test_select_replay_service_feasibility():
    # spi target = 64/8 = 8 → need 8·insert_rate samples/s
    pts = [_serve_point(n_shards=1, inserts=2000.0, samples=16000.0),
           _serve_point(n_shards=2, inserts=4000.0, samples=32000.0)]
    # both clear 1000 inserts/s and 8000 samples/s — fewest shards win
    assert planner.select_replay_service(
        pts, insert_rate=1000.0, update_interval=8,
        batch_size=64) == (1, 8.0)
    # only the 2-shard config clears 3000 inserts/s
    assert planner.select_replay_service(
        pts, insert_rate=3000.0, update_interval=8,
        batch_size=64) == (2, 8.0)
    # nothing clears 5000 inserts/s → keep the replay in-loop
    assert planner.select_replay_service(
        pts, insert_rate=5000.0, update_interval=8,
        batch_size=64) == (0, 0.0)
    # insert rate fine but sample rate short → in-loop
    assert planner.select_replay_service(
        [_serve_point(inserts=2000.0, samples=100.0)],
        insert_rate=1000.0, update_interval=8, batch_size=64) == (0, 0.0)
    # batch must divide over shards (stratified sampling)
    assert planner.select_replay_service(
        [_serve_point(n_shards=3, inserts=9000.0, samples=72000.0)],
        insert_rate=1000.0, update_interval=8, batch_size=64) == (0, 0.0)
    assert planner.select_replay_service(
        [], insert_rate=1.0, update_interval=1, batch_size=64) == (0, 0.0)


def test_select_replay_service_headroom_tiebreak():
    roomy = _serve_point(writers=1, inserts=8000.0, samples=64000.0)
    tight = _serve_point(writers=4, inserts=1100.0, samples=8800.0)
    for pts in ([roomy, tight], [tight, roomy]):    # order-independent
        shards, spi = planner.select_replay_service(
            pts, insert_rate=1000.0, update_interval=8, batch_size=64)
        assert (shards, spi) == (1, 8.0)


def test_plan_threads_serve_points_into_config():
    fig9 = [_fig9_point("fused", steps=1000.0)]
    serve = [_serve_point(n_shards=2, inserts=4000.0, samples=32000.0)]
    pc = planner.plan(fig9, [], serve_points=serve, update_interval=8,
                      batch_size=64)
    assert pc.n_replay_shards == 2
    assert pc.samples_per_insert == 8.0
    assert "replay service" in pc.describe()
    # round trip keeps the service shape
    assert planner.PlannedConfig(**pc.to_dict()) == pc
    # no serve points → in-loop replay, and describe stays quiet
    pc0 = planner.plan(fig9, [])
    assert (pc0.n_replay_shards, pc0.samples_per_insert) == (0, 0.0)
    assert "replay service" not in pc0.describe()


def test_planned_config_service_validation():
    with pytest.raises(ValueError, match="n_replay_shards"):
        planner.PlannedConfig(backend="fused", n_replay_shards=-1)
    with pytest.raises(ValueError, match="samples_per_insert"):
        planner.PlannedConfig(backend="fused", samples_per_insert=4.0)
    with pytest.raises(ValueError, match="samples_per_insert"):
        planner.PlannedConfig(backend="fused", n_replay_shards=1,
                              samples_per_insert=-1.0)


def test_merge_bench_points_newest_wins(tmp_path):
    old = tmp_path / "old"
    new = tmp_path / "nested" / "new"
    old.mkdir()
    new.mkdir(parents=True)
    stale = _fig9_point("fused", steps=111.0)
    fresh = _fig9_point("fused", steps=999.0)    # same identity, new rate
    other = _fig9_point("async", publish_interval=4, steps=500.0)
    (old / planner.FIG9_JSON).write_text(json.dumps(
        {"figure": "fig9", "metric": "env_steps_per_s",
         "points": [stale, other]}))
    (new / planner.FIG9_JSON).write_text(json.dumps(
        {"figure": "fig9", "metric": "env_steps_per_s",
         "points": [fresh]}))
    os.utime(old / planner.FIG9_JSON, (1_000_000, 1_000_000))
    os.utime(new / planner.FIG9_JSON, (2_000_000, 2_000_000))
    # plan envelopes and junk are skipped, not fatal
    (tmp_path / "BENCH_plan.json").write_text(json.dumps(
        {"figure": "plan", "config": {}}))
    (tmp_path / "BENCH_broken.json").write_text("{not json")

    merged = planner.merge_bench_points(str(tmp_path))
    fig9 = merged["fig9"]
    assert len(fig9) == 2
    by_backend = {p["backend"]: p for p in fig9}
    assert by_backend["fused"]["env_steps_per_s"] == 999.0   # freshest wins
    assert by_backend["async"]["env_steps_per_s"] == 500.0


def test_plan_from_json_merges_serve(tmp_path):
    (tmp_path / planner.FIG9_JSON).write_text(json.dumps(
        {"figure": "fig9", "metric": "env_steps_per_s",
         "points": [_fig9_point("fused", steps=1200.0)]}))
    (tmp_path / planner.SERVE_JSON).write_text(json.dumps(
        {"figure": "serve", "metric": "inserts_per_s",
         "points": [_serve_point(inserts=40000.0, samples=320000.0)]}))
    pc = planner.plan_from_json(str(tmp_path), update_interval=8,
                                batch_size=64)
    assert pc.backend == "fused"
    assert pc.n_replay_shards == 1
    assert pc.samples_per_insert == 8.0


def test_schema_serve_payloads():
    from benchmarks import schema

    good = {"figure": "serve", "metric": "inserts_per_s", "smoke": True,
            "points": [_serve_point()]}
    assert schema.validate(good) == "serve"
    bad = _serve_point()
    del bad["samples_per_s"]
    with pytest.raises(schema.SchemaError, match="samples_per_s"):
        schema.validate({"figure": "serve", "metric": "inserts_per_s",
                         "points": [bad]})
    bad = _serve_point()
    bad["n_shards"] = "two"
    with pytest.raises(schema.SchemaError, match="n_shards"):
        schema.validate({"figure": "serve", "metric": "inserts_per_s",
                         "points": [bad]})


def test_executor_from_plan_replay_service():
    from repro.runtime.executors import executor_from_plan
    from repro.service import ServiceExecutor

    agent, env_fn, example = _agent_and_example()
    cfg = LoopConfig(batch_size=32, warmup=64, epsilon=0.3)
    pc = planner.PlannedConfig(backend="fused", n_envs=4, update_interval=4,
                               n_replay_shards=2, samples_per_insert=8.0)
    ex = executor_from_plan(pc, agent, env_fn, cfg, example)
    assert isinstance(ex, ServiceExecutor)
    assert ex.n_shards == 2
    assert ex.limiter.samples_per_insert == 8.0
    state, hist = ex.train(48, jax.random.PRNGKey(0))
    assert int(hist["env_steps"][-1]) == 192

    # a device mesh and a replay service cannot be combined
    pc = planner.PlannedConfig(backend="sharded", n_data=1, n_envs=4,
                               n_replay_shards=1)
    with pytest.raises(ValueError, match="mesh"):
        executor_from_plan(pc, agent, env_fn, cfg, example)
