"""Pallas kernel ↔ pure-jnp oracle allclose sweeps (interpret mode on CPU).

Sweeps shapes (capacities around block boundaries, batch sizes around
SAMPLE/UPDATE/GATHER blocks) and dtypes per the deliverable-(c) spec."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sumtree
from repro.kernels import ops, ref


def mk(capacity, fanout=128, seed=0, low=0.01, high=2.0):
    spec = sumtree.make_spec(capacity, fanout)
    rng = np.random.default_rng(seed)
    pri = rng.uniform(low, high, capacity).astype(np.float32)
    return spec, sumtree.build(spec, jnp.asarray(pri)), rng


@pytest.mark.parametrize("capacity", [100, 1000, 16384, 131072])
@pytest.mark.parametrize("batch", [1, 64, 128, 300, 512])
def test_sample_kernel_matches_ref(capacity, batch):
    spec, tree, rng = mk(capacity, seed=capacity + batch)
    u = jnp.asarray(rng.uniform(0, 1, batch).astype(np.float32))
    ri, rp = ref.sumtree_sample_ref(spec, tree, u)
    ki, kp = ops.sumtree_sample(spec, tree, u)
    ri_, ki_ = np.asarray(ri), np.asarray(ki)
    agree = ri_ == ki_
    assert agree.mean() > 0.99
    # disagreements must be fp ties: adjacent leaves with CDF gap ≈ eps·total
    if not agree.all():
        leaves = np.asarray(sumtree.leaves(spec, tree))
        cdf = np.cumsum(leaves)
        gap = np.abs(cdf[ri_[~agree]] - cdf[ki_[~agree]])
        assert (gap <= 2e-5 * cdf[-1] + np.maximum(
            leaves[ri_[~agree]], leaves[ki_[~agree]])).all()
    match_pri = np.asarray(rp)[agree]
    np.testing.assert_allclose(match_pri, np.asarray(kp)[agree],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fanout", [128, 256])
def test_sample_kernel_fanouts(fanout):
    spec, tree, rng = mk(2000, fanout=fanout, seed=fanout)
    u = jnp.asarray(rng.uniform(0, 1, 256).astype(np.float32))
    ri, _ = ref.sumtree_sample_ref(spec, tree, u)
    ki, _ = ops.sumtree_sample(spec, tree, u)
    assert (np.asarray(ri) == np.asarray(ki)).all()


@pytest.mark.parametrize("capacity", [100, 4096, 100_000])
@pytest.mark.parametrize("batch", [1, 17, 128, 257])
def test_update_kernel_matches_ref(capacity, batch):
    spec, tree, rng = mk(capacity, seed=capacity * 7 + batch)
    idx = jnp.asarray(rng.integers(0, capacity, batch).astype(np.int32))
    val = jnp.asarray(rng.uniform(0, 5, batch).astype(np.float32))
    rt = ref.sumtree_update_ref(spec, tree, idx, val)
    kt = ops.sumtree_update(spec, tree, idx, val)
    np.testing.assert_allclose(np.asarray(rt), np.asarray(kt),
                               rtol=1e-4, atol=2e-3)
    assert sumtree.check_invariant(spec, kt)


def test_update_kernel_cross_block_duplicates():
    """Duplicates spanning grid blocks must resolve sequentially
    (last-writer-wins across the whole batch)."""
    spec, tree, rng = mk(1000, seed=9)
    b = 3 * 128
    idx = np.full(b, 42, np.int32)
    idx[::3] = rng.integers(0, 1000, len(idx[::3]))
    val = rng.uniform(0, 5, b).astype(np.float32)
    rt = ref.sumtree_update_ref(spec, tree, jnp.asarray(idx), jnp.asarray(val))
    kt = ops.sumtree_update(spec, tree, jnp.asarray(idx), jnp.asarray(val))
    np.testing.assert_allclose(np.asarray(rt), np.asarray(kt),
                               rtol=1e-4, atol=2e-3)


def test_update_then_sample_kernel_pipeline():
    spec, tree, rng = mk(8192, seed=11)
    for it in range(3):
        idx = jnp.asarray(rng.integers(0, 8192, 128).astype(np.int32))
        val = jnp.asarray(rng.uniform(0, 4, 128).astype(np.float32))
        tree = ops.sumtree_update(spec, tree, idx, val)
    u = jnp.asarray(rng.uniform(0, 1, 128).astype(np.float32))
    ki, kp = ops.sumtree_sample(spec, tree, u)
    ri, rp = ref.sumtree_sample_ref(spec, tree, u)
    assert (np.asarray(ki) == np.asarray(ri)).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("n,f,b", [(777, 5, 99), (512, 128, 128), (2048, 33, 1)])
def test_gather_kernel_matches_ref(dtype, n, f, b):
    rng = np.random.default_rng(n + f + b)
    if dtype == jnp.int32:
        storage = jnp.asarray(rng.integers(0, 150_000, (n, f)), jnp.int32)
    else:
        storage = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32)).astype(dtype)
    idx = jnp.asarray(rng.integers(0, n, b).astype(np.int32))
    got = ops.prioritized_gather(storage, idx)
    want = ref.gather_rows_ref(storage, idx)
    if dtype == jnp.int32:
        assert (np.asarray(got) == np.asarray(want)).all()
    else:
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


def test_gather_kernel_rank3():
    rng = np.random.default_rng(0)
    storage = jnp.asarray(rng.normal(size=(300, 4, 7)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 300, 50).astype(np.int32))
    got = ops.prioritized_gather(storage, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(storage[idx]),
                               rtol=1e-6)


def test_sample_kernel_padded_tail_clamp_parity():
    """Regression: an fp-tail draw whose no-hit clamps cascade into the
    leaf-level padding used to return the *pre-clamp* cutoff lane's value
    (the padding zero) while the XLA path re-reads the priority after
    clamping to ``capacity - 1`` — different (idx, priority) pairs across
    backends.  The trigger is a tree whose internal sums slightly exceed
    the leaf sums (real-world source: f32 delta-propagation drift in
    ``update``): a draw at u → 1 then overshoots every leaf-row cumsum
    and the clamp lands in padding deterministically."""
    capacity, fanout = 10, 4
    spec = sumtree.make_spec(capacity, fanout)
    assert spec.num_leaves > capacity  # the padded tail exists
    pri = jnp.asarray(np.linspace(0.5, 1.4, capacity).astype(np.float32))
    tree = sumtree.build(spec, pri)
    # bump the root and the last nonzero level-1 parent coherently, so
    # both backends see the same total while every leaf row undershoots
    tree = tree.at[0].add(0.05).at[spec.offsets[1] + 2].add(0.05)
    u = jnp.asarray(np.concatenate([
        np.full(4, 1.0 - 1e-7, np.float32),          # forced tail clamps
        np.linspace(0.01, 0.95, 60).astype(np.float32),  # plus normal draws
    ]))
    xi, xp = sumtree.sample(spec, tree, u)
    ki, kp = ops.sumtree_sample(spec, tree, u)
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(ki))
    np.testing.assert_allclose(np.asarray(xp), np.asarray(kp),
                               rtol=1e-5, atol=1e-6)
    # the tail draws really exercised the clamp: they land on the last
    # real leaf with its true (re-read) priority, not the padding zero
    assert (np.asarray(xi)[:4] == capacity - 1).all()
    assert (np.asarray(kp)[:4] > 0).all()


@pytest.mark.parametrize("capacity", [100, 1000, 16384])
@pytest.mark.parametrize("batch", [1, 64, 300])
def test_fused_sample_gather_matches_split_kernels(capacity, batch):
    """The fused descent+gather kernel returns the identical indices and
    priorities as the split sample kernel (they share the descent code)
    and the exact storage rows for mixed-dtype payloads."""
    spec, tree, rng = mk(capacity, seed=capacity * 3 + batch)
    storage = {
        "obs": jnp.asarray(rng.normal(size=(capacity, 5)).astype(np.float32)),
        "action": jnp.asarray(rng.integers(0, 7, capacity), jnp.int32),
        "reward": jnp.asarray(rng.uniform(0, 1, capacity).astype(np.float32)),
    }
    u = jnp.asarray(rng.uniform(0, 1, batch).astype(np.float32))
    fi, fp, fitems = ops.sumtree_sample_gather(spec, tree, u, storage)
    si, sp = ops.sumtree_sample(spec, tree, u)
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(si))
    np.testing.assert_allclose(np.asarray(fp), np.asarray(sp),
                               rtol=1e-5, atol=1e-6)
    taken = np.asarray(fi)
    np.testing.assert_allclose(np.asarray(fitems["obs"]),
                               np.asarray(storage["obs"])[taken],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(fitems["action"]),
                                  np.asarray(storage["action"])[taken])
    assert fitems["action"].dtype == jnp.int32
    np.testing.assert_allclose(np.asarray(fitems["reward"]),
                               np.asarray(storage["reward"])[taken],
                               rtol=1e-5, atol=1e-6)


def test_fused_sample_gather_rank3_and_scalar_leaves():
    spec, tree, rng = mk(500, seed=17)
    storage = {
        "frames": jnp.asarray(rng.normal(size=(500, 3, 4)).astype(np.float32)),
        "done": jnp.asarray(rng.integers(0, 2, 500).astype(np.float32)),
    }
    u = jnp.asarray(rng.uniform(0, 1, 100).astype(np.float32))
    fi, _, fitems = ops.sumtree_sample_gather(spec, tree, u, storage)
    taken = np.asarray(fi)
    assert fitems["frames"].shape == (100, 3, 4)
    np.testing.assert_allclose(np.asarray(fitems["frames"]),
                               np.asarray(storage["frames"])[taken],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(fitems["done"]),
                                  np.asarray(storage["done"])[taken])


def test_fused_sample_gather_vmem_fallback_exact():
    """Above the VMEM budget the fused op must fall back to the split
    XLA path and still return exact rows."""
    big = ops.KERNEL_TREE_BYTE_BUDGET // 4 + 50_000
    spec = sumtree.make_spec(big, 128)
    assert not ops.kernel_path_ok(spec)
    rng = np.random.default_rng(2)
    pri = rng.uniform(0.01, 1, big).astype(np.float32)
    tree = sumtree.build(spec, jnp.asarray(pri))
    storage = {"x": jnp.asarray(rng.normal(size=(big, 2)).astype(np.float32))}
    u = jnp.asarray(rng.uniform(0, 1, 32).astype(np.float32))
    fi, _, fitems = ops.sumtree_sample_gather(spec, tree, u, storage)
    ri, _ = ref.sumtree_sample_ref(spec, tree, u)
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(fitems["x"]),
                                  np.asarray(storage["x"])[np.asarray(fi)])


def test_update_kernel_unique_skips_dedup_correctly():
    """unique=True (FIFO insert slots) must produce the same tree as the
    dedup path when indices really are distinct."""
    spec, tree, rng = mk(2048, seed=23)
    idx = jnp.asarray(rng.permutation(2048)[:256].astype(np.int32))
    val = jnp.asarray(rng.uniform(0, 3, 256).astype(np.float32))
    t_dedup = ops.sumtree_update(spec, tree, idx, val)
    t_unique = ops.sumtree_update(spec, tree, idx, val, unique=True)
    np.testing.assert_allclose(np.asarray(t_dedup), np.asarray(t_unique),
                               rtol=1e-5, atol=1e-4)


def test_vmem_budget_fallback():
    """Above the VMEM budget the ops must fall back to the XLA path and
    still be exact."""
    big = ops.KERNEL_TREE_BYTE_BUDGET // 4 + 100_000
    spec = sumtree.make_spec(big, 128)
    assert not ops.kernel_path_ok(spec)
    rng = np.random.default_rng(1)
    pri = rng.uniform(0.01, 1, big).astype(np.float32)
    tree = sumtree.build(spec, jnp.asarray(pri))
    u = jnp.asarray(rng.uniform(0, 1, 64).astype(np.float32))
    ki, _ = ops.sumtree_sample(spec, tree, u)
    ri, _ = ref.sumtree_sample_ref(spec, tree, u)
    assert (np.asarray(ki) == np.asarray(ri)).all()
