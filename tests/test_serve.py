"""Continuous-batching actor-server tests (DESIGN.md §13).

The invariants that make the serve frontend trustworthy, each pinned:
bucket assignment is a pure deterministic function with hard edges;
prefill retraces are bounded to the bucket set (compile-counter spy) and
the vmapped decode compiles exactly once; a finished slot is reused by
the next queued request (continuous batching, no global drain); a batch
step never mixes two parameter versions and a mid-step publication only
lands at the next step boundary; continuous batching is BIT-EXACT
against solo greedy decodes (slot isolation + pad-shadowing, the
strongest single check); token accounting is closed-form exact; the
"actor" BENCH schema accepts the emitted shape and rejects malformed
payloads; and the bench-archive merge tool is superset-safe including
the silent-cache-miss drill.
"""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from benchmarks import schema
from repro.agents import token_dqn
from repro.configs import get_config
from repro.models import backbone
from repro.models.config import NO_SHARDING
from repro.serve import (ActorServeConfig, ActorServer, BucketSpec,
                         DecodeEngine, ParamDoubleBuffer, Scheduler,
                         ServiceParamChannel)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def smoke():
    cfg = get_config("granite_8b", smoke=True)
    params = backbone.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _drain(sched, params, version=0, max_steps=500):
    completions = []
    for _ in range(max_steps):
        if not sched.busy:
            return completions
        completions.extend(sched.serve_step(params, version))
    raise AssertionError(f"scheduler did not drain in {max_steps} steps")


def _solo_greedy(cfg, params, prompt, n_tokens, max_len):
    """Reference: exact-length prefill + plain decode loop, batch 1."""
    logits, cache = backbone.prefill(
        cfg, NO_SHARDING, params, prompt.reshape(1, -1), max_len=max_len)
    off = logits.shape[1] - prompt.shape[0]
    tok = int(np.argmax(np.asarray(logits[0, off + prompt.shape[0] - 1])))
    out = [tok]
    for _ in range(n_tokens - 1):
        lg, cache = backbone.decode_step(
            cfg, NO_SHARDING, params, cache,
            np.full((1, 1), out[-1], np.int32))
        out.append(int(np.argmax(np.asarray(lg[0, -1]))))
    return out


# -- buckets ------------------------------------------------------------------

def test_bucket_assignment_deterministic():
    spec = BucketSpec((4, 8, 32))
    assert [spec.bucket_for(n) for n in (1, 4, 5, 8, 9, 32)] == \
        [4, 4, 8, 8, 32, 32]
    # pure function of (edges, length): same answer every time
    assert spec.bucket_for(5) == spec.bucket_for(5) == 8
    padded = spec.pad(np.arange(1, 6, dtype=np.int32))
    assert padded.shape == (1, 8)
    assert padded[0, :5].tolist() == [1, 2, 3, 4, 5]
    assert padded[0, 5:].tolist() == [0, 0, 0]


def test_bucket_errors():
    with pytest.raises(ValueError, match="at least one edge"):
        BucketSpec(())
    with pytest.raises(ValueError, match="strictly increasing"):
        BucketSpec((8, 4))
    with pytest.raises(ValueError, match="strictly increasing"):
        BucketSpec((4, 4))
    spec = BucketSpec((4, 8))
    with pytest.raises(ValueError, match="must be >= 1"):
        spec.bucket_for(0)
    with pytest.raises(ValueError, match="exceeds the largest bucket edge"):
        spec.bucket_for(9)
    with pytest.raises(ValueError, match="must be 1-D"):
        spec.pad(np.zeros((1, 4), np.int32))


def test_engine_admission_checks(smoke):
    cfg, params = smoke
    with pytest.raises(ValueError, match="exceeds.*max_len"):
        DecodeEngine(cfg, slots=1, max_len=4, buckets=BucketSpec((8,)))
    eng = DecodeEngine(cfg, slots=1, max_len=8, buckets=BucketSpec((4,)))
    eng.fits(4, 5)                      # last write at position 7: fits
    with pytest.raises(ValueError, match="overrun the KV cache"):
        eng.fits(4, 6)                  # last write at position 8: overrun
    with pytest.raises(ValueError, match="exceeds the largest bucket edge"):
        eng.fits(5, 1)
    with pytest.raises(ValueError, match="must be >= 1"):
        eng.fits(4, 0)


def test_engine_rejects_recurrent_families(smoke):
    import dataclasses

    cfg, _ = smoke
    bad = dataclasses.replace(cfg, family="ssm")
    with pytest.raises(ValueError, match="pad-then-rewind"):
        DecodeEngine(bad, slots=1, max_len=8, buckets=BucketSpec((4,)))


# -- retraces + continuous batching ------------------------------------------

def test_retraces_bounded_to_bucket_set(smoke):
    """The §13 invariant: prefill compiles == buckets TOUCHED (never more),
    decode compiles exactly once regardless of traffic shape."""
    cfg, params = smoke
    eng = DecodeEngine(cfg, slots=2, max_len=12,
                       buckets=BucketSpec((4, 8)))
    sched = Scheduler(eng)
    rng = np.random.RandomState(0)
    # lengths 1..4 land in bucket 4; only it should compile
    for n in (1, 3, 4, 2, 4):
        sched.submit(rng.randint(0, cfg.vocab_size, size=n), 4)
    _drain(sched, params)
    assert eng.prime_compiles == 1, eng.prime_compiles
    assert eng.decode_compiles == 1, eng.decode_compiles
    # lengths 5..8 touch the second bucket: exactly one more compile
    for n in (5, 8, 6):
        sched.submit(rng.randint(0, cfg.vocab_size, size=n), 4)
    _drain(sched, params)
    assert eng.prime_compiles == 2, eng.prime_compiles
    assert eng.decode_compiles == 1, eng.decode_compiles


def test_finished_slot_reused(smoke):
    """3 requests on 2 slots: the third admits into a slot freed by an
    eviction, at a later step — continuous batching, not a drain."""
    cfg, params = smoke
    eng = DecodeEngine(cfg, slots=2, max_len=12, buckets=BucketSpec((4,)))
    sched = Scheduler(eng)
    rng = np.random.RandomState(1)
    rids = [sched.submit(rng.randint(0, cfg.vocab_size, size=3), 4)
            for _ in range(3)]
    completions = _drain(sched, params)
    assert sorted(c.rid for c in completions) == rids
    log = {rid: (slot, step) for rid, slot, step in sched.admission_log}
    first_two_slots = {log[rids[0]][0], log[rids[1]][0]}
    assert first_two_slots == {0, 1}
    reused_slot, admit_step = log[rids[2]]
    assert reused_slot in first_two_slots           # a recycled slot
    assert admit_step > log[rids[0]][1]             # admitted later,
    # after the slot's previous occupant finished (4 tokens = 3 decode
    # steps past admission)
    assert admit_step >= 3


def test_continuous_matches_solo_greedy(smoke):
    """The strongest check: tokens from 3 requests interleaved on 2
    slots (mixed buckets, mid-flight admission) are bit-identical to
    each request decoded alone with exact-length prefill — slot
    isolation AND pad-shadowing in one assertion."""
    cfg, params = smoke
    max_len = 16
    eng = DecodeEngine(cfg, slots=2, max_len=max_len,
                       buckets=BucketSpec((4, 8)))
    sched = Scheduler(eng)
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (3, 6, 5)]                  # buckets 4, 8, 8
    gen = 6
    for p in prompts:
        sched.submit(p, gen)
    completions = {c.rid: c for c in _drain(sched, params)}
    for rid, p in enumerate(prompts):
        ref = _solo_greedy(cfg, params, p, gen, max_len)
        assert completions[rid].tokens == ref, (rid, completions[rid].tokens,
                                                ref)


def test_slot_mask_freezes_free_slot(smoke):
    """A masked-out slot's cache (including pos) must not advance and
    its action is pinned to 0 — the release/admission gap is inert."""
    cfg, params = smoke
    eng = DecodeEngine(cfg, slots=2, max_len=8, buckets=BucketSpec((4,)))
    tok, slot_cache = eng.prime(params, np.arange(1, 4, dtype=np.int32))
    state = eng.init_state()
    state = eng.insert(state, 0, slot_cache, tok)   # slot 1 stays free
    frozen_before = jax.tree.map(
        lambda x: np.asarray(x[1]).copy(), state.cache)
    actions, state = eng.step(params, state)
    acts = np.asarray(actions)
    assert acts[1] == 0                              # pinned, not decoded
    frozen_after = jax.tree.map(
        lambda x: np.asarray(x[1]), state.cache)
    jax.tree.map(np.testing.assert_array_equal, frozen_before, frozen_after)


def test_exact_token_accounting(smoke):
    """admissions + decoded_tokens == every token handed out, including
    the budget-1 edge case (complete at admission, zero decode steps)."""
    cfg, params = smoke
    eng = DecodeEngine(cfg, slots=2, max_len=12, buckets=BucketSpec((4,)))
    sched = Scheduler(eng)
    rng = np.random.RandomState(3)
    budgets = [1, 4, 2, 1, 3]
    for b in budgets:
        sched.submit(rng.randint(0, cfg.vocab_size, size=3), b)
    completions = _drain(sched, params)
    out = sum(len(c.tokens) for c in completions)
    assert out == sum(budgets)
    assert [len(c.tokens) for c in
            sorted(completions, key=lambda c: c.rid)] == budgets
    assert sched.admissions == len(budgets)
    assert sched.generated_tokens == sched.admissions + sched.decoded_tokens
    assert sched.generated_tokens == out


# -- parameter publication ----------------------------------------------------

def test_double_buffer_swap_discipline():
    buf = ParamDoubleBuffer({"w": 0}, version=1)
    assert buf.swap_if_staged() == ({"w": 0}, 1, False)
    assert buf.stage({"w": 1}) == 2                 # auto-increment
    assert buf.version == 1                          # live half untouched
    params, version, swapped = buf.swap_if_staged()
    assert (params, version, swapped) == ({"w": 1}, 2, True)
    # stale publishes are dropped
    assert buf.stage({"w": 9}, version=2) == 2
    assert buf.swap_if_staged()[2] is False
    # staged-but-unswapped is superseded by a newer stage
    buf.stage({"w": 3}, version=3)
    buf.stage({"w": 4}, version=5)
    assert buf.swap_if_staged() == ({"w": 4}, 5, True)
    assert buf.swaps == 2


def test_no_version_mix_within_step(smoke):
    """A publication staged while a step is in flight lands at the NEXT
    boundary: every step_log entry carries exactly one version, version
    changes only between steps, and the swap_log step matches the first
    step that saw the new version."""
    cfg, params = smoke
    server = ActorServer(
        cfg, params,
        ActorServeConfig(slots=2, max_len=12, buckets=(4,),
                         max_new_tokens=6),
        params_version=1)
    rng = np.random.RandomState(4)
    handles = [server.submit(rng.randint(0, cfg.vocab_size, size=3))
               for _ in range(2)]
    server.serve_step()                              # steps at v1
    server.serve_step()
    v2 = server.publish(params)                      # staged, not live
    assert server.params.version == 1                # not yet swapped
    log_before = list(server.scheduler.step_log)
    assert {v for _, v, _ in log_before} == {1}
    server.serve_step()                              # boundary: v2 lands
    while server.scheduler.busy:
        server.serve_step()
    for h in handles:
        assert h.done()
    log = list(server.scheduler.step_log)
    versions = [v for _, v, _ in log]
    # single version per entry by construction; the sequence is a clean
    # monotonic 1→2 split with no interleaving
    assert versions == sorted(versions)
    assert set(versions) == {1, v2}
    first_v2_step = next(s for s, v, _ in log if v == v2)
    assert list(server._swap_log) == [(first_v2_step, v2)]
    assert all(s < first_v2_step for s, v, _ in log if v == 1)


def test_service_channel_publishes_under_traffic(smoke):
    """End-to-end publication drill through the replay service's
    versioned params channel against a live background serve loop."""
    import pickle

    from repro.service import ReplayService, ReplayServiceConfig

    cfg, params = smoke
    service = ReplayService(ReplayServiceConfig(capacity_per_shard=8,
                                                n_shards=1),
                            {"obs": np.zeros((2,), np.float32)})
    server = ActorServer(
        cfg, params,
        ActorServeConfig(slots=2, max_len=12, buckets=(4,),
                         max_new_tokens=4, idle_wait_s=0.005),
        params_version=0, param_source=service)
    blob = pickle.dumps(jax.tree.map(np.asarray, params),
                        protocol=pickle.HIGHEST_PROTOCOL)
    try:
        server.start()
        rng = np.random.RandomState(5)
        first = [server.submit(rng.randint(0, cfg.vocab_size, size=3))
                 for _ in range(3)]
        for h in first:
            h.result(timeout=120.0)
        service.put_params(blob)                     # learner-side publish
        second = [server.submit(rng.randint(0, cfg.vocab_size, size=3))
                  for _ in range(3)]
        done = [h.result(timeout=120.0) for h in second]
        stats = server.stats()
        assert stats["params_version"] == 1          # channel version landed
        assert stats["param_swaps"] == 1
        assert stats["completed"] == 6
        # requests finished after the swap carry the new version
        assert all(c.params_version in (0, 1) for c in done)
        assert any(c.params_version == 1 for c in done)
        assert stats["generated_tokens"] == 6 * 4
    finally:
        server.stop()
        service.stop()


def test_channel_poll_is_nonblocking_and_deduped(smoke):
    """poll() returns False on an empty channel and never re-stages a
    version it has already seen."""
    import pickle

    from repro.service import ReplayService, ReplayServiceConfig

    service = ReplayService(ReplayServiceConfig(capacity_per_shard=8,
                                                n_shards=1),
                            {"obs": np.zeros((2,), np.float32)})
    try:
        buf = ParamDoubleBuffer({"w": 0}, version=0)
        chan = ServiceParamChannel(service, buf)
        assert chan.poll() is False                  # nothing published
        service.put_params(pickle.dumps({"w": 1}))
        assert chan.poll() is True
        assert buf.staged_version == 1
        assert chan.poll() is False                  # same version: deduped
        _, v, swapped = buf.swap_if_staged()
        assert (v, swapped) == (1, True)
        assert chan.poll() is False
    finally:
        service.stop()


# -- schema + archive tooling -------------------------------------------------

def _actor_point(**over):
    point = {
        "users": 1, "target_rps": 2.0, "overload": False, "slots": 4,
        "gen_tokens": 8, "arch": "granite-smoke", "prompt_buckets": "4/8",
        "requests_per_s": 2.0, "p50_ms": 5.0, "p99_ms": 9.0,
        "param_swaps": 1, "repeats": 3, "rel_spread": 0.01,
    }
    point.update(over)
    return point


def _actor_payload(points):
    return {"figure": "actor", "metric": "requests_per_s", "smoke": True,
            "points": points}


def test_schema_actor_accepts_emitted_shape():
    assert schema.validate(_actor_payload([
        _actor_point(),
        _actor_point(users=2, target_rps=16.0, overload=True,
                     p99_before_swap_ms=7.0, p99_after_swap_ms=8.0),
    ])) == "actor"
    # the committed baseline itself must validate
    assert schema.validate_file(
        os.path.join(REPO, "BENCH_actor.json")) == "actor"


def test_schema_actor_rejects_malformed():
    with pytest.raises(schema.SchemaError, match="missing required"):
        p = _actor_point()
        del p["users"]
        schema.validate(_actor_payload([p]))
    with pytest.raises(schema.SchemaError, match="must be > 0"):
        schema.validate(_actor_payload([_actor_point(requests_per_s=0.0)]))
    with pytest.raises(schema.SchemaError, match="unknown fields"):
        schema.validate(_actor_payload([_actor_point(surprise=1)]))
    with pytest.raises(schema.SchemaError, match="metric must be"):
        bad = _actor_payload([_actor_point()])
        bad["metric"] = "env_steps_per_s"
        schema.validate(bad)
    with pytest.raises(schema.SchemaError, match="expected.*got bool"):
        schema.validate(_actor_payload([_actor_point(users=True)]))


def _run_archive(archive, fresh, run_id):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "bench_archive.py"),
         "--archive", str(archive), "--fresh", str(fresh),
         "--run-id", str(run_id)],
        capture_output=True, text=True, env=env, cwd=REPO)


def test_bench_archive_merges_runs(tmp_path):
    """Two runs with overlapping + disjoint identities: the merged
    snapshot is a superset of both, freshest measurement wins, and the
    silent-cache-miss drill hard-fails."""
    f1 = tmp_path / "f1" / "bench-json-actor"
    f2 = tmp_path / "f2" / "bench-json-actor"
    for d in (f1, f2):
        d.mkdir(parents=True)
    (f1 / "BENCH_actor.json").write_text(json.dumps(_actor_payload(
        [_actor_point(), _actor_point(users=2)])))
    # run 2 remeasures users=1 (fresher value must win) + adds users=4
    (f2 / "BENCH_actor.json").write_text(json.dumps(_actor_payload(
        [_actor_point(requests_per_s=3.5), _actor_point(users=4)])))
    archive = tmp_path / "arch"

    r1 = _run_archive(archive, f1.parent, "111")
    assert r1.returncode == 0, r1.stdout + r1.stderr
    assert "first archived run" in r1.stdout

    os.utime(f2 / "BENCH_actor.json")               # strictly newer mtime
    r2 = _run_archive(archive, f2.parent, "222")
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "MERGED_RUNS=2" in r2.stdout
    merged = json.loads(
        (archive / "merged" / "BENCH_actor.json").read_text())
    assert schema.validate(merged) == "actor"
    by_users = {p["users"]: p for p in merged["points"]}
    assert set(by_users) == {1, 2, 4}                # union of identities
    assert by_users[1]["requests_per_s"] == 3.5      # freshest wins
    manifest = json.loads((archive / "manifest.json").read_text())
    assert [r["id"] for r in manifest["runs"]] == ["111", "222"]

    # the cache-restore-missed drill: manifest says 2 runs, runs/ gone
    import shutil
    shutil.rmtree(archive / "runs")
    r3 = _run_archive(archive, f2.parent, "333")
    assert r3.returncode == 1
    assert "cache restore silently missed" in r3.stderr
