"""Per-arch smoke tests (deliverable f): reduced same-family configs run a
forward + train step on CPU, asserting shapes and finiteness; plus
decode↔forward consistency and the mamba-chunked-vs-sequential oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agents import token_dqn
from repro.configs import ARCH_IDS, get_config
from repro.models import backbone, mamba
from repro.models.config import NO_SHARDING

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    s_text = s - (cfg.num_patch_tokens if cfg.family == "vlm" else 0)
    tokens = jax.random.randint(key, (b, s_text), 0, cfg.vocab_size)
    extra = None
    if cfg.family == "vlm":
        extra = jax.random.normal(key, (b, cfg.num_patch_tokens, cfg.d_model)) * 0.1
    if cfg.family == "audio":
        extra = jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model)) * 0.1
    return tokens, extra, s_text


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = backbone.init_params(cfg, KEY)
    tokens, extra, s_text = _inputs(cfg)
    logits = backbone.forward(cfg, NO_SHARDING, params, tokens, extra)
    exp_s = s_text + (cfg.num_patch_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, exp_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    tcfg = token_dqn.TokenDQNConfig(accum=2)
    state = token_dqn.init_train_state(cfg, tcfg, KEY)
    b, s = 4, 32
    tokens, extra, s_text = _inputs(cfg, b=b, s=s)
    batch = {
        "tokens": tokens,
        "actions": jax.random.randint(KEY, (b, s_text), 0, cfg.vocab_size),
        "rewards": jax.random.uniform(KEY, (b, s_text)),
        "dones": jnp.zeros((b, s_text)),
        "is_weights": jnp.ones((b,)),
    }
    if extra is not None:
        batch["extra_embeds"] = extra
    state2, metrics, tds = token_dqn.train_step(cfg, NO_SHARDING, tcfg, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert tds.shape == (b,) and np.isfinite(np.asarray(tds)).all()
    assert int(state2.step) == 1
    # params actually moved
    d0 = jax.tree.leaves(state.params)[1]
    d1 = jax.tree.leaves(state2.params)[1]
    assert not np.allclose(np.asarray(d0, np.float32), np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", ["granite_8b", "mixtral_8x7b", "hymba_1_5b",
                                  "xlstm_125m", "whisper_medium"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = backbone.init_params(cfg, KEY)
    b, s, extra_steps, max_len = 2, 16, 2, 32
    tokens, extra, s_text = _inputs(cfg, b=b, s=s + extra_steps, seed=1)
    prompt = tokens[:, :s_text - extra_steps]
    logits_p, cache = backbone.prefill(cfg, NO_SHARDING, params, prompt,
                                       max_len, extra)
    outs = []
    for t in range(extra_steps):
        tok = tokens[:, s_text - extra_steps + t: s_text - extra_steps + t + 1]
        lg, cache = backbone.decode_step(cfg, NO_SHARDING, params, cache, tok)
        outs.append(lg[:, 0])
    ref = backbone.forward(cfg, NO_SHARDING, params, tokens, extra)
    off = ref.shape[1] - tokens.shape[1]
    for t in range(extra_steps):
        pos = off + s_text - extra_steps + t
        np.testing.assert_allclose(
            np.asarray(outs[t], np.float32), np.asarray(ref[:, pos], np.float32),
            atol=5e-5, rtol=1e-3)


def test_mamba_chunked_matches_sequential():
    """Chunked SSD (training path) ↔ O(1) recurrence (decode path)."""
    cfg = dataclasses.replace(get_config("hymba_1_5b", smoke=True), num_layers=1)
    p = mamba.mamba_init(cfg, KEY)
    b, s = 2, mamba.CHUNK * 2
    x = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.d_model)) * 0.3
    y_chunked = mamba.mamba_scan(cfg, NO_SHARDING, p, x)
    state = mamba.mamba_decode_init(cfg, b)
    ys = []
    for t in range(s):
        y_t, state = mamba.mamba_decode_step(cfg, NO_SHARDING, p,
                                             x[:, t:t + 1], state)
        ys.append(y_t[:, 0])
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked, np.float32),
                               np.asarray(y_seq, np.float32),
                               atol=1e-4, rtol=1e-3)
    # prefill state equals sequential final state
    st_prefill = mamba.mamba_prefill_state(cfg, NO_SHARDING, p, x)
    np.testing.assert_allclose(np.asarray(st_prefill), np.asarray(state),
                               atol=1e-4, rtol=1e-3)


def test_unroll_matches_scan():
    """scan_layers=False (cost-probe path) is numerically identical."""
    cfg = get_config("granite_8b", smoke=True)
    params = backbone.init_params(cfg, KEY)
    tokens, _, _ = _inputs(cfg)
    a = backbone.forward(cfg, NO_SHARDING, params, tokens)
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    b = backbone.forward(cfg_u, NO_SHARDING, params, tokens)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-5)


def test_sliding_window_masks_old_tokens():
    cfg = dataclasses.replace(get_config("mixtral_8x7b", smoke=True),
                              window=8, num_layers=1)
    params = backbone.init_params(cfg, KEY)
    tokens, _, _ = _inputs(cfg, b=1, s=24, seed=3)
    base = backbone.forward(cfg, NO_SHARDING, params, tokens)
    # perturbing a token > window away must not change the last position
    tokens2 = tokens.at[0, 2].set((tokens[0, 2] + 1) % cfg.vocab_size)
    pert = backbone.forward(cfg, NO_SHARDING, params, tokens2)
    np.testing.assert_allclose(np.asarray(base[0, -1], np.float32),
                               np.asarray(pert[0, -1], np.float32), atol=1e-5)
    # ...but perturbing inside the window does
    tokens3 = tokens.at[0, 20].set((tokens[0, 20] + 1) % cfg.vocab_size)
    pert3 = backbone.forward(cfg, NO_SHARDING, params, tokens3)
    assert not np.allclose(np.asarray(base[0, -1], np.float32),
                           np.asarray(pert3[0, -1], np.float32), atol=1e-5)
