"""Checkpoint manager: atomic roundtrip, async save, keep-k GC, crash-safe
staging, elastic resharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint.elastic import _filter_spec, reshard
from repro.checkpoint.manager import CheckpointManager


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)),
                   "b": jnp.zeros((16,), jnp.bfloat16)},
        "opt": [jnp.ones((3,)), jnp.asarray(7, jnp.int32)],
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = tree()
    mgr.save(10, t)
    step, restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, t))
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree(s))
    mgr.wait()
    assert mgr.all_steps() == [3, 4]     # keep-last-2 GC
    _, restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, tree()))
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(tree(4)["params"]["w"]))


def test_crash_safe_tmp_not_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, tree())
    # simulate a crash mid-save: stray .tmp directory
    os.makedirs(tmp_path / "step_6.tmp")
    assert mgr.all_steps() == [5]        # uncommitted step invisible
    step, _ = mgr.restore_latest(jax.tree.map(jnp.zeros_like, tree()))
    assert step == 5


def test_resave_same_step_replaces_committed_checkpoint(tmp_path):
    # a restart that re-saves at its resume step must replace the old
    # commit, not crash on rename-over-nonempty-dir (POSIX EEXIST/ENOTEMPTY)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(7, tree(0))
    mgr.save(7, tree(1))
    assert mgr.all_steps() == [7]
    _, restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, tree()))
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(tree(1)["params"]["w"]))


def test_extra_blobs_roundtrip(tmp_path):
    """Opaque sidecar blobs (the replay server's service.json/params.bin
    snapshot metadata) commit atomically with the arrays and read back
    by name; absent names are None, reserved names are rejected."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(3, tree(), extra={"service.json": b'{"appends": 7}',
                              "params.bin": b"\x00\x01\x02"})
    assert mgr.read_extra(3, "service.json") == b'{"appends": 7}'
    assert mgr.read_extra(3, "params.bin") == b"\x00\x01\x02"
    assert mgr.read_extra(3, "absent.bin") is None
    # the arrays ride the same commit
    step, restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, tree()))
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree()["params"]["w"]))
    # a save without extras is still readable (and reports no blobs)
    mgr.save(4, tree(1))
    assert mgr.read_extra(4, "service.json") is None
    for bad in ("arrays.npz", "manifest.json", "a/b.json"):
        with pytest.raises(ValueError):
            mgr.save(5, tree(), extra={bad: b"x"})


def test_manifest_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree())
    bad_example = {"params": {"w": jnp.zeros((8, 16))}}   # missing keys
    with pytest.raises(ValueError):
        mgr.restore(1, bad_example)


def test_elastic_spec_filtering():
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    # multi-pod spec shrinks onto a single-pod mesh
    assert _filter_spec(mesh, P(("pod", "data"), "model")) == P(("data",), "model")
    assert _filter_spec(mesh, P("pod", None)) == P(None, None)


def test_elastic_reshard_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 16))}
    mgr.save(1, t)
    _, restored = mgr.restore_latest({"w": jnp.zeros((8, 16))})
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    specs = {"w": P(("pod", "data"), "model")}   # checkpointed at 2 pods
    out = reshard(restored, specs, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))


def test_elastic_reshard_agent_state(tmp_path):
    """The service learner's resume path (launch/multiprocess.py): a full
    AgentState — registered dataclass containers, optax NamedTuple
    chains, integer step counters — checkpoints on one topology and
    reshards replicated onto the current 1-device mesh in one call."""
    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.envs.classic import make_vec

    spec, _, _ = make_vec("cartpole", 1)
    agent = make_dqn(spec, DQNConfig())
    state = agent.init(jax.random.PRNGKey(3))

    mgr = CheckpointManager(str(tmp_path))
    payload = {"agent": state, "learn_step": np.asarray(41, np.int32)}
    mgr.save(41, payload)

    zeros = {"agent": jax.tree.map(jnp.zeros_like, state),
             "learn_step": np.zeros((), np.int32)}
    step, restored = mgr.restore_latest(zeros)
    assert step == 41

    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
    specs = {"agent": jax.tree.map(lambda _: P(), restored["agent"]),
             "learn_step": None}
    out = reshard(restored, specs, mesh)

    assert int(out["learn_step"]) == 41
    ref = jax.tree_util.tree_leaves(state)
    got = jax.tree_util.tree_leaves(out["agent"])
    assert len(ref) == len(got)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    for leaf in got:    # every leaf landed fully replicated on the mesh
        assert leaf.sharding.is_fully_replicated


def test_elastic_reshard_mixed_specs():
    """Spec trees mix PartitionSpec leaves and None (= replicated); a
    sharded spec whose axes are absent from the mesh degrades to
    replicated instead of erroring (elastic shrink)."""
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
    t = {"a": jnp.arange(8.0), "nest": {"b": jnp.ones((4, 4))}}
    specs = {"a": P("model"),            # 'model' not in this mesh
             "nest": {"b": None}}
    out = reshard(t, specs, mesh)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(8.0))
    assert out["a"].sharding.is_fully_replicated
    assert out["nest"]["b"].sharding.is_fully_replicated
