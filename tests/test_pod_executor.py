"""Two-axis (pod × data) sharded executor (DESIGN.md §7): a degenerate
pod mesh reproduces the 1-D data mesh, the hierarchical int8-EF
compressed cross-pod reduce stays within EF tolerance of the
uncompressed run, and the 2×2 pod×data path trains CartPole end to end
with a 4×-smaller cross-pod payload (subprocess tests: the forced
host-device count must be set before jax initializes)."""

import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agents.dqn import DQNConfig, make_dqn
from repro.core.distributed import ShardedPrioritizedReplay, ShardedReplayConfig
from repro.envs.classic import make_vec
from repro.launch.mesh import data_mesh, pod_data_mesh
from repro.runtime.executors import AsyncExecutor, FusedExecutor, ShardedExecutor
from repro.runtime.loop import LoopConfig


def transition_example(spec):
    return {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }


def _setup(cfg):
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    mk_replay = lambda axes: ShardedPrioritizedReplay(
        ShardedReplayConfig(capacity_per_shard=1024, fanout=8,
                            axis_names=axes), transition_example(spec))
    return env_fn, agent, mk_replay


def test_1x1_pod_data_reproduces_fused():
    """The degenerate 1×1 pod×data mesh (both collectives over size-1
    axes) must reproduce the fused program's metrics — the multi-axis
    generalization adds no numerics at extent 1."""
    cfg = LoopConfig(batch_size=32, warmup=8, epsilon=0.2)
    env_fn, agent, mk_replay = _setup(cfg)
    fused = FusedExecutor(
        agent,
        mk_replay(("data",)).local,  # plain single-shard buffer
        env_fn, cfg, n_envs=4, scan_chunk=4)
    pod = ShardedExecutor(agent, mk_replay(("pod", "data")), env_fn, cfg,
                          n_envs=4, mesh=pod_data_mesh(1, 1), scan_chunk=4)
    key = jax.random.PRNGKey(7)
    s1, h1 = fused.train(12, key)
    s2, h2 = pod.train(12, key)
    for k in ("env_steps", "learn_steps", "buffer_size"):
        np.testing.assert_array_equal(np.asarray(h1[k]), np.asarray(h2[k]),
                                      err_msg=k)
    np.testing.assert_allclose(np.asarray(h1["loss"]), np.asarray(h2["loss"]),
                               rtol=1e-4, atol=1e-6)


def test_1x1_compressed_reduce_runs_and_threads_ef_state():
    """Compression on the degenerate mesh: the cross-pod compressed_pmean
    over a size-1 axis quantizes and dequantizes every gradient, so the
    run must stay finite, still learn, and carry a live (non-empty)
    error-feedback buffer in LoopState.ef_error."""
    cfg = LoopConfig(batch_size=32, warmup=8, epsilon=0.2)
    env_fn, agent, mk_replay = _setup(cfg)
    ex = ShardedExecutor(agent, mk_replay(("pod", "data")), env_fn, cfg,
                         n_envs=4, mesh=pod_data_mesh(1, 1), scan_chunk=4,
                         compress_pod_reduce=True)
    state, hist = ex.train(24, jax.random.PRNGKey(3))
    assert np.isfinite(np.asarray(hist["loss"])).all()
    ef_leaves = jax.tree.leaves(state.ef_error)
    assert ef_leaves, "EF buffer must be materialized when compressing"
    # the quantizer rarely round-trips exactly: after 20+ learns the
    # carried error is non-zero somewhere
    assert any(float(jnp.max(jnp.abs(x))) > 0 for x in ef_leaves)
    # uncompressed runs keep the empty pytree (no memory overhead)
    ex0 = ShardedExecutor(agent, mk_replay(("pod", "data")), env_fn, cfg,
                          n_envs=4, mesh=pod_data_mesh(1, 1), scan_chunk=4)
    assert jax.tree.leaves(ex0.init(jax.random.PRNGKey(0)).ef_error) == []


def test_compress_pod_reduce_validation():
    cfg = LoopConfig(batch_size=32)
    env_fn, agent, mk_replay = _setup(cfg)
    with pytest.raises(ValueError, match="axis_names"):
        # a 1-axis replay config on a 2-D mesh would silently replicate
        # every shard across the unnamed pod axis (duplicate programs)
        ShardedExecutor(agent, mk_replay(("data",)), env_fn, cfg, n_envs=4,
                        mesh=pod_data_mesh(1, 1, axes=("pod", "data")))
    with pytest.raises(ValueError, match="multi-axis"):
        ShardedExecutor(agent, mk_replay(("data",)), env_fn, cfg, n_envs=4,
                        mesh=data_mesh(1), compress_pod_reduce=True)
    with pytest.raises(ValueError, match="mesh"):
        AsyncExecutor(agent, mk_replay(("data",)).local, env_fn, cfg,
                      n_envs=4, compress_pod_reduce=True)


POD_EQUIV = textwrap.dedent("""
    import functools, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.core.distributed import (ShardedPrioritizedReplay,
                                        ShardedReplayConfig)
    from repro.envs.classic import make_vec
    from repro.launch.mesh import data_mesh, pod_data_mesh
    from repro.runtime.executors import ShardedExecutor
    from repro.runtime.loop import LoopConfig

    assert jax.device_count() == 4
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    mk = lambda axes: ShardedPrioritizedReplay(
        ShardedReplayConfig(capacity_per_shard=1024, fanout=8,
                            axis_names=axes), example)
    key = jax.random.PRNGKey(7)

    # -- 2×1 pod×data ≡ 1-D 2-shard data, same seed -----------------------
    # The flattened (pod, data) shard id equals the 1-D data shard id, so
    # rng folds, env resets, replay shards and the reduce pairing all
    # line up; the two XLA programs differ only at the reassociation-ulp
    # level, so the strict window is short (12 iters, learning from 1).
    cfg = LoopConfig(batch_size=32, warmup=8, epsilon=0.2)
    s1, h1 = ShardedExecutor(agent, mk(("data",)), env_fn, cfg, n_envs=8,
                             mesh=data_mesh(2), scan_chunk=4).train(12, key)
    s2, h2 = ShardedExecutor(agent, mk(("pod", "data")), env_fn, cfg,
                             n_envs=8, mesh=pod_data_mesh(2, 1),
                             scan_chunk=4).train(12, key)
    for k in ("env_steps", "learn_steps", "buffer_size"):
        np.testing.assert_array_equal(np.asarray(h1[k]), np.asarray(h2[k]),
                                      err_msg=k)
    np.testing.assert_allclose(np.asarray(h1["mean_episode_return"]),
                               np.asarray(h2["mean_episode_return"]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(h1["loss"]),
                               np.asarray(h2["loss"]), rtol=1e-3, atol=1e-6)
    for a, b in zip(jax.tree.leaves(s1.agent.params),
                    jax.tree.leaves(s2.agent.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    # -- long horizon at ε=1: the env trajectory cannot fork on ulp-level
    # greedy flips, so collection metrics must stay exact for 80 iters
    # while the full two-axis learn path runs every iteration
    cfg2 = LoopConfig(batch_size=32, warmup=64, epsilon=1.0,
                      epsilon_final=1.0)
    s1, h1 = ShardedExecutor(agent, mk(("data",)), env_fn, cfg2, n_envs=8,
                             mesh=data_mesh(2), scan_chunk=16).train(80, key)
    s2, h2 = ShardedExecutor(agent, mk(("pod", "data")), env_fn, cfg2,
                             n_envs=8, mesh=pod_data_mesh(2, 1),
                             scan_chunk=16).train(80, key)
    for k in ("env_steps", "learn_steps", "buffer_size"):
        np.testing.assert_array_equal(np.asarray(h1[k]), np.asarray(h2[k]),
                                      err_msg=k)
    np.testing.assert_allclose(np.asarray(h1["mean_episode_return"]),
                               np.asarray(h2["mean_episode_return"]),
                               rtol=1e-6)
    # PER cumsum tie-flips over ~600 learns drift a few weights by ~1e-1;
    # wiring bugs (wrong axis, dropped pod) move params by O(1)
    for a, b in zip(jax.tree.leaves(s1.agent.params),
                    jax.tree.leaves(s2.agent.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.5)

    # -- 2×2: compressed ≡ uncompressed within EF tolerance ---------------
    # After the first learns the compressed run's params track the f32 run
    # to quantization noise; once PER draws fork the runs genuinely
    # diverge, so the window is short and the bound is the EF tolerance,
    # not ulps.
    cfg3 = LoopConfig(batch_size=32, warmup=8, epsilon=0.2)
    su, hu = ShardedExecutor(agent, mk(("pod", "data")), env_fn, cfg3,
                             n_envs=8, mesh=pod_data_mesh(2, 2),
                             scan_chunk=4).train(12, key)
    sc, hc = ShardedExecutor(agent, mk(("pod", "data")), env_fn, cfg3,
                             n_envs=8, mesh=pod_data_mesh(2, 2), scan_chunk=4,
                             compress_pod_reduce=True).train(12, key)
    for k in ("env_steps", "learn_steps", "buffer_size"):
        np.testing.assert_array_equal(np.asarray(hu[k]), np.asarray(hc[k]),
                                      err_msg=k)
    assert np.isfinite(np.asarray(hc["loss"])).all()
    for a, b in zip(jax.tree.leaves(su.agent.params),
                    jax.tree.leaves(sc.agent.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.1)
    # the global EF buffer carries one copy per mesh cell (leading axis 4)
    ef = jax.tree.leaves(sc.ef_error)[0]
    assert np.asarray(ef).shape[0] == 4
    print("POD_EQUIV_OK")
""")


POD_E2E = textwrap.dedent("""
    import functools, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.core.distributed import (ShardedPrioritizedReplay,
                                        ShardedReplayConfig)
    from repro.envs.classic import make_vec
    from repro.launch.mesh import pod_data_mesh
    from repro.optim import compress
    from repro.runtime.executors import ShardedExecutor
    from repro.runtime.loop import LoopConfig

    assert jax.device_count() == 4
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    replay = ShardedPrioritizedReplay(
        ShardedReplayConfig(capacity_per_shard=2048, fanout=8,
                            axis_names=("pod", "data")), example)
    cfg = LoopConfig(batch_size=64, warmup=128, epsilon=0.2,
                     update_interval=8)
    ex = ShardedExecutor(agent, replay, env_fn, cfg, n_envs=8,
                         mesh=pod_data_mesh(2, 2), scan_chunk=16,
                         compress_pod_reduce=True)
    assert ex.n_shards == 4 and ex.n_envs_local == 2
    state, hist = ex.train(192, jax.random.PRNGKey(0))

    # trained through the compressed two-axis path: scheduled ratio
    # honored, every mesh cell's buffer filled, finite numerics, and the
    # policy collects reward
    env_steps = int(hist["env_steps"][-1])
    learn_steps = int(hist["learn_steps"][-1])
    assert env_steps == 192 * 8
    assert learn_steps > 0
    realized = (env_steps - 128) / learn_steps
    assert abs(realized - 8.0) <= 1.0, realized
    assert int(hist["buffer_size"][-1]) == 192 * 8
    assert np.isfinite(np.asarray(hist["loss"])).all()
    assert all(np.isfinite(np.asarray(p)).all()
               for p in jax.tree.leaves(state.agent.params))
    assert float(hist["mean_episode_return"][-1]) > 0.0

    # cross-pod payload: the int8 wire format of exactly the pytree the
    # reduce ships (the gradient/param-shaped EF-compressed leaves) is
    # ≥ 3.9× smaller than the f32 payload of the uncompressed reduce
    grads_shaped = state.agent.params
    comp, _ = compress.compress(grads_shaped,
                                compress.init_error(grads_shaped))
    for leaf in jax.tree.leaves(
            comp, is_leaf=lambda x: isinstance(x, compress.CompressedLeaf)):
        assert leaf.q.dtype == jnp.int8
    wire = compress.payload_bytes(comp)
    raw = compress.raw_bytes(grads_shaped)
    assert wire * 3.9 < raw, (wire, raw)
    print("POD_E2E_OK")
""")


def _run_sub(script):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=600,
                          env=env, cwd=root)


@pytest.mark.slow
def test_pod_data_equivalences_multidevice():
    """2×1 pod×data ≡ 1-D 2-shard data from the same seed, and the 2×2
    compressed run tracks the uncompressed one within EF tolerance (4
    forced host devices)."""
    r = _run_sub(POD_EQUIV)
    assert "POD_EQUIV_OK" in r.stdout, r.stdout[-800:] + r.stderr[-2000:]


@pytest.mark.slow
def test_pod_data_compressed_e2e_multidevice():
    """End-to-end DQN/CartPole through the 2×2 pod×data executor with the
    int8-EF cross-pod reduce on 4 forced host devices, asserting the 4×
    cross-pod payload shrink."""
    r = _run_sub(POD_E2E)
    assert "POD_E2E_OK" in r.stdout, r.stdout[-800:] + r.stderr[-2000:]
