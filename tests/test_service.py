"""Replay-as-a-service (DESIGN.md §11): rate-limiter flow control,
router addressing, the in-process ServiceExecutor's bit-exact
equivalence with the fused loop, and the TCP server/client wire path."""

import functools
import pickle
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agents.dqn import DQNConfig, make_dqn
from repro.core.replay import PrioritizedReplay, ReplayConfig
from repro.envs.classic import make_vec
from repro.runtime.executors import FusedExecutor
from repro.runtime.loop import LoopConfig, RatioSchedule
from repro.service import (RateLimiter, ReplayClient, ReplayService,
                           ReplayServiceConfig, Router, ServiceExecutor,
                           ServiceStopped, serve)

EXAMPLE = {
    "obs": jnp.zeros((4,), jnp.float32),
    "action": jnp.zeros((), jnp.int32),
    "reward": jnp.zeros(()),
    "next_obs": jnp.zeros((4,), jnp.float32),
    "done": jnp.zeros(()),
}


def items(n, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "obs": rng.normal(size=(n, 4)).astype(np.float32),
        "action": rng.integers(0, 2, n).astype(np.int32),
        "reward": rng.uniform(0, 1, n).astype(np.float32),
        "next_obs": rng.normal(size=(n, 4)).astype(np.float32),
        "done": np.zeros(n, np.float32),
    }


def transition_example(spec):
    return {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }


def params_checksum(agent_state) -> float:
    total = 0.0
    for leaf in jax.tree.leaves(jax.device_get(agent_state.params)):
        total += float(np.abs(np.asarray(leaf, np.float64)).sum())
    return total


# -- rate limiter ------------------------------------------------------------


def test_rate_limiter_band():
    lim = RateLimiter(samples_per_insert=2.0, min_size_to_sample=10,
                      error_buffer=4.0)
    # below min size: inserts fine, samples blocked
    assert lim.can_insert(10) and not lim.can_sample(1)
    lim.note_insert(10)
    # at min size, debt 0: sample of up to error_buffer admitted
    assert lim.can_sample(4) and not lim.can_sample(5)
    lim.note_sample(4)          # debt -4 — sampler at the band edge
    assert not lim.can_sample(1)
    # writer credit: 1 insert adds spi=2 credit
    lim.note_insert(1)
    assert lim.can_sample(2) and not lim.can_sample(3)
    # writer backpressure: debt -2, insert of b adds 2b credit;
    # 3 ≤ (4+2)/2 admitted, 4 is not
    assert lim.can_insert(3) and not lim.can_insert(4)


def test_rate_limiter_blocking_and_stop():
    lim = RateLimiter(samples_per_insert=1.0, min_size_to_sample=1,
                      error_buffer=1.0)
    got = []

    def sampler():
        try:
            lim.await_sample(1, timeout=10.0)
            got.append("sampled")
        except ServiceStopped:
            got.append("stopped")

    t = threading.Thread(target=sampler)
    t.start()
    time.sleep(0.05)
    assert got == []            # parked: no inserts yet
    lim.note_insert(2)
    t.join(timeout=5.0)
    assert got == ["sampled"]

    # writers parked in backpressure must wake on stop()
    t2 = threading.Thread(target=lambda: got.append(
        "insert-stopped" if _raises_stopped(lim) else "insert-ok"))
    t2.start()
    time.sleep(0.05)
    lim.stop()
    t2.join(timeout=5.0)
    assert got[-1] == "insert-stopped"


def _raises_stopped(lim):
    try:
        lim.await_insert(10_000, timeout=10.0)
        return False
    except ServiceStopped:
        return True


def test_rate_limiter_timeout():
    lim = RateLimiter(samples_per_insert=1.0, min_size_to_sample=1,
                      error_buffer=1.0)
    with pytest.raises(TimeoutError, match="not admitted"):
        lim.await_sample(1, timeout=0.05)


def test_rate_limiter_validation():
    with pytest.raises(ValueError, match="samples_per_insert"):
        RateLimiter(0.0, 1, 1.0)
    with pytest.raises(ValueError, match="min_size_to_sample"):
        RateLimiter(1.0, 0, 1.0)
    with pytest.raises(ValueError, match="deadlock"):
        RateLimiter(4.0, 1, 1.0)


def test_from_schedule_reproduces_ratio_cadence():
    """The tight-band limiter admits exactly the RatioSchedule cadence
    under a greedy drain — flow control generalizes the schedule."""
    for cfg, n_envs in [(LoopConfig(batch_size=64, update_interval=1,
                                    warmup=400), 8),
                        (LoopConfig(batch_size=64, update_interval=16,
                                    warmup=384), 8)]:
        sched = RatioSchedule.from_config(cfg, n_envs)
        lim = RateLimiter.from_schedule(sched, cfg.batch_size, cfg.warmup)
        learns_per_window = []
        for w in range(120):
            n = 0
            while lim.can_sample(cfg.batch_size):
                lim.note_sample(cfg.batch_size)
                n += 1
            learns_per_window.append(n)
            lim.note_insert(n_envs)
        expect = [sched.learns
                  if (8 * w >= cfg.warmup and w % sched.period == 0) else 0
                  for w in range(120)]
        assert learns_per_window == expect


# -- router ------------------------------------------------------------------


def test_router_policies():
    r = Router(4, "hash")
    # stable per writer, spread across shards for distinct writers
    assert all(r.route("actor-3") == r.route("actor-3") for _ in range(5))
    assert len({r.route(f"actor-{i}") for i in range(64)}) == 4
    rr = Router(3, "round_robin")
    assert [rr.route("x") for x in range(6)] == [0, 1, 2, 0, 1, 2]
    with pytest.raises(ValueError, match="unknown router policy"):
        Router(2, "modulo")
    with pytest.raises(ValueError, match="n_shards"):
        Router(0)


# -- service core ------------------------------------------------------------


def test_service_append_sample_update_roundtrip():
    svc = ReplayService(ReplayServiceConfig(capacity_per_shard=128,
                                            n_shards=2, fanout=8), EXAMPLE)
    expect = [0, 0]
    for i in range(4):
        out = svc.append(f"w{i}", items(32, seed=i))
        assert not out["stopped"] and out["inserts"] == 32 * (i + 1)
        expect[Router(2, "hash").route(f"w{i}")] += 32
    st = svc.stats()
    assert st["inserts"] == 128 and st["per_shard_count"] == expect
    out = svc.sample(batch=32, beta=0.4)
    assert out["items"]["obs"].shape == (32, 4)
    assert out["weights"].shape == (32,) and out["weights"].max() <= 1 + 1e-6
    assert svc.update_priorities(out["sample_id"],
                                 np.ones(32, np.float32))["applied"]
    # a second write-back on the same handle is stale, not an error
    assert not svc.update_priorities(out["sample_id"],
                                     np.ones(32, np.float32))["applied"]


def test_service_sample_batch_must_divide_shards():
    svc = ReplayService(ReplayServiceConfig(capacity_per_shard=64,
                                            n_shards=3, fanout=8), EXAMPLE)
    svc.append("w", items(48))
    with pytest.raises(ValueError, match="divide evenly"):
        svc.sample(batch=32)


def test_service_lazy_appends_flush_once_per_window():
    """Appends are leaf-only (pending ledger grows); the sample boundary
    runs ONE propagation pass and the flushed tree is bit-exact with the
    eager per-op path (the per-shard lazy ≡ eager contract through the
    service API)."""
    svc = ReplayService(ReplayServiceConfig(capacity_per_shard=256,
                                            n_shards=1, fanout=8,
                                            seed=7), EXAMPLE)
    eager = PrioritizedReplay(ReplayConfig(capacity=256, fanout=8), EXAMPLE)
    est = eager.init()
    for i in range(3):
        batch = items(64, seed=i)
        svc.append("w", batch)
        est = eager.insert(est, batch)      # eager: propagate per op
    assert int(svc.states[0].pending) > 0   # ledger carries 3 appends
    svc.sample(batch=64)                    # the admission window boundary
    assert int(svc.states[0].pending) == 0
    np.testing.assert_array_equal(np.asarray(svc.states[0].tree),
                                  np.asarray(est.tree))


def test_service_param_channel():
    svc = ReplayService(ReplayServiceConfig(capacity_per_shard=64), EXAMPLE)
    assert svc.params_version() == 0
    with pytest.raises(TimeoutError):
        svc.get_params(min_version=1, timeout=0.05)
    v = svc.put_params(pickle.dumps({"w": np.ones(3)}))
    assert v == 1
    out = svc.get_params(min_version=1, timeout=1.0)
    assert out["version"] == 1
    np.testing.assert_array_equal(pickle.loads(out["blob"])["w"], np.ones(3))


def test_service_rate_limited_ratio():
    """2 writer threads + 1 sampler thread against a live service: the
    realized samples-per-insert ratio lands inside the limiter band."""
    lim = RateLimiter(samples_per_insert=0.5, min_size_to_sample=64,
                      error_buffer=64.0)
    svc = ReplayService(ReplayServiceConfig(capacity_per_shard=512,
                                            n_shards=2, fanout=8),
                        EXAMPLE, rate_limiter=lim)
    stop_at = 2048   # inserts target

    def writer(wid):
        i = 0
        while not svc.stopped and svc.total_inserts() < stop_at:
            try:
                svc.append(f"writer-{wid}", items(32, seed=i), timeout=10.0)
            except ServiceStopped:
                return
            i += 1

    def sampler():
        while not svc.stopped:
            out = svc.sample(batch=32, beta=0.4, timeout=10.0)
            if out.get("stopped"):
                return
            svc.update_priorities(out["sample_id"],
                                  np.ones(32, np.float32))

    threads = [threading.Thread(target=writer, args=(w,)) for w in (0, 1)]
    threads.append(threading.Thread(target=sampler))
    for t in threads:
        t.start()
    for t in threads[:2]:
        t.join(timeout=60.0)
    svc.stop()
    threads[2].join(timeout=60.0)
    assert not any(t.is_alive() for t in threads)
    st = lim.stats()
    assert st["inserts"] >= stop_at
    # band: |realized − configured| ≤ error_buffer / (inserts − min_size)
    slack = lim.error_buffer / (st["inserts"] - lim.min_size_to_sample)
    assert abs(st["realized_spi"] - 0.5) <= slack + 1e-6


def test_service_stop_wakes_parked_writers_in_process():
    """Writers parked in rate-limiter backpressure must wake on stop()
    with a stopped (not applied) reply — not hang until timeout."""
    lim = RateLimiter(samples_per_insert=1.0, min_size_to_sample=1,
                      error_buffer=1.0)
    svc = ReplayService(ReplayServiceConfig(capacity_per_shard=256,
                                            fanout=8), EXAMPLE,
                        rate_limiter=lim)
    replies = []

    def writer(wid):
        replies.append(svc.append(f"w{wid}", items(64, seed=wid),
                                  timeout=30.0))

    threads = [threading.Thread(target=writer, args=(w,)) for w in range(2)]
    for t in threads:
        t.start()
    time.sleep(0.2)
    assert replies == []            # both parked: 64 ≫ the limiter band
    svc.stop()
    for t in threads:
        t.join(timeout=10.0)
    assert not any(t.is_alive() for t in threads)
    assert len(replies) == 2
    assert all(r["stopped"] and "applied" not in r for r in replies)


def test_service_stop_wakes_parked_writers_tcp():
    """The same wake-on-stop contract through the wire: appends parked
    server-side return a stopped reply to their TCP clients."""
    lim = RateLimiter(samples_per_insert=1.0, min_size_to_sample=1,
                      error_buffer=1.0)
    svc = ReplayService(ReplayServiceConfig(capacity_per_shard=256,
                                            fanout=8), EXAMPLE,
                        rate_limiter=lim)
    server, port = serve(svc)
    replies = []

    def writer(wid):
        c = ReplayClient("127.0.0.1", port)
        try:
            replies.append(c.append(f"w{wid}", items(64, seed=wid),
                                    timeout=30.0))
        finally:
            c.close()

    try:
        threads = [threading.Thread(target=writer, args=(w,))
                   for w in range(2)]
        for t in threads:
            t.start()
        time.sleep(0.3)
        assert replies == []
        ctl = ReplayClient("127.0.0.1", port)
        ctl.stop()
        ctl.close()
        for t in threads:
            t.join(timeout=10.0)
        assert not any(t.is_alive() for t in threads)
        assert len(replies) == 2
        assert all(r["stopped"] and not r.get("applied") for r in replies)
    finally:
        server.shutdown()


# -- wire path ---------------------------------------------------------------


def test_tcp_server_client_roundtrip():
    svc = ReplayService(ReplayServiceConfig(capacity_per_shard=256,
                                            n_shards=2, fanout=8), EXAMPLE)
    server, port = serve(svc)
    try:
        c = ReplayClient("127.0.0.1", port)
        assert c.ping()
        out = c.append("actor-0", items(64))
        assert out["inserts"] == 64
        c.put_params({"w": np.arange(4.0)})
        got = c.get_params(min_version=1, timeout=5.0)
        assert got["version"] == 1
        np.testing.assert_array_equal(got["params"]["w"], np.arange(4.0))
        s = c.sample(batch=32)
        assert s["items"]["obs"].shape == (32, 4)
        assert c.update_priorities(s["sample_id"], np.ones(32, np.float32))
        # errors cross the wire as exceptions, not dead connections
        with pytest.raises(RuntimeError, match="divide evenly"):
            c.sample(batch=31)
        assert c.stats()["inserts"] == 64
        c.stop()
        assert svc.stopped
        c.close()
    finally:
        server.shutdown()


# -- in-process service executor ---------------------------------------------


def _dqn_setup(n_envs=8):
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    return env_fn, spec, agent


def test_service_executor_bit_exact_vs_fused():
    """The acceptance contract: a 1-shard in-process service at the
    loop-derived 1:1 rate limit is bit-exact with FusedExecutor — same
    seed, identical params checksum and trajectory metrics."""
    env_fn, spec, agent = _dqn_setup()
    cfg = LoopConfig(batch_size=32, warmup=64, epsilon=0.3,
                     update_interval=1, epsilon_decay_steps=500)
    key = jax.random.PRNGKey(3)
    iters = 40

    replay = PrioritizedReplay(ReplayConfig(capacity=1024, fanout=8),
                               transition_example(spec))
    fused = FusedExecutor(agent, replay, env_fn, cfg, n_envs=8,
                          scan_chunk=16)
    f_state, f_hist = fused.train(iters, key)

    svc = ReplayService(ReplayServiceConfig(capacity_per_shard=1024,
                                            n_shards=1, fanout=8),
                        transition_example(spec))
    ex = ServiceExecutor(agent, svc, env_fn, cfg, n_envs=8, scan_chunk=16)
    s_state, s_hist = ex.train(iters, key)

    assert params_checksum(s_state.agent) == params_checksum(f_state.agent)
    assert int(s_state.learn_steps) == int(f_state.learn_steps) > 0
    np.testing.assert_array_equal(np.asarray(s_state.obs),
                                  np.asarray(f_state.obs))
    np.testing.assert_array_equal(np.asarray(s_hist["loss"]),
                                  np.asarray(f_hist["loss"]))
    # the limiter realized exactly the loop's samples-per-insert ratio
    realized = ex.realized_samples_per_insert()
    assert realized == pytest.approx(cfg.batch_size / cfg.update_interval
                                     / 8 * 8, rel=0.05)


def test_service_executor_multi_shard_trains():
    """2-shard service: windows route round-robin across shards, learner
    samples stratified with globally-normalized weights — training runs
    and both shards fill."""
    env_fn, spec, agent = _dqn_setup()
    cfg = LoopConfig(batch_size=32, warmup=64, epsilon=0.3,
                     update_interval=2, epsilon_decay_steps=500)
    svc = ReplayService(ReplayServiceConfig(capacity_per_shard=512,
                                            n_shards=2, fanout=8,
                                            router="round_robin"),
                        transition_example(spec))
    ex = ServiceExecutor(agent, svc, env_fn, cfg, n_envs=8, scan_chunk=16)
    state, hist = ex.train(48, jax.random.PRNGKey(0))
    assert int(state.learn_steps) > 0
    assert np.isfinite(np.asarray(hist["loss"])).all()
    counts = [int(s.count) for s in state.replay]
    assert len(counts) == 2 and min(counts) > 0
    assert abs(counts[0] - counts[1]) <= 8   # round-robin balance
