"""Wall-clock launcher (launch/multiprocess.py): coordinator handshake
failure surfaces as a clear error (never a hang), and the degenerate
single-process launch is bit-exact against the in-process FusedExecutor
— the distributed runtime at N=1 must be a no-op.

These tests spawn real OS processes (each imports jax); they are the
slowest tier-1 tests by design — the wallclock-smoke CI job runs them
against the real gloo transport.
"""

import functools
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import pytest

from repro.launch import multiprocess as mp


def test_parse_kv_takes_upper_snake_lines_later_wins():
    text = ("garbage\nSTEPS_PER_S=12.5\nnoise a=b\nlower=skipped\n"
            "STEPS_PER_S=13.0\nREL_SPREAD=0.01\n")
    kv = mp.parse_kv(text)
    assert kv == {"STEPS_PER_S": "13.0", "REL_SPREAD": "0.01"}


def test_launch_rejects_empty_gang():
    with pytest.raises(ValueError, match="n_procs"):
        mp.launch(["--mode", "fused"], n_procs=0)


def test_handshake_timeout_raises_clear_error_not_hang():
    """A worker whose coordinator never comes up (process 0 missing from
    the gang) must exit with the initialize_distributed RuntimeError
    naming the coordinator — within the handshake timeout, not a
    collective-deadline hang."""
    port = mp.free_port()   # bound by nobody: the handshake cannot succeed
    cmd = [sys.executable, "-m", "repro.launch.multiprocess",
           "--coordinator", f"127.0.0.1:{port}",
           "--n-procs", "2", "--process-id", "1",
           "--handshake-timeout", "8",
           "--mode", "fused", "--iters", "1"]
    t0 = time.monotonic()
    res = subprocess.run(cmd, env=mp.worker_env(1), capture_output=True,
                         text=True, timeout=180)
    elapsed = time.monotonic() - t0
    assert res.returncode != 0
    out = res.stdout + res.stderr
    assert "coordinator handshake failed" in out, out[-2000:]
    assert f"127.0.0.1:{port}" in out
    # timeout (8s) + interpreter/jax startup, nowhere near the 180s hang
    assert elapsed < 120, elapsed


def test_launch_surfaces_worker_failure_with_output_tail():
    """Parent-side contract: a worker that exits non-zero after the
    handshake (here: --mode fused on a 2-process gang, which the worker
    rejects) turns into a RuntimeError carrying the worker's output tail
    — and the rest of the gang is killed rather than left wedged at the
    next collective."""
    with pytest.raises(RuntimeError, match="wall-clock worker"):
        mp.launch(["--mode", "fused", "--iters", "1"], n_procs=2,
                  timeout_s=300.0)


def test_single_process_launch_bit_exact_vs_in_process_fused():
    """The degenerate launch: one worker through the full coordinator
    handshake runs the exact FusedExecutor program — final loss, env
    steps and a parameter checksum must match the same executor driven
    in-process, bit for bit."""
    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.core.replay import PrioritizedReplay, ReplayConfig
    from repro.envs.classic import make_vec
    from repro.runtime.executors import FusedExecutor
    from repro.runtime.loop import LoopConfig

    iters, n_envs, scan_chunk, seed = 30, 8, 10, 0
    out = mp.launch(["--mode", "fused",
                     "--iters", str(iters),
                     "--n-envs", str(n_envs),
                     "--scan-chunk", str(scan_chunk),
                     "--seed", str(seed)],
                    n_procs=1, timeout_s=600.0)
    kv = mp.parse_kv(out[0])

    # in-process reference: mirrors multiprocess._build_executor exactly
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    cfg = LoopConfig(batch_size=64, warmup=64, epsilon=0.1)
    replay = PrioritizedReplay(
        ReplayConfig(capacity=50_000, fanout=128), example)
    ex = FusedExecutor(agent, replay, env_fn, cfg, n_envs,
                       scan_chunk=scan_chunk)
    state, hist = ex.train(iters, jax.random.PRNGKey(seed))
    params = jax.device_get(state.agent.params)
    checksum = 0.0
    for leaf in jax.tree.leaves(params):
        checksum += float(abs(leaf.astype("float64")).sum())

    assert float(kv["FINAL_LOSS"]) == float(hist["loss"][-1])
    assert float(kv["FINAL_RETURN"]) == float(
        hist["mean_episode_return"][-1])
    assert int(kv["ENV_STEPS"]) == int(hist["env_steps"][-1])
    assert float(kv["PARAMS_CHECKSUM"]) == checksum
