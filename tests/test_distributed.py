"""Distributed pieces that run on host: compressed EF-psum numerics, DSE
solver, staleness weights, sharded-replay stratified weights math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import compress
from repro.runtime import dse
from repro.runtime.learner import staleness_weights


def test_int8_ef_compression_contracts():
    """Error feedback: repeated compression of the same gradient stream
    converges — accumulated error stays bounded, mean dequantized value
    tracks the true mean (EF-SGD property)."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32) * 1e-2)}
    err = compress.init_error(g)
    total_true = jnp.zeros((64, 64))
    total_deq = jnp.zeros((64, 64))
    for i in range(50):
        gi = jax.tree.map(lambda x: x * (1 + 0.01 * i), g)
        comp, err = compress.compress(gi, err)
        deq = compress.decompress(comp)
        total_true = total_true + gi["w"]
        total_deq = total_deq + deq["w"]
        assert comp["w"].q.dtype == jnp.int8
    # with error feedback, cumulative dequantized ≈ cumulative true
    rel = float(jnp.linalg.norm(total_deq - total_true) /
                jnp.linalg.norm(total_true))
    assert rel < 2e-3, rel
    # without EF the same stream drifts measurably more
    err0 = compress.init_error(g)
    tot_no_ef = jnp.zeros((64, 64))
    for i in range(50):
        gi = jax.tree.map(lambda x: x * (1 + 0.01 * i), g)
        comp, _ = compress.compress(gi, compress.init_error(g))
        tot_no_ef = tot_no_ef + compress.decompress(comp)["w"]
    rel_no_ef = float(jnp.linalg.norm(tot_no_ef - total_true) /
                      jnp.linalg.norm(total_true))
    assert rel < rel_no_ef


def test_compression_ratio():
    g = {"w": jnp.zeros((1024,), jnp.float32)}
    comp, _ = compress.compress(g, compress.init_error(g))
    wire = comp["w"].q.size * 1 + 4
    assert wire < 1024 * 4 / 3.9   # ≥ 3.9× smaller than f32


def test_dse_solver_matches_ratio():
    # linear actor scaling, sub-linear learner scaling (paper Fig. 12 shape)
    actor = {x: 100.0 * x for x in range(1, 9)}
    learner = {x: 300.0 * x ** 0.8 for x in range(1, 9)}
    res = dse.solve(actor, learner, total=8, update_interval=1.0)
    assert res.x_actor + res.x_learner <= 8
    # realized ratio close to the target
    assert abs(res.ratio - 1.0) < 0.35
    # a deliberately unbalanced target shifts allocation toward actors
    res4 = dse.solve(actor, learner, total=8, update_interval=4.0)
    assert res4.x_actor > res.x_actor or res4.ratio > res.ratio


def test_dse_solver_rejects_infeasible_budget():
    """Regression: total < 2 used to crash with TypeError ('NoneType' is
    not subscriptable) because the search space is empty and ``best``
    stays None — now a clear ValueError."""
    actor = {1: 100.0}
    learner = {1: 300.0}
    for total in (0, 1, -3):
        with pytest.raises(ValueError, match="total"):
            dse.solve(actor, learner, total=total)
    with pytest.raises(ValueError, match="curve"):
        dse.solve({}, learner, total=4)
    with pytest.raises(ValueError, match="curve"):
        dse.solve(actor, {}, total=4)


def test_staleness_weights_drop_stragglers():
    ages = jnp.asarray([0, 1, 3, 10])
    w = staleness_weights(ages, max_staleness=4)
    assert w[0] == 1.0 and w[1] == 0.5
    assert w[3] == 0.0          # dropped straggler


def test_sharded_replay_global_weights_math():
    """Stratified IS weights against the global distribution (DESIGN.md §2):
    simulate two shards in numpy and check unbiasedness of the weighted
    estimator vs the single-buffer PER estimator."""
    rng = np.random.default_rng(0)
    p1 = rng.uniform(0.1, 1, 128)
    p2 = rng.uniform(0.1, 1, 128)
    values = rng.normal(size=256)            # f(i) to estimate E_uniform[f]
    g_total, g_count = p1.sum() + p2.sum(), 256
    beta = 1.0                                # full correction → unbiased
    draws = 20_000
    est = []
    for p, vals in ((p1, values[:128]), (p2, values[128:])):
        prob_local = p / p.sum()
        idx = rng.choice(128, size=draws, p=prob_local)
        w = (g_count * (p[idx] / g_total)) ** (-beta)
        est.append((vals[idx] * w).mean() * (p.sum() / g_total) * 2)
    approx = 0.5 * (est[0] + est[1])
    # the PER-weighted mean recovers the uniform mean
    assert abs(approx - values.mean()) < 0.05
