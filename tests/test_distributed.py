"""Distributed pieces that run on host: compressed EF-psum numerics, DSE
solver, staleness weights, sharded-replay stratified weights math, the
fused one-launch tree collective, the double-buffered (overlapped)
cross-pod reduce — plus a real 2-process gloo gang equivalence check."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import compress
from repro.optim.collectives import fused_tree_reduce
from repro.runtime import dse
from repro.runtime.learner import make_grad_reducer, staleness_weights


def test_int8_ef_compression_contracts():
    """Error feedback: repeated compression of the same gradient stream
    converges — accumulated error stays bounded, mean dequantized value
    tracks the true mean (EF-SGD property)."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32) * 1e-2)}
    err = compress.init_error(g)
    total_true = jnp.zeros((64, 64))
    total_deq = jnp.zeros((64, 64))
    for i in range(50):
        gi = jax.tree.map(lambda x: x * (1 + 0.01 * i), g)
        comp, err = compress.compress(gi, err)
        deq = compress.decompress(comp)
        total_true = total_true + gi["w"]
        total_deq = total_deq + deq["w"]
        assert comp["w"].q.dtype == jnp.int8
    # with error feedback, cumulative dequantized ≈ cumulative true
    rel = float(jnp.linalg.norm(total_deq - total_true) /
                jnp.linalg.norm(total_true))
    assert rel < 2e-3, rel
    # without EF the same stream drifts measurably more
    tot_no_ef = jnp.zeros((64, 64))
    for i in range(50):
        gi = jax.tree.map(lambda x: x * (1 + 0.01 * i), g)
        comp, _ = compress.compress(gi, compress.init_error(g))
        tot_no_ef = tot_no_ef + compress.decompress(comp)["w"]
    rel_no_ef = float(jnp.linalg.norm(tot_no_ef - total_true) /
                      jnp.linalg.norm(total_true))
    assert rel < rel_no_ef


def test_compression_ratio():
    g = {"w": jnp.zeros((1024,), jnp.float32)}
    comp, _ = compress.compress(g, compress.init_error(g))
    wire = comp["w"].q.size * 1 + 4
    assert wire < 1024 * 4 / 3.9   # ≥ 3.9× smaller than f32


def test_compressed_pmean_scale_parity_vs_uncompressed():
    """Regression: the cross-pod reduce computes a *mean* of dequantized
    values, but its old ``compressed_psum`` name/docstring promised a
    psum — at 2 pods any caller trusting the documented sum semantics
    got half the gradient scale.  ``compressed_pmean`` must track the
    uncompressed ``jax.lax.pmean`` within quantization tolerance — and
    in particular must NOT be off by the pod-count factor.  (vmap with an
    axis name runs the real collective without needing devices.)"""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(2, 32, 32)).astype(np.float32) * 1e-2)
    err0 = jnp.zeros_like(g)

    def reduce_one(gp, ep):
        red, new_err = compress.compressed_pmean({"w": gp}, {"w": ep}, "pod")
        return red["w"], new_err["w"]

    reduced, _ = jax.vmap(reduce_one, axis_name="pod")(g, err0)
    target = jnp.mean(g, axis=0)
    # replicated output on both pods, equal to the f32 pmean within the
    # int8 quantization step (scale = max|g|/127 per pod)
    np.testing.assert_allclose(np.asarray(reduced[0]), np.asarray(reduced[1]))
    tol = 2 * float(jnp.max(jnp.abs(g))) / 127.0
    np.testing.assert_allclose(np.asarray(reduced[0]), np.asarray(target),
                               atol=tol)
    # the old documented-psum semantics would be 2× this mean: rule the
    # scale mismatch out explicitly
    scale = float(jnp.vdot(reduced[0], target) / jnp.vdot(target, target))
    assert abs(scale - 1.0) < 0.05, scale


def test_compressed_pmean_ef_contraction_through_reduce():
    """Error feedback through the *actual* collective: summing the
    compressed_pmean outputs over a gradient stream tracks the summed
    true pmean (EF-SGD contraction), far better than compressing without
    the carried error."""
    rng = np.random.default_rng(1)
    base = jnp.asarray(rng.normal(size=(2, 16, 16)).astype(np.float32) * 1e-2)

    def reduce_one(gp, ep):
        red, new_err = compress.compressed_pmean({"w": gp}, {"w": ep}, "pod")
        return red["w"], new_err["w"]

    vreduce = jax.vmap(reduce_one, axis_name="pod")
    err = jnp.zeros_like(base)
    tot_deq = jnp.zeros((16, 16))
    tot_true = jnp.zeros((16, 16))
    tot_no_ef = jnp.zeros((16, 16))
    for i in range(50):
        gi = base * (1 + 0.02 * i)
        reduced, err = vreduce(gi, err)
        tot_deq = tot_deq + reduced[0]
        tot_true = tot_true + jnp.mean(gi, axis=0)
        r0, _ = vreduce(gi, jnp.zeros_like(base))
        tot_no_ef = tot_no_ef + r0[0]
    rel = float(jnp.linalg.norm(tot_deq - tot_true) /
                jnp.linalg.norm(tot_true))
    rel_no_ef = float(jnp.linalg.norm(tot_no_ef - tot_true) /
                      jnp.linalg.norm(tot_true))
    assert rel < 2e-3, rel
    assert rel < rel_no_ef


def test_dse_solver_matches_ratio():
    # linear actor scaling, sub-linear learner scaling (paper Fig. 12 shape)
    actor = {x: 100.0 * x for x in range(1, 9)}
    learner = {x: 300.0 * x ** 0.8 for x in range(1, 9)}
    res = dse.solve(actor, learner, total=8, update_interval=1.0)
    assert res.x_actor + res.x_learner <= 8
    # realized ratio close to the target
    assert abs(res.ratio - 1.0) < 0.35
    # a deliberately unbalanced target shifts allocation toward actors
    res4 = dse.solve(actor, learner, total=8, update_interval=4.0)
    assert res4.x_actor > res.x_actor or res4.ratio > res.ratio


def test_dse_solver_rejects_infeasible_budget():
    """Regression: total < 2 used to crash with TypeError ('NoneType' is
    not subscriptable) because the search space is empty and ``best``
    stays None — now a clear ValueError."""
    actor = {1: 100.0}
    learner = {1: 300.0}
    for total in (0, 1, -3):
        with pytest.raises(ValueError, match="total"):
            dse.solve(actor, learner, total=total)
    with pytest.raises(ValueError, match="curve"):
        dse.solve({}, learner, total=4)
    with pytest.raises(ValueError, match="curve"):
        dse.solve(actor, {}, total=4)


def test_dse_solver_stays_on_profiled_hull():
    """Regression: flat extrapolation below/above the profiled range let
    ``solve`` return lane counts that were never measured, claiming the
    nearest profiled point's throughput.  With actor throughput profiled
    only at x ∈ {2, 4}, the old solver returned x_a=1 (same claimed
    throughput as x=2, encountered first by iteration order); the search
    must stay inside each curve's hull."""
    actor = {2: 200.0, 4: 400.0}
    learner = {2: 100.0, 4: 200.0}
    res = dse.solve(actor, learner, total=20, update_interval=1.0)
    assert 2 <= res.x_actor <= 4, res
    assert 2 <= res.x_learner <= 4, res
    # the perfect ratio match inside the hull: f_a(2)=200 = f_l(4)·1? no —
    # f_a(2)=200 vs f_l(4)=200 ties err=0 with f_a(4)=400 vs … none; the
    # tie-break maximizes work, so (4, 4) would need f_l=400 (off-hull):
    # the solver must settle on the measured (2, 4) zero-error point
    assert (res.x_actor, res.x_learner) == (2, 4)
    assert res.actor_throughput == 200.0 and res.learner_throughput == 200.0
    # a budget too small to reach both hulls has no measured allocation
    with pytest.raises(ValueError, match="hull"):
        dse.solve({8: 800.0}, {8: 300.0}, total=10)


def _run_pod_data_reducer(reducer, grads, ages, ef):
    """Drive a (pod, data) grad reducer over a (P, D, ...) stack with the
    real collectives via nested vmap axis names."""
    def cell(g, age, e):
        red, e2 = reducer({"w": g}, age, {"w": e})
        return red["w"], e2["w"]
    f = jax.vmap(jax.vmap(cell, axis_name="data"), axis_name="pod")
    return f(grads, ages, ef)


def test_hierarchical_compressed_reduce_matches_pmean():
    """compress_axis='pod' over a 2×2 mesh: the hierarchical reduce (f32
    pmean over data, int8-EF mean over pod) tracks the global pmean
    within quantization tolerance, replicated across all 4 cells."""
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=(2, 2, 8, 8)).astype(np.float32) * 1e-2)
    ef = jnp.zeros_like(g)
    reducer = make_grad_reducer(("pod", "data"), compress_axis="pod")
    red, _ = _run_pod_data_reducer(reducer, g, jnp.zeros((2, 2), jnp.int32),
                                   ef)
    target = jnp.mean(g, axis=(0, 1))
    tol = 2 * float(jnp.max(jnp.abs(g))) / 127.0
    for p in range(2):
        for d in range(2):
            np.testing.assert_allclose(np.asarray(red[p, d]),
                                       np.asarray(target), atol=tol)


def test_all_stale_compressed_round_zero_update_ef_held():
    """With every shard past the staleness bound the compressed reduce
    must return an *exactly* zero gradient and hold the EF buffer —
    without the gate the quantizer folds the carried error into the zero
    partials and emits ≈ Σ_pods ef_p as a phantom gradient."""
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=(2, 2, 8, 8)).astype(np.float32))
    ef = jnp.asarray(rng.normal(size=(2, 2, 8, 8)).astype(np.float32) * 1e-3)
    reducer = make_grad_reducer(("pod", "data"), max_staleness=1,
                                compress_axis="pod")
    ages = jnp.full((2, 2), 7, jnp.int32)          # all past the bound
    red, ef2 = _run_pod_data_reducer(reducer, g, ages, ef)
    assert float(jnp.max(jnp.abs(red))) == 0.0
    np.testing.assert_array_equal(np.asarray(ef2), np.asarray(ef))
    # with one shard alive the reduce is that shard's gradient (weight 1)
    # within quantization tolerance, and the EF buffer moves again
    ages = jnp.asarray([[0, 7], [7, 7]], jnp.int32)
    red, ef3 = _run_pod_data_reducer(reducer, g, ages, ef)
    tol = 2 * float(jnp.max(jnp.abs(g) + jnp.abs(ef))) / 127.0
    np.testing.assert_allclose(np.asarray(red[0, 0]), np.asarray(g[0, 0]),
                               atol=tol)
    assert not np.array_equal(np.asarray(ef3), np.asarray(ef))


def test_bf16_intra_pod_reduce_tracks_f32_pmean():
    """intra_pod_dtype='bf16' halves the fast-axis wire payload; the
    reduce must track the f32 pmean within bf16 mantissa tolerance and
    return f32 leaves."""
    from repro.runtime.learner import resolve_reduce_dtype

    rng = np.random.default_rng(11)
    g = jnp.asarray(rng.normal(size=(2, 2, 8, 8)).astype(np.float32) * 1e-2)
    ef = jnp.zeros_like(g)
    ages = jnp.zeros((2, 2), jnp.int32)
    reducer = make_grad_reducer(("pod", "data"), intra_pod_dtype="bf16")
    red, _ = _run_pod_data_reducer(reducer, g, ages, ef)
    assert red.dtype == jnp.float32
    target = jnp.mean(g, axis=(0, 1))
    # bf16 has ~8 mantissa bits: relative tolerance ~2^-8 per element
    tol = float(jnp.max(jnp.abs(g))) / 128.0
    for p in range(2):
        for d in range(2):
            np.testing.assert_allclose(np.asarray(red[p, d]),
                                       np.asarray(target), atol=tol)
    # composes with the compressed pod leg
    reducer2 = make_grad_reducer(("pod", "data"), compress_axis="pod",
                                 intra_pod_dtype="bf16")
    red2, _ = _run_pod_data_reducer(reducer2, g, ages, ef)
    q_tol = 2 * float(jnp.max(jnp.abs(g))) / 127.0 + tol
    np.testing.assert_allclose(np.asarray(red2[0, 0]), np.asarray(target),
                               atol=q_tol)
    with pytest.raises(ValueError, match="intra_pod_dtype"):
        resolve_reduce_dtype("fp8")


def test_bf16_intra_pod_executor_surfaces_error_norm_metric():
    """The ShardedExecutor plumb: with intra_pod_dtype='bf16' the
    compress_error_norm loop metric reports the injected cast error
    (> 0 once learning starts); with the default f32 reduce it stays
    exactly 0."""
    import functools

    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.core.distributed import (ShardedPrioritizedReplay,
                                        ShardedReplayConfig)
    from repro.envs.classic import make_vec
    from repro.launch.mesh import data_mesh
    from repro.runtime.executors import ShardedExecutor
    from repro.runtime.loop import LoopConfig

    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    cfg = LoopConfig(batch_size=32, warmup=8, epsilon=0.3)

    def train(dtype):
        replay = ShardedPrioritizedReplay(
            ShardedReplayConfig(capacity_per_shard=1024, fanout=8), example)
        ex = ShardedExecutor(agent, replay, env_fn, cfg, n_envs=4,
                             mesh=data_mesh(1), scan_chunk=8,
                             intra_pod_dtype=dtype)
        _, hist = ex.train(24, jax.random.PRNGKey(0))
        return np.asarray(hist["compress_error_norm"])

    assert train("bf16")[-1] > 0.0
    assert (train(None) == 0.0).all()


def test_grad_reducer_requires_ef_buffer_when_compressing():
    reducer = make_grad_reducer(("pod", "data"), compress_axis="pod")
    with pytest.raises(ValueError, match="error-feedback"):
        reducer({"w": jnp.zeros((4,))}, None, ())
    with pytest.raises(ValueError, match="axes"):
        make_grad_reducer(("data",), compress_axis="pod")


def test_fused_tree_reduce_bit_exact_vs_per_leaf():
    """The one-launch-per-dtype fused collective (optim/collectives.py)
    must be *bit-exact* against the per-leaf reduce it replaces:
    elementwise pmean/psum commute with concatenation."""
    rng = np.random.default_rng(7)
    tree = {
        "w": jnp.asarray(rng.normal(size=(2, 3, 5)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(2, 7)).astype(np.float32)),
        "h": jnp.asarray(rng.normal(size=(2, 4)).astype(np.float16)),
        "step": jnp.asarray(rng.integers(0, 9, size=(2,)), jnp.int32),
    }

    def fused(t):
        return fused_tree_reduce(t, ("data",), jax.lax.pmean)

    def per_leaf(t):
        return jax.tree.map(lambda x: jax.lax.pmean(x, "data"), t)

    out_f = jax.vmap(fused, axis_name="data")(tree)
    out_p = jax.vmap(per_leaf, axis_name="data")(tree)
    for k in tree:
        # dtype tracks the per-leaf form (pmean of ints promotes to float
        # in both; f16/f32 stay themselves)
        assert out_f[k].dtype == out_p[k].dtype
        np.testing.assert_array_equal(np.asarray(out_f[k]),
                                      np.asarray(out_p[k]))
    # psum form too (the staleness-weighted path)
    sum_f = jax.vmap(lambda t: fused_tree_reduce(t, ("data",), jax.lax.psum),
                     axis_name="data")(tree)
    sum_p = jax.vmap(lambda t: jax.tree.map(
        lambda x: jax.lax.psum(x, "data"), t), axis_name="data")(tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(sum_f[k]),
                                      np.asarray(sum_p[k]))


def test_fused_tree_reduce_select_passes_unselected_through():
    """``select`` (the inexact-only pmean of the parameter-average
    fallback) must leave unselected leaves untouched — int opt-state
    step counters may not cross the wire."""
    tree = {"p": jnp.ones((2, 4)), "n": jnp.arange(2, dtype=jnp.int32)}

    def reduce_inexact(t):
        return fused_tree_reduce(
            t, ("data",), jax.lax.pmean,
            select=lambda x: jnp.issubdtype(x.dtype, jnp.inexact))

    out = jax.vmap(reduce_inexact, axis_name="data")(tree)
    np.testing.assert_array_equal(np.asarray(out["p"]), np.ones((2, 4)))
    np.testing.assert_array_equal(np.asarray(out["n"]),
                                  np.arange(2, dtype=np.int32))
    # no axes / empty tree: identity
    same = fused_tree_reduce(tree, (), jax.lax.pmean)
    assert same is tree
    assert fused_tree_reduce({}, ("data",), jax.lax.pmean) == {}


def _drive_pod_reducer(reducer, stream, ef0):
    """Run a ("pod",) reducer over a list of (P, ...) gradient stacks
    with the real collective via vmap, returning per-event outputs."""
    def step(g, e):
        red, e2 = reducer({"w": g}, None, jax.tree.map(lambda x: x, e))
        return red["w"], e2
    outs = []
    ef = ef0
    for g in stream:
        out, ef = jax.vmap(step, axis_name="pod")(g, ef)
        outs.append(out)
    return outs, ef


def test_overlapped_reduce_shift_identity_on_constant_stream():
    """Double-buffered pod leg (DESIGN.md §10): on a constant gradient
    stream the overlapped reduce's event t must equal the barrier
    reduce's event t−1 *bit-exactly* — the local delta ``p_t − p_{t−1}``
    is exactly zero, so the applied update is the previous compressed
    pod mean unchanged."""
    rng = np.random.default_rng(5)
    g = jnp.asarray(rng.normal(size=(2, 8, 8)).astype(np.float32) * 1e-2)
    z = jnp.zeros_like(g)
    barrier = make_grad_reducer(("pod",), compress_axis="pod")
    overlap = make_grad_reducer(("pod",), compress_axis="pod", overlap=True)
    stream = [g] * 6
    b_outs, _ = _drive_pod_reducer(barrier, stream, {"w": z})
    o_outs, _ = _drive_pod_reducer(
        overlap, stream,
        {"ef": {"w": z}, "prev_mean": {"w": z}, "prev_partial": {"w": z}})
    for t in range(1, 6):
        np.testing.assert_array_equal(np.asarray(o_outs[t]),
                                      np.asarray(b_outs[t - 1]))


def test_overlapped_reduce_telescopes_on_varying_stream():
    """On a varying stream the cumulative overlapped−barrier difference
    telescopes to ``p_T − pm_T`` — one event's pod disagreement, never
    compounding with T."""
    rng = np.random.default_rng(6)
    T = 8
    gs = jnp.asarray(rng.normal(size=(T, 2, 8, 8)).astype(np.float32) * 1e-2)
    z = jnp.zeros_like(gs[0])
    barrier = make_grad_reducer(("pod",), compress_axis="pod")
    overlap = make_grad_reducer(("pod",), compress_axis="pod", overlap=True)
    stream = [gs[t] for t in range(T)]
    b_outs, _ = _drive_pod_reducer(barrier, stream, {"w": z})
    o_outs, _ = _drive_pod_reducer(
        overlap, stream,
        {"ef": {"w": z}, "prev_mean": {"w": z}, "prev_partial": {"w": z}})
    cum = sum(np.asarray(o) for o in o_outs) - sum(
        np.asarray(b) for b in b_outs)
    # n_data = 1 ⇒ the intra-pod partial is each pod's local gradient
    expect = np.asarray(gs[-1]) - np.asarray(b_outs[-1])
    np.testing.assert_allclose(cum, expect, atol=1e-6)


def test_overlap_requires_compress_axis_and_no_staleness():
    with pytest.raises(ValueError, match="overlap"):
        make_grad_reducer(("data",), overlap=True)
    with pytest.raises(ValueError, match="max_staleness"):
        make_grad_reducer(("pod",), compress_axis="pod", overlap=True,
                          max_staleness=2)


def test_two_process_gang_overlapped_equals_barrier():
    """The same shift/telescoping contracts over a *real* 2-process gloo
    gang (launch/multiprocess.py --mode equiv): each pod lives in its
    own OS process and the compressed reduce crosses a process
    boundary."""
    from repro.launch import multiprocess as mp

    out = mp.launch(["--mode", "equiv", "--seed", "0"], n_procs=2,
                    timeout_s=600.0)
    kv = mp.parse_kv(out[0])
    assert float(kv["SHIFT_MAX_ABS_ERR"]) == 0.0
    assert float(kv["TELESCOPE_MAX_ABS_ERR"]) < 1e-6


def test_staleness_weights_drop_stragglers():
    ages = jnp.asarray([0, 1, 3, 10])
    w = staleness_weights(ages, max_staleness=4)
    assert w[0] == 1.0 and w[1] == 0.5
    assert w[3] == 0.0          # dropped straggler


def test_sharded_replay_global_weights_math():
    """Stratified IS weights against the global distribution (DESIGN.md §2):
    simulate two shards in numpy and check unbiasedness of the weighted
    estimator vs the single-buffer PER estimator."""
    rng = np.random.default_rng(0)
    p1 = rng.uniform(0.1, 1, 128)
    p2 = rng.uniform(0.1, 1, 128)
    values = rng.normal(size=256)            # f(i) to estimate E_uniform[f]
    g_total, g_count = p1.sum() + p2.sum(), 256
    beta = 1.0                                # full correction → unbiased
    draws = 20_000
    est = []
    for p, vals in ((p1, values[:128]), (p2, values[128:])):
        prob_local = p / p.sum()
        idx = rng.choice(128, size=draws, p=prob_local)
        w = (g_count * (p[idx] / g_total)) ** (-beta)
        est.append((vals[idx] * w).mean() * (p.sum() / g_total) * 2)
    approx = 0.5 * (est[0] + est[1])
    # the PER-weighted mean recovers the uniform mean
    assert abs(approx - values.mean()) < 0.05
