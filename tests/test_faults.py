"""Fault-tolerant service fabric (DESIGN.md §14): deterministic fault
injection, reconnecting clients with idempotent appends, and restart
from shard snapshots — drilled in-process so every failure mode the
resilience layer claims to survive is exercised in seconds.

The multiprocess twin (a *hard* server crash across real OS processes)
lives in tests/test_service_gang.py; here the same wire-layer faults
run against in-process served instances:

  * retry-after-drop is **bit-identical**: the same append stream with
    injected connection drops (request-lost and reply-lost flavors)
    lands the exact same shard state as the clean run — zero duplicate
    inserts, per-writer applied counters equal;
  * a soft crash-on-Kth-append + restore-from-snapshot round trip
    preserves exactly-once across the restart;
  * retry budgets are bounded (deadline-exceeded raises a typed,
    operator-readable ConnectionError) and the param channel degrades
    to last-good params instead of taking its caller down.
"""

import socket
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.service import (ClientFaultInjector, ConnectionClosed, FaultPlan,
                           ReplayClient, ReplayService, ReplayServiceConfig,
                           RetryPolicy, backoff_delays, serve,
                           wait_for_service)
from repro.service.server import recv_msg
from repro.serve.params import ParamDoubleBuffer, ServiceParamChannel

EXAMPLE = {
    "obs": jnp.zeros((4,), jnp.float32),
    "action": jnp.zeros((), jnp.int32),
    "reward": jnp.zeros(()),
    "next_obs": jnp.zeros((4,), jnp.float32),
    "done": jnp.zeros(()),
}


def items(n, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "obs": rng.normal(size=(n, 4)).astype(np.float32),
        "action": rng.integers(0, 2, n).astype(np.int32),
        "reward": rng.uniform(0, 1, n).astype(np.float32),
        "next_obs": rng.normal(size=(n, 4)).astype(np.float32),
        "done": np.zeros(n, np.float32),
    }


FAST_RETRY = dict(base=0.01, cap=0.05, jitter=0.25, deadline=30.0)


def _service(n_shards=1, capacity=4096):
    return ReplayService(
        ReplayServiceConfig(capacity_per_shard=capacity, n_shards=n_shards,
                            fanout=8, seed=5), EXAMPLE)


# -- FaultPlan ---------------------------------------------------------------


def test_fault_plan_parse_and_validation():
    plan = FaultPlan.parse("drop_after_frames=3,drop_before_send=1,"
                           "crash_on_op=append:40,hard=true,seed=7")
    assert plan.drop_after_frames == 3 and plan.drop_before_send
    assert plan.crash_target == ("append", 40) and plan.hard
    assert plan.seed == 7
    assert FaultPlan.parse("").crash_target is None
    with pytest.raises(ValueError, match="unknown fault plan field"):
        FaultPlan.parse("explode=1")
    with pytest.raises(ValueError, match="key=value"):
        FaultPlan.parse("drop_after_frames")
    with pytest.raises(ValueError, match="cmd:K"):
        FaultPlan(crash_on_op="append")
    with pytest.raises(ValueError, match="must be ≥ 1"):
        FaultPlan(crash_on_op="append:0")
    with pytest.raises(ValueError, match="drop_prob"):
        FaultPlan(drop_prob=1.5)
    # crashes are a server-side fault: the client injector refuses them
    with pytest.raises(ValueError, match="server-side"):
        ClientFaultInjector(FaultPlan(crash_on_op="append:1"))


def test_backoff_delays_seeded_and_capped():
    pol = RetryPolicy(base=0.1, cap=1.0, factor=2.0, jitter=0.5, seed=11)
    import random
    a = [next(d) for d in [backoff_delays(pol, random.Random(11))]
         for _ in range(12)]
    b = [next(d) for d in [backoff_delays(pol, random.Random(11))]
         for _ in range(12)]
    assert a == b                                   # seeded: replayable
    assert all(x <= pol.cap * (1 + pol.jitter) for x in a)
    assert a[0] <= pol.base * (1 + pol.jitter)      # starts at base
    assert max(a) > pol.cap * (1 - pol.jitter)      # reaches the cap band
    with pytest.raises(ValueError, match="base"):
        RetryPolicy(base=0.0)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=1.0)
    with pytest.raises(ValueError, match="deadline"):
        RetryPolicy(deadline=-1.0)


# -- typed connection teardown ----------------------------------------------


def test_connection_closed_reports_progress():
    a, b = socket.socketpair()
    b.close()
    with pytest.raises(ConnectionClosed, match="closed connection before "
                                               "a frame"):
        recv_msg(a)
    a.close()
    a, b = socket.socketpair()
    b.sendall(b"\x00\x00\x00\x00")      # half of the 8-byte length prefix
    b.close()
    with pytest.raises(ConnectionClosed,
                       match=r"mid-frame \(4/8 bytes read\)") as ei:
        recv_msg(a)
    assert ei.value.bytes_read == 4 and ei.value.expected == 8
    a.close()


# -- idempotent appends under injected drops --------------------------------


def _run_append_stream(plan, chunks=20, chunk=64):
    """Drive one writer's full append stream through a served instance
    under ``plan``; returns (shard leaves, server stats, client)."""
    svc = _service()
    server, port = serve(svc, fault_plan=plan)
    client = ReplayClient("127.0.0.1", port,
                          retry=RetryPolicy(seed=3, **FAST_RETRY))
    try:
        for c in range(chunks):
            reply = client.append("w0", items(chunk, seed=c), timeout=30.0)
            assert reply["applied"]
        leaves = [np.asarray(x) for x in
                  (svc.states[0].storage["obs"], svc.states[0].tree)]
        return leaves, svc.stats(), client
    finally:
        client.close()
        server.shutdown()
        server.server_close()


@pytest.mark.parametrize("plan,expect_dedup", [
    (None, False),
    # reply lost after apply: the retry MUST be deduplicated
    (FaultPlan(drop_after_frames=3), True),
    # request lost before dispatch: the retry is the first application
    (FaultPlan(drop_after_frames=4, drop_before_send=True), False),
])
def test_append_retry_lands_exactly_once(plan, expect_dedup):
    clean, clean_stats, _ = _run_append_stream(None)
    leaves, stats, client = _run_append_stream(plan)
    assert stats["inserts"] == clean_stats["inserts"] == 20 * 64
    assert stats["writer_appends"] == {"w0": 20}
    assert client.acked_appends == 20
    for got, want in zip(leaves, clean):
        np.testing.assert_array_equal(got, want)     # bit-identical
    if plan is not None:
        assert client.reconnects > 0
        assert (client.deduped_appends > 0) == expect_dedup
        assert stats["dup_appends"] == client.deduped_appends


def test_sample_and_update_survive_reply_drops():
    """A retried sample is a fresh draw; a priority write-back on an
    orphaned handle is stale (applied=False), never an error."""
    svc = _service()
    server, port = serve(svc, fault_plan=FaultPlan(drop_after_frames=5))
    client = ReplayClient("127.0.0.1", port,
                          retry=RetryPolicy(seed=1, **FAST_RETRY))
    try:
        client.append("w0", items(256), timeout=30.0)
        seen = set()
        for _ in range(12):
            out = client.sample(batch=32)
            assert out["items"]["obs"].shape == (32, 4)
            assert out["sample_id"] not in seen      # every draw is fresh
            seen.add(out["sample_id"])
            client.update_priorities(out["sample_id"],
                                     np.ones(32, np.float32))
        assert client.reconnects > 0
    finally:
        client.close()
        server.shutdown()
        server.server_close()


# -- crash + restore ---------------------------------------------------------


def test_soft_crash_restore_is_exactly_once(tmp_path):
    """Crash-on-6th-append tears down the live server; a replacement
    restores the per-append snapshot onto the same port and the writer's
    retried stream lands exactly once across the restart."""
    manager = CheckpointManager(str(tmp_path), keep=2)
    svc = _service()
    svc.attach_snapshots(manager, every_appends=1)
    server, port = serve(svc, fault_plan=FaultPlan(crash_on_op="append:6"))
    restored = {}

    def monitor():
        server.crashed.wait(timeout=60.0)
        svc2 = _service()
        restored["step"] = svc2.restore_snapshot(
            CheckpointManager(str(tmp_path), keep=2))
        svc2.attach_snapshots(CheckpointManager(str(tmp_path), keep=2),
                              every_appends=1)
        restored["server"], _ = serve(svc2, port=port)
        restored["service"] = svc2

    mon = threading.Thread(target=monitor, daemon=True)
    mon.start()
    client = ReplayClient("127.0.0.1", port,
                          retry=RetryPolicy(seed=2, **FAST_RETRY))
    try:
        for c in range(12):
            assert client.append("w0", items(64, seed=c),
                                 timeout=30.0)["applied"]
        mon.join(timeout=60.0)
        st = restored["service"].stats()
        assert restored["step"] is not None
        assert st["restored_step"] == restored["step"]
        assert st["inserts"] == 12 * 64              # exactly once
        assert st["writer_appends"] == {"w0": 12}
        assert client.acked_appends == 12
        assert client.reconnects >= 1
    finally:
        client.close()
        server.server_close()
        if "server" in restored:
            restored["server"].shutdown()
            restored["server"].server_close()


def test_restore_snapshot_without_snapshots_returns_none(tmp_path):
    svc = _service()
    assert svc.restore_snapshot(CheckpointManager(str(tmp_path))) is None


# -- bounded retry ------------------------------------------------------------


def test_retry_deadline_exceeded_is_typed_and_bounded():
    svc = _service()
    server, port = serve(svc)
    client = ReplayClient("127.0.0.1", port,
                          retry=RetryPolicy(base=0.01, cap=0.05,
                                            deadline=1.0))
    assert client.ping()
    server.simulate_crash()
    t0 = time.monotonic()
    with pytest.raises(ConnectionError,
                       match=r"'ping' still failing after .* "
                             r"\(deadline 1s\)"):
        client.ping()
    assert time.monotonic() - t0 < 10.0             # bounded, not hung
    client.close()
    server.server_close()


def test_client_side_injected_drops_are_retried():
    svc = _service()
    server, port = serve(svc)
    client = ReplayClient("127.0.0.1", port,
                          retry=RetryPolicy(seed=4, **FAST_RETRY),
                          fault_plan=FaultPlan(drop_after_frames=3))
    try:
        for c in range(8):
            assert client.append("w0", items(16, seed=c),
                                 timeout=30.0)["applied"]
        assert svc.stats()["inserts"] == 8 * 16      # exactly once
        assert client.reconnects > 0
    finally:
        client.close()
        server.shutdown()
        server.server_close()


def test_wait_for_service_deadline_message():
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()                                    # nobody listening now
    t0 = time.monotonic()
    with pytest.raises(RuntimeError,
                       match=rf"replay service at 127.0.0.1:{port} not "
                             rf"reachable within 1s"):
        wait_for_service("127.0.0.1", port, timeout=1.0)
    assert time.monotonic() - t0 < 10.0


# -- graceful degradation -----------------------------------------------------


def test_param_channel_degrades_through_outage():
    svc = _service()
    server, port = serve(svc)
    client = ReplayClient("127.0.0.1", port,
                          retry=RetryPolicy(base=0.01, cap=0.02,
                                            deadline=0.2))
    buf = ParamDoubleBuffer({"w": np.zeros(3)}, version=0)
    chan = ServiceParamChannel(client, buf)
    client.put_params({"w": np.ones(3)})
    assert chan.poll()
    params, version, _ = buf.swap_if_staged()
    assert version == 1 and chan.stale_polls == 0

    server.simulate_crash()                          # outage begins
    for k in range(1, 4):
        assert not chan.poll()
        assert chan.outages == k and chan.stale_polls == k
    assert chan.last_error is not None
    # last-good params stay live throughout the outage
    live, v, swapped = buf.swap_if_staged()
    assert v == 1 and not swapped
    np.testing.assert_array_equal(live["w"], np.ones(3))
    server.server_close()

    svc2 = _service()
    server2, _ = serve(svc2, port=port)              # service returns
    try:
        ctl = ReplayClient("127.0.0.1", port)
        ctl.put_params({"w": np.full(3, 2.0)})
        ctl.put_params({"w": np.full(3, 3.0)})       # version 2 on svc2
        assert chan.poll()                           # recovery resets
        assert chan.stale_polls == 0
        _, v2, swapped = buf.swap_if_staged()
        assert swapped and v2 == 2
        ctl.close()
    finally:
        client.close()
        server2.shutdown()
        server2.server_close()
