"""Replay-service gang (launch/multiprocess.py + service/, DESIGN.md
§11): real OS processes — 1 replay server + 2 actor writers + 1 learner
— train CartPole end-to-end through the TCP service boundary.

These are the slowest tier-1 tests alongside test_multiprocess.py (every
role imports jax in its own process); the replay-service-smoke CI job
runs the same gang shape.  What they pin down:

  * the decoupled gang *learns*: the near-greedy eval return of the
    learner's final params clears the same criterion as the in-process
    system test (mean return > 30, tests/test_system.py);
  * the rate limiter's band theorem holds across process boundaries:
    |realized_spi − configured_spi| ≤ error_buffer / (inserts − min);
  * the learner can exit mid-run and a fresh process resumes from the
    checkpoint (CheckpointManager + elastic reshard) against the
    still-live service — actors park in writer backpressure, nothing
    deadlocks, and the learn-step count continues where it stopped.
"""

import pytest

from repro.launch import multiprocess as mp

# the proven in-process hyperparameters of tests/test_system.py, recast
# as explicit flow control: 1400 learns of batch 64 over ~11200 env
# steps ⇒ samples_per_insert = learns·batch/steps = 8
GANG = dict(n_actors=2, samples_per_insert=8.0, batch_size=64,
            warmup=400, n_envs=8, actor_chunk=8, epsilon=0.2, seed=1)


def _assert_spi_band(kv):
    realized = float(kv["REALIZED_SPI"])
    configured = float(kv["CONFIGURED_SPI"])
    tol = float(kv["SPI_TOLERANCE"])
    assert abs(realized - configured) <= tol, (realized, configured, tol)


def test_service_gang_trains_cartpole():
    res = mp.launch_service(learn_steps=1400, timeout_s=540.0, **GANG)

    server, learner = res["server"], res["learner"]
    _assert_spi_band(server)
    # counters agree across the boundary: the server's limiter totals are
    # what the learner saw in its final stats round trip
    assert server["INSERTS"] == learner["SERVICE_INSERTS"]
    assert server["SAMPLES"] == learner["SERVICE_SAMPLES"]
    assert int(learner["LEARN_STEPS"]) == 1400
    assert int(server["SAMPLES"]) == 1400 * GANG["batch_size"]
    # every transition the actors shipped landed in the (single) shard;
    # the server may hold up to one extra in-flight chunk per actor
    # (admitted between the learner's stop and the actor observing it)
    appended = sum(int(res[f"actor-{a}"]["TRANSITIONS"])
                   for a in range(GANG["n_actors"]))
    burst = GANG["actor_chunk"] * GANG["n_envs"]
    inserts = int(server["INSERTS"])
    assert appended <= inserts <= appended + GANG["n_actors"] * burst
    assert int(server["PER_SHARD_COUNT"]) == inserts
    # both writers made real progress (no actor starved by backpressure)
    for a in range(GANG["n_actors"]):
        assert int(res[f"actor-{a}"]["CHUNKS"]) > 10, res[f"actor-{a}"]
        assert int(res[f"actor-{a}"]["PARAMS_VERSION"]) > 1
    # the learning criterion of tests/test_system.py, through the service
    assert float(learner["EVAL_RETURN"]) > 30.0, learner


def test_service_gang_learner_restart_resumes_from_checkpoint(tmp_path):
    res = mp.launch_service(learn_steps=800, timeout_s=540.0,
                            ckpt_dir=str(tmp_path), ckpt_every=100,
                            restart_learner_after=300, **GANG)

    first, resumed = res["learner-0"], res["learner"]
    assert first["EXITED_EARLY"] == "1"
    assert int(first["LEARN_STEPS"]) == 300
    assert int(resumed["RESUMED_FROM"]) == 300
    assert int(resumed["LEARN_STEPS"]) == 800
    # the service survived the learner gap: one continuous limiter
    # history, still inside the band, with both actors running throughout
    _assert_spi_band(res["server"])
    assert int(res["server"]["SAMPLES"]) == 800 * GANG["batch_size"]
    for a in range(GANG["n_actors"]):
        assert int(res[f"actor-{a}"]["CHUNKS"]) > 10, res[f"actor-{a}"]


def test_service_gang_server_restart_restores_from_snapshot(tmp_path):
    """The server is the casualty (DESIGN.md §14): a fault plan hard-kills
    it at its 40th append, actors and learner park in reconnect backoff,
    a replacement restores the per-append shard snapshot onto the same
    port, and training runs through the fault to the same learning
    criterion.  Exactly-once is asserted as *bit-identical counters*:
    every actor's acked-append count equals the restored server's
    per-writer applied table — zero duplicate inserts across the crash."""
    res = mp.launch_service(learn_steps=1400, timeout_s=600.0,
                            snapshot_dir=str(tmp_path),
                            snapshot_every_appends=1,
                            restart_server_after=40,
                            retry_deadline=240.0, **GANG)

    server, learner = res["server"], res["learner"]
    assert int(server["RESTORED_STEP"]) >= 1
    assert int(server["SNAPSHOTS"]) >= 1
    # per-writer exactly-once across the restart: the client-side ack
    # count IS the server-side applied count, for every actor
    applied = dict(kv.split(":") for kv in
                   server["WRITER_APPENDS"].split(","))
    for a in range(GANG["n_actors"]):
        actor = res[f"actor-{a}"]
        assert int(actor["ACKED_APPENDS"]) == int(applied[f"actor-{a}"]), (
            actor, server)
        # the fault really hit this writer's connection
        assert int(actor["RECONNECTS"]) >= 1, actor
    # duplicates were *detected* (and not applied); the server may have
    # lost pre-crash dedup-ack counts that clients kept, never the
    # reverse
    deduped = sum(int(res[f"actor-{a}"]["DEDUPED_APPENDS"])
                  for a in range(GANG["n_actors"]))
    assert int(server["DUP_APPENDS"]) <= deduped
    # one continuous limiter history through the crash, inside the band
    _assert_spi_band(server)
    assert int(learner["LEARN_STEPS"]) == 1400
    # the learning criterion of tests/test_system.py, through the fault
    assert float(learner["EVAL_RETURN"]) > 30.0, learner


def test_launch_service_validates_inputs():
    with pytest.raises(ValueError, match="n_actors"):
        mp.launch_service(n_actors=0)
    with pytest.raises(ValueError, match="restart_learner_after"):
        mp.launch_service(n_actors=1, restart_learner_after=10)
    with pytest.raises(ValueError, match="restart_server_after"):
        mp.launch_service(n_actors=1, restart_server_after=10)
