"""Rolling BENCH archive: merge each CI run's artifacts into a cached
perf trajectory (the bench-archive job in .github/workflows/ci.yml).

Every push to main emits fresh ``BENCH_*.json`` (bench-smoke, the
wall-clock gang, the replay-service sweep, the actor-serve load
generator).  A single run only sees its own points; the archive keeps
the union.  This script is the whole job:

    PYTHONPATH=src python tools/bench_archive.py \
        --archive bench-archive/ --fresh fresh/ --run-id 12345

1. ingest: copy the fresh dir's ``BENCH_*.json`` (recursively — the
   download-artifact merge nests per-artifact subdirectories) into
   ``archive/runs/<run-id>/`` and append the run to ``manifest.json``;
2. merge: ``runtime/planner.merge_bench_points`` over ``archive/runs``
   — identical point identities keep the freshest measurement — and
   write one schema-valid snapshot per figure under ``archive/merged/``;
3. check: the merged identity sets must be supersets of BOTH the fresh
   run's identities and the pre-merge archive's identities.  When the
   manifest already lists prior runs (i.e. the actions/cache restore
   was supposed to bring them back), an empty prior identity set is a
   hard failure — that is exactly the silent-cache-miss case a rolling
   archive must not paper over.

The merged snapshot dir is what ``planner.plan_from_json`` consumes, so
the planner plans over the accumulated trajectory, not one run's files.
Exit is non-zero on any check failure.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import Dict, List, Set, Tuple

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.schema import FIGURE_METRICS, SchemaError, validate  # noqa: E402
from repro.runtime.planner import (  # noqa: E402
    _point_identity, merge_bench_points)

MANIFEST = "manifest.json"


def _load_manifest(archive: str) -> dict:
    path = os.path.join(archive, MANIFEST)
    if not os.path.exists(path):
        return {"runs": []}
    with open(path) as f:
        return json.load(f)


def _identities(bench_dir: str) -> Dict[str, Set[Tuple]]:
    """figure → set of point identities for every BENCH file under
    ``bench_dir`` (empty when the dir is missing)."""
    if not os.path.isdir(bench_dir):
        return {}
    return {figure: {_point_identity(p) for p in points}
            for figure, points in merge_bench_points(bench_dir).items()}


def _ingest(archive: str, fresh: str, run_id: str) -> List[str]:
    """Copy the fresh run's BENCH json into ``archive/runs/<run_id>/``,
    preserving subdirectories (the download-artifact merge nests one dir
    per artifact, and the merge walk needs the ``BENCH_*`` filename
    intact)."""
    dest = os.path.join(archive, "runs", run_id)
    copied = []
    for root, _dirs, files in sorted(os.walk(fresh)):
        for name in sorted(files):
            if not (name.startswith("BENCH_") and name.endswith(".json")):
                continue
            rel = os.path.relpath(root, fresh)
            sub = dest if rel == "." else os.path.join(dest, rel)
            os.makedirs(sub, exist_ok=True)
            shutil.copy2(os.path.join(root, name), os.path.join(sub, name))
            copied.append(os.path.normpath(os.path.join(rel, name)))
    return copied


def _write_merged(archive: str) -> Dict[str, int]:
    """One schema-valid snapshot per figure under ``archive/merged/``."""
    merged_dir = os.path.join(archive, "merged")
    if os.path.isdir(merged_dir):
        shutil.rmtree(merged_dir)  # rebuilt wholesale from runs/ each time
    os.makedirs(merged_dir)
    merged = merge_bench_points(os.path.join(archive, "runs"))
    counts = {}
    for figure, points in sorted(merged.items()):
        if figure not in FIGURE_METRICS:
            print(f"-- skipping unknown figure {figure!r} ({len(points)} "
                  "points) — not in benchmarks/schema.py")
            continue
        payload = {
            "figure": figure,
            "metric": FIGURE_METRICS[figure],
            "merged": True,
            "points": points,
        }
        try:
            validate(payload)
        except SchemaError as e:
            print(f"FAIL: merged {figure} snapshot is schema-invalid: {e}",
                  file=sys.stderr)
            raise
        path = os.path.join(merged_dir, f"BENCH_{figure}.json")
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        counts[figure] = len(points)
    return counts


def _check_superset(merged: Dict[str, Set[Tuple]],
                    part: Dict[str, Set[Tuple]], label: str) -> int:
    failures = 0
    for figure, idents in sorted(part.items()):
        missing = idents - merged.get(figure, set())
        if missing:
            print(f"FAIL: merged archive lost {len(missing)} {figure} "
                  f"point(s) present in the {label} set", file=sys.stderr)
            failures += 1
        else:
            print(f"OK  merged {figure} ⊇ {label} "
                  f"({len(idents)} identities)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archive", required=True,
                    help="rolling archive dir (actions/cache restore/save)")
    ap.add_argument("--fresh", required=True,
                    help="this run's BENCH artifacts (download-artifact "
                         "merge dir)")
    ap.add_argument("--run-id", required=True,
                    help="unique id for this run (github.run_id)")
    args = ap.parse_args()

    manifest = _load_manifest(args.archive)
    prior_runs = [r for r in manifest["runs"] if r["id"] != args.run_id]
    # identities BEFORE this run is ingested — the restored cache's view
    prior = _identities(os.path.join(args.archive, "runs"))
    fresh = _identities(args.fresh)
    if not fresh:
        print(f"FAIL: no BENCH points under {args.fresh!r} — nothing to "
              "archive", file=sys.stderr)
        return 1
    if prior_runs and not prior:
        print(f"FAIL: manifest lists {len(prior_runs)} prior run(s) but the "
              "restored archive holds zero points — the cache restore "
              "silently missed", file=sys.stderr)
        return 1

    copied = _ingest(args.archive, args.fresh, args.run_id)
    print(f"ingested run {args.run_id}: {len(copied)} file(s)")
    manifest["runs"] = prior_runs + [{"id": args.run_id, "files": copied}]
    with open(os.path.join(args.archive, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")

    counts = _write_merged(args.archive)
    for figure, n in sorted(counts.items()):
        print(f"merged/{figure}: {n} point(s) across "
              f"{len(manifest['runs'])} run(s)")

    merged = _identities(os.path.join(args.archive, "merged"))
    failures = _check_superset(merged, fresh, "fresh")
    if prior:
        failures += _check_superset(merged, prior, "prior-archive")
        if not failures:
            print(f"MERGED_RUNS={len(manifest['runs'])} (prior cache + "
                  "fresh both represented)")
    else:
        print("first archived run — no prior cache to merge")
    if failures:
        print(f"FAIL: {failures} archive check(s) failed", file=sys.stderr)
        return 1
    print("bench-archive: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
