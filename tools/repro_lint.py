#!/usr/bin/env python3
"""Standalone entry point for repro-lint — usable without PYTHONPATH:

    python tools/repro_lint.py [--check] [paths…]

Equivalent to ``PYTHONPATH=src python -m repro.analysis``; see
DESIGN.md §12 for the rule table and suppression workflow.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
