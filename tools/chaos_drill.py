#!/usr/bin/env python3
"""Chaos drill: the multiprocess server-kill-and-restore gang, run
standalone with machine-readable evidence (the chaos-smoke CI job).

    python tools/chaos_drill.py --out out/CHAOS_drill.json

Launches the real replay-service gang (launch/multiprocess.py) with a
hard fault plan: the server ``os._exit``s at its Nth append while every
append snapshots durably; actors and the learner park in reconnect
backoff; a replacement server restores the snapshot onto the same port
and training runs through the fault.  The drill then asserts the
fabric's contracts (DESIGN.md §14) rather than just "it exited 0":

  * the replacement really restored (RESTORED_STEP ≥ 1);
  * exactly-once appends as bit-identical counters — every actor's
    client-side acked-append count equals the restored server's
    per-writer applied table entry;
  * every actor reconnected at least once (the fault was real);
  * the limiter band held across the crash (one continuous history);
  * the learner finished all its steps and the policy clears the same
    learning criterion as the in-process system test.

The stats json it writes is uploaded as a CI artifact so a failing (or
suspicious) run leaves evidence: all worker counters, the recovery
topology, and wall time.  Exit is non-zero on any violated invariant.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.launch import multiprocess as mp  # noqa: E402

# the proven gang shape of tests/test_service_gang.py, shortened: the
# drill pins recovery invariants, the full learning criterion runs in
# the tier-1 gang test (learn_steps=1400 ⇒ eval return > 30)
GANG = dict(n_actors=2, samples_per_insert=8.0, batch_size=64,
            warmup=400, n_envs=8, actor_chunk=8, epsilon=0.2, seed=1)


def run_drill(learn_steps: int, restart_after: int, out_path: str) -> int:
    snap_dir = tempfile.mkdtemp(prefix="chaos_snap_")
    t0 = time.monotonic()
    res = mp.launch_service(learn_steps=learn_steps, timeout_s=600.0,
                            snapshot_dir=snap_dir,
                            snapshot_every_appends=1,
                            restart_server_after=restart_after,
                            retry_deadline=240.0, **GANG)
    wall_s = time.monotonic() - t0

    server, learner = res["server"], res["learner"]
    applied = dict(kv.split(":") for kv in
                   server["WRITER_APPENDS"].split(","))
    failures = []

    def check(ok: bool, what: str):
        if not ok:
            failures.append(what)

    check(int(server["RESTORED_STEP"]) >= 1,
          f"server did not restore (RESTORED_STEP="
          f"{server['RESTORED_STEP']})")
    check(int(server["SNAPSHOTS"]) >= 1, "restored server never snapshot")
    for a in range(GANG["n_actors"]):
        actor = res[f"actor-{a}"]
        acked, srv = int(actor["ACKED_APPENDS"]), int(applied[f"actor-{a}"])
        check(acked == srv,
              f"actor-{a}: acked {acked} != server applied {srv} "
              f"(duplicate or lost appends across the restart)")
        check(int(actor["RECONNECTS"]) >= 1,
              f"actor-{a}: never reconnected — the fault missed it")
    deduped = sum(int(res[f"actor-{a}"]["DEDUPED_APPENDS"])
                  for a in range(GANG["n_actors"]))
    check(int(server["DUP_APPENDS"]) <= deduped,
          f"server deduped {server['DUP_APPENDS']} > clients saw {deduped}")
    realized, configured = (float(server["REALIZED_SPI"]),
                            float(server["CONFIGURED_SPI"]))
    tol = float(server["SPI_TOLERANCE"])
    check(abs(realized - configured) <= tol,
          f"limiter band broken across restart: |{realized} - {configured}|"
          f" > {tol}")
    check(int(learner["LEARN_STEPS"]) == learn_steps,
          f"learner finished {learner['LEARN_STEPS']}/{learn_steps} steps")

    report = {
        "ok": not failures,
        "failures": failures,
        "wall_s": round(wall_s, 1),
        "learn_steps": learn_steps,
        "restart_server_after": restart_after,
        "gang": GANG,
        "workers": res,
    }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"# wrote {out_path} (wall {wall_s:.1f}s)")
    for line in failures:
        print(f"CHAOS FAIL: {line}", file=sys.stderr)
    if not failures:
        print(f"chaos drill: OK — restored at step "
              f"{server['RESTORED_STEP']}, "
              f"{sum(int(applied[k]) for k in applied)} appends applied "
              f"exactly once across the restart")
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="out/CHAOS_drill.json",
                    help="stats json path (CI artifact)")
    ap.add_argument("--learn-steps", type=int, default=300,
                    help="learner steps (default sized for CI smoke)")
    ap.add_argument("--restart-server-after", type=int, default=30,
                    help="hard-kill the server at this append count")
    args = ap.parse_args()
    return run_drill(args.learn_steps, args.restart_server_after, args.out)


if __name__ == "__main__":
    sys.exit(main())
