"""DSE auto-tuning (paper §V-D): profile collection/consumption curves on
this machine and print the Eq. 5 actor/learner allocation — and, when a
BENCH json directory is given, the full planner-selected runtime config
(runtime/planner.py, DESIGN.md §8).

    PYTHONPATH=src python examples/dse_autotune.py --total 8 --ratio 1

    # full-config planning from measured BENCH json
    PYTHONPATH=src python -m benchmarks.run --emit-json out/ --smoke
    PYTHONPATH=src python examples/dse_autotune.py --bench-json out/
"""

import argparse
import os

from benchmarks.fig12_dse import actor_throughput, learner_throughput
from repro.runtime import dse, planner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--total", type=int, default=8)
    ap.add_argument("--ratio", type=float, default=1.0,
                    help="update_interval (collect/consume target)")
    ap.add_argument("--bench-json", default=None, metavar="DIR",
                    help="also plan the full runtime config from the "
                         "BENCH json in DIR (benchmarks/run.py "
                         "--emit-json output)")
    args = ap.parse_args()

    lanes = [1, 2, 4, 8]
    print("profiling actor curve f_a(x)...")
    fa = dse.profile_curve(actor_throughput, lanes)
    print("profiling learner curve f_l(x)...")
    fl = dse.profile_curve(learner_throughput, lanes)
    for x in lanes:
        print(f"  x={x}: f_a={fa[x]:,.0f} steps/s   f_l={fl[x]:,.0f} items/s")
    res = planner.solve_lanes(fa, fl, args.total, args.ratio)
    print(f"\nEq.5 solution for total={args.total}, "
          f"update_interval={args.ratio}:")
    print(f"  actors x_a={res.x_actor} (→ {res.actor_throughput:,.0f}/s), "
          f"learners x_l={res.x_learner} (→ {res.learner_throughput:,.0f}/s)")
    print(f"  realized ratio {res.ratio:.2f} (target {res.target_ratio})")

    if args.bench_json:
        # executable configs carry an integer update_interval
        # (LoopConfig); round a fractional --ratio rather than silently
        # truncating it, and say so
        ui = max(1, round(args.ratio))
        if ui != args.ratio:
            print(f"\nnote: --ratio {args.ratio:g} rounded to "
                  f"update_interval={ui} for the executable plan")
        pc = planner.plan_from_json(
            args.bench_json, actor_curve=fa, learner_curve=fl,
            total_lanes=args.total, update_interval=ui)
        # write the plan just computed, so the printed command runs THIS
        # config — not whatever an earlier --emit-json left in the dir
        plan_path = os.path.join(args.bench_json, planner.PLAN_JSON)
        planner.save_plan(pc, plan_path)
        print(f"\nplanner-selected config: {pc.describe()}")
        print("  run it:  PYTHONPATH=src python examples/quickstart.py "
              f"--plan {plan_path}")


if __name__ == "__main__":
    main()
