"""DSE auto-tuning (paper §V-D): profile collection/consumption curves on
this machine and print the Eq. 5 actor/learner allocation.

    PYTHONPATH=src python examples/dse_autotune.py --total 8 --ratio 1
"""

import argparse

from benchmarks.fig12_dse import actor_throughput, learner_throughput
from repro.runtime import dse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--total", type=int, default=8)
    ap.add_argument("--ratio", type=float, default=1.0,
                    help="update_interval (collect/consume target)")
    args = ap.parse_args()

    lanes = [1, 2, 4, 8]
    print("profiling actor curve f_a(x)...")
    fa = dse.profile_curve(actor_throughput, lanes)
    print("profiling learner curve f_l(x)...")
    fl = dse.profile_curve(learner_throughput, lanes)
    for x in lanes:
        print(f"  x={x}: f_a={fa[x]:,.0f} steps/s   f_l={fl[x]:,.0f} items/s")
    res = dse.solve(fa, fl, args.total, args.ratio)
    print(f"\nEq.5 solution for total={args.total}, "
          f"update_interval={args.ratio}:")
    print(f"  actors x_a={res.x_actor} (→ {res.actor_throughput:,.0f}/s), "
          f"learners x_l={res.x_learner} (→ {res.learner_throughput:,.0f}/s)")
    print(f"  realized ratio {res.ratio:.2f} (target {res.target_ratio})")


if __name__ == "__main__":
    main()
